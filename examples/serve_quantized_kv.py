"""Serving example: batched continuous-batching decode with an int8
Q(2,6)-quantized KV cache vs the bf16 baseline.

The KV cache is the dominant decode traffic (paper §2.4's "data" at batch
scale); per-layer data bits applied to it halve-to-quarter the cache bytes.
Prints agreement between the two runs and the cache footprint ratio.

Run:  PYTHONPATH=src python examples/serve_quantized_kv.py
"""
import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model


def cache_bytes(caches):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(caches))


def main():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    mk = lambda: [Request(i, rng.integers(0, cfg.vocab_size, 10)
                          .astype(np.int32), 12) for i in range(8)]

    print("=== bf16 KV cache ===")
    srv_fp = BatchedServer(cfg, params, batch_size=4, max_len=96)
    reqs_fp = srv_fp.run(mk(), verbose=True)

    print("=== int8 Q(2,6) KV cache ===")
    rng = np.random.default_rng(0)
    srv_q8 = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8)
    reqs_q8 = srv_q8.run(mk(), verbose=True)

    fp_b, q8_b = cache_bytes(srv_fp.caches), cache_bytes(srv_q8.caches)
    print(f"\ncache footprint: bf16={fp_b / 2**20:.2f} MiB  "
          f"int8={q8_b / 2**20:.2f} MiB  ratio={q8_b / fp_b:.2f}")
    agree = np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                     for a, b in zip(reqs_fp, reqs_q8)])
    print(f"token agreement fp vs int8-KV: {agree:.1%}")


if __name__ == "__main__":
    main()
