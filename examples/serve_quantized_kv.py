"""Serving example: continuous-batching decode with quantized + paged KV.

The KV cache is the dominant decode traffic (paper §2.4's "data" at batch
scale). Three levers stack here:

* per-layer data bits (int8 Q(2,6) / int4 Q(2,2)) shrink every stored token,
* the paged layout (--page-size in launch.serve) allocates cache by pages
  actually used instead of batch * max_len slabs, and frees them per
  request,
* the serving hot path: **bucketed prefill** admits prompts in power-of-two
  chunks written straight into the paged pool (O(prompt/bucket) forwards
  instead of O(prompt) whole-batch steps; `prefill_bucket` caps the chunk,
  so at most log2(bucket)+1 prefill programs ever compile per row count);
  **multi-request batched prefill** (`prefill_batch` / ``--prefill-batch``)
  stacks same-bucket prompts admitted in one scheduler cycle into single
  ``[n_reqs, bucket]`` forwards with per-row page tables and valid lengths
  — fewer forwards and fewer compilations when traffic arrives in waves
  (0 = auto: the batch size; with the prefix cache on, same-wave prompts
  sharing a prefix are deduplicated by the prefix-aware wave dedupe below
  instead of falling back to sequential admission); and **unified
  attention routing** (`attn_impl="pallas"`): ONE variable-length Pallas chunk
  kernel (`kernels.paged_kv_attention`, scalar-prefetch page tables,
  per-row causal masking against cache positions) serves BOTH chunked
  prefill (S > 1) and decode (S = 1 — the kernel's single-row special
  case); interpret-mode on CPU, compiled on TPU.

Which modes remain **bitwise-reference**: `attn_impl="gather"` (jnp pool
reads, identical accumulation order to the dense cache) for every chunk
shape, and `prefill="stepwise"` (slot-granular whole-batch steps). Batched
prefill is bitwise-identical to sequential bucketed prefill (rows are
independent sequences writing disjoint pages — asserted in
tests/test_serve_fast.py), so it is NOT a reference/fast split; the pallas
kernel's per-page online softmax reorders accumulation, so pallas ==
gather only within float tolerance.

Two further levers ride the same paged pool:

* **shared-prefix page cache** (``prefix_cache="on"`` / ``--prefix-cache
  on``): requests sharing a system prompt alias its full pages (refcounted;
  freed only at refcount zero), copy-on-write the page where they diverge
  mid-page, and prefill only their suffix. Unreferenced cached prefixes are
  LRU-evicted under pool pressure; ``release_prefix_cache()`` drops them
  all and returns the leak count (0 = clean).
* **per-layer precision profiles** (``--kv-profile policy.json``, see
  examples/serve_policy_profile.py) store each layer's pages in the
  container its policy data format needs — the paper's per-layer result
  applied to serving HBM; ``--kv-scale page`` swaps the static Q(I,F) grid
  for dynamic per-page max-abs calibration.

Since PR 4 the bounded device pool is backed by a **tiered page store**:

* ``kv_offload="host"`` (``--kv-offload host``) adds a host-memory tier
  (``core.page_store``): pool pressure *demotes* unreferenced cached
  prefix pages to host numpy instead of destroying them, and admission
  *promotes* matched host pages back before aliasing. Demoted bytes stay
  in their packed int4/int8/fp containers, so offload traffic scales with
  the precision policy. ``host_pages=N`` (``--host-pages``) bounds the
  tier; when it fills, cold host pages are dropped LRU and eviction falls
  back to the PR-3 destructive path.
* ``sched="slo"`` (``--sched``) replaces FIFO admission: the queue is
  ordered by (priority, deadline_step, arrival), up to ``admit_window``
  requests may be admitted past a deferred head (no more head-of-line
  block), and a strictly more urgent request may PREEMPT a running one —
  the victim's written pages demote to the host tier, it re-queues, and
  resume promotes the pages back and continues decoding
  bitwise-identically (no re-prefill).
* ``snapshot_prefix_cache(path)`` / ``restore_prefix_cache(path)``
  (``--prefix-snapshot``) persist cached prefix chains across server
  restarts. The snapshot format is **profile-key-namespaced like the
  trie** — every chain carries its KV quantization key, so an int8
  snapshot can never back an int4 server — and a pool-geometry signature
  rejects arch mismatches. Restored pages land in the HOST tier (zero
  device pages until a hit promotes them).

Since PR 6 pool pressure can *narrow* pages before it evicts them —
**online precision adaptation** (``kv_adapt="on"`` / ``--kv-adapt on``;
needs the paged pool + prefix cache):

* the eviction chain becomes requantize -> host-demote -> drop: a cold,
  unreferenced cached prefix page is re-quantized one container step
  (fp -> int8 -> int4; fresh per-page max-abs scales, stale tail slots
  masked out of calibration) and PARKED in a device-resident quant tier
  instead of paying a host round trip. ``--kv-adapt-pages`` bounds the
  tier in int4-floor page-byte units (0 = auto: the pool size);
  ``--kv-adapt-floor {4,8}`` sets the narrowing floor (8 stops at int8,
  e.g. when accuracy headroom is thin).
* under continued pressure parked pages *deepen* toward the floor
  (int8 -> int4) to make byte room; only when the tier is genuinely full
  does eviction fall back to the PR-4 host tier and then the PR-3
  destructive drop.
* a later hit promotes a parked page back: the narrowed grid widens
  exactly into the pool's native container. The narrowing rounding loss
  is permanent — ``benchmarks.lm_precision.accuracy_gate`` prices it, and
  the ``--workload adapt`` bench gates >= 0.9 token agreement against the
  byte-exact adapt-off reference.
* ``OutOfPagesError.requantizable`` reports how many cold cached pages
  could still be narrowed right now (the operator hint that --kv-adapt
  headroom exists). With ``--kv-adapt off`` all of the above is bitwise
  inert (asserted in tests/test_serve_fast.py).

Since PR 7 steady-state serving can run **one program per scheduler
cycle** — the fused ragged forward (``fused="on"`` / ``--fused on``; needs
bucketed prefill):

* every cycle launches ONE ``[rows, S]`` variable-length program
  (``launch.steps.make_fused_step``): decode rows carry their single next
  token (1 valid query), admission rows carry a prefill chunk padded to
  the shared power-of-two bucket, each row with its own page table, start
  position, and valid length. Decode no longer waits for prefill programs
  — admission rounds ADVANCE the running slots (continuous batching with
  zero prefill/decode program switches), and the LM head gathers only the
  rows that emit a token this cycle, so prefill rows never pay vocab-width
  compute. The only retrace axis is the S bucket: steady-state decode
  (S=1) lowers to exactly the separate decode program, so fused output is
  bitwise-identical to ``fused="off"`` at kv-bits {0, 8, 4} and mixed
  profiles (asserted in tests/test_serve_fast.py); ``program_launches ==
  cycles`` by construction, counted and printed by the server.
* **prefix-aware wave dedupe** makes ``--prefill-batch`` compose with
  ``--prefix-cache``: prompts admitted in the same wave that share a page-
  aligned prefix elect a leader; followers wait, then alias the leader's
  freshly written pages (refcounted, like a cache hit) and prefill only
  their tail — fewer prefill forwards than sequential admission even when
  the shared prefix was never cached before. On the saturated
  shared-prefix backlog bench (``--workload ragged``) the composition cuts
  prefill forwards 13 -> 9 and fused cuts total program launches 61 -> 52
  at equal decode steps and 100% token agreement.

Since PR 8 the whole serving path is **observable** (``--metrics on``;
``runtime.telemetry``):

* one injectable :class:`~repro.runtime.telemetry.MetricsRegistry` per
  server threads through allocator, scheduler, prefix cache and host/quant
  tiers — every legacy counter attribute (``prefill_forwards``,
  ``preempt_count``, ``prefix_cache.hits``, …) is now registry-backed
  (``serve.*`` / ``sched.*`` / ``prefix.*`` / ``alloc.*`` / ``kv.*``
  names), with live ``kv.*`` gauges mirroring ``kv_inventory()`` exactly.
  ``registry.reset()`` / ``checkpoint()`` / ``since()`` are the sanctioned
  warmup/measurement boundary (benchmarks no longer hand-zero attributes).
* a span :class:`~repro.runtime.telemetry.Tracer` records the request
  lifecycle — arrive -> admit/defer/reject -> prefill chunks -> decode
  spans -> preempt/offload/resume -> finish — on a monotonic clock and
  exports **Chrome trace-event JSON** via ``--trace-out trace.json``: load
  it at https://ui.perfetto.dev (drag-and-drop) or chrome://tracing; tid 0
  is the engine track, tid 1+rid one track per request. The same records
  reduce to SLO metrics (``tracer.slo_summary()``): exact p50/p99 **TTFT**
  and **TPOT**, and **goodput** — the fraction of offered requests that
  finished by their ``deadline_step`` (printed after every ``--metrics
  on`` run; the ragged/overcommit benches append them to BENCH_serve.json).
* ``--metrics-out metrics.jsonl`` streams a ``registry.snapshot()`` JSONL
  line every ``--metrics-every`` scheduler cycles (counters + gauges +
  histogram summaries) for dashboard scraping.
* ``--metrics off`` (default) is the NullTracer path: telemetry lives
  entirely outside jitted code, so off is bitwise-identical to the
  pre-telemetry server (asserted in tests/test_telemetry.py).

Since PR 9 the telemetry closes the loop — **traffic at scale**
(``core.traffic`` + ``--predictor on`` / ``--pager-async on``):

* ``core.traffic.generate_trace`` expands a seeded :class:`TraceConfig`
  into a deterministic open-loop arrival stream — Poisson or bursty
  (2-state MMPP) arrivals, heavy-tailed lognormal prompt/output lengths,
  multi-tenant mixes with per-tenant priority, deadline slack, and
  Zipf-weighted shared-prefix pools. Equal configs yield byte-identical
  traces across processes (``trace_fingerprint``), so both arms of an
  A/B replay exactly the same offered load.
* ``SLOMonitor`` (``runtime.telemetry``) reduces the live run to rolling
  ``slo.*`` gauges — windowed goodput, TTFT/TPOT p50/p99 over the last N
  finished requests, queue-depth / arrival-rate / TPOT EWMAs — streamed
  with every ``--metrics-out`` snapshot line.
* ``--predictor on`` (needs ``--sched slo``) consults an online logistic
  **deadline-miss predictor** every admission cycle: features are queue
  depth, arrival-rate EWMA, free-page headroom, prefill debt, occupancy,
  and TPOT slowdown; the risk feeds a peak-hold hazard that resizes the
  SPECULATIVE share of the batch (no-deadline admissions throttle to 1
  then 0 as hazard crosses the gate) — deadlined requests are never
  gated. Retired deadlined requests SGD-update the weights online. On
  the bursty overload bench (``benchmarks.traffic --mode serve``) the
  gate lifts goodput 0.79 -> 0.91 at 100% token agreement.
* ``--pager-async on`` (needs ``--kv-offload host``) double-buffers
  demote/offload transfers: ``copy_to_host_async`` slices are enqueued
  at eviction time and drained at decode-span boundaries, so transfer
  time hides behind decode — ``pager.demote``/``pager.offload`` spans
  overlap ``decode_span`` on the Chrome trace's pager track.

Since PR 10 serving scales UP and OUT — **sharded multi-replica paged
serving** (``launch.frontend`` + ``--tp``):

* ``--tp N`` (``launch.mesh.make_serving_mesh``) makes one server a
  tensor-parallel replica over a ``(devices//N, N)`` data x model mesh:
  weights land TP-only via ``parallel.sharding.param_shardings(...,
  inference=True)`` (no per-token FSDP gathers) and the paged KV pool
  becomes a sharded pytree via ``paged_pool_shardings`` — page grids
  ``(NP, ps, KV, hdw)`` shard their KV-heads axis over "model" (int4
  lane-packing runs along head_dim, so packed lanes stay whole per
  shard), per-page scales replicate. Token streams are identical to the
  single-device server (CI asserts this on virtual host devices —
  tests/test_serving_mesh.py).
* :class:`~repro.launch.frontend.ReplicaFrontend` scales OUT: it consumes
  a ``core.traffic`` arrival stream and routes each request to one of N
  replica servers on ONE shared decode-step clock. Routing is
  prefix-affinity first — requests carrying a shared system prompt stick
  to the replica that prefilled it (pages keep being re-aliased instead
  of re-prefilled N times) — and yields to the least-loaded replica only
  past a load margin, where load = the replica's own ``slo.*`` gauges
  (queue-depth EWMA) + slot occupancy - paged-pool headroom.
* the :class:`~repro.launch.frontend.SharedPrefixStore` closes the pool:
  after each global round, every replica's cached chains publish into a
  cross-replica store on the PR-4 snapshot wire format (profile-key +
  pool-geometry namespaced) and install into the other replicas' HOST
  tiers — a hot system prompt prefilled once is aliasable by all, at
  zero device pages until a hit promotes it.
* the identity contract: a 1-replica frontend IS the plain server —
  bitwise-identical token streams at kv-bits {0, 8, 4} (asserted in
  tests/test_frontend.py; delivering arrivals at the shared clock caps
  decode spans exactly like a pending request does). On the bursty
  4x-overload trace (``benchmarks.traffic --mode replicas``) 2 replicas
  lift aggregate goodput 0.79 -> 1.00 at 100% token agreement, with the
  affinity map absorbing the shared-prefix tenants and the store moving
  the hot chains across the pool.
* the decode attention kernel grew a matching DMA-tuning knob:
  ``block_kv=True`` (``ops.paged_kv_attention_chunk``) fetches whole
  ``(ps, KV, hdw)`` pages per grid step — KVx fewer pipeline steps and
  page fetches on the same math (``benchmarks.kernel_bench --only
  paged_decode_gap``: 1.4x geomean faster at S=1, float-ULP agreement
  with the per-head default, which stays the shipped reference).

Error/failure semantics: paged admission preflights a request's WORST-CASE
page demand (prompt + max_new; with prefix sharing, only the non-shared
suffix plus one promotion page per matched host page is charged). A
request that can never fit the pool is rejected with
``core.paged_kv.OutOfPagesError`` carrying the full inventory
(needed/free/usable plus written vs reserved-but-unwritten vs
evictable-cached vs host-tier pages): FIFO mode records it on
``request.error``, SKIPS it (the queue behind it keeps being served — the
old behavior stalled), and re-raises after the run drains; SLO mode only
records it. A request that merely has to wait is deferred. Preemption
requires the host tier (victim pages must survive); with the tier full
and nothing droppable, preemption simply does not fire and the request
waits like before. The free list can never empty mid-prefill.

Prints token agreement between the runs and the cache footprint ratios.

Run:  PYTHONPATH=src python examples/serve_quantized_kv.py
"""
import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.paged_kv import OutOfPagesError
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model


def cache_bytes(caches):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(caches))


def agreement(a_reqs, b_reqs):
    return np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                    for a, b in zip(a_reqs, b_reqs)])


def main():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)

    def mk():
        rng = np.random.default_rng(0)
        return [Request(i, rng.integers(0, cfg.vocab_size, 10)
                        .astype(np.int32), 12) for i in range(8)]

    print("=== fp32 dense KV cache ===")
    srv_fp = BatchedServer(cfg, params, batch_size=4, max_len=96)
    reqs_fp = srv_fp.run(mk(), verbose=True)

    print("=== int8 Q(2,6) dense KV cache ===")
    srv_q8 = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8)
    reqs_q8 = srv_q8.run(mk(), verbose=True)

    print("=== int4 Q(2,2) paged KV cache (page_size=16, bucketed "
          "prefill) ===")
    srv_p4 = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=4,
                           page_size=16, num_pages=1 + 4 * 2,
                           prefill_bucket=16)
    reqs_p4 = srv_p4.run(mk(), verbose=True)
    print(f"  bucketed prefill: {srv_p4.prefill_forwards} chunk forwards for "
          f"{srv_p4.prefill_tokens} prompt tokens "
          f"(stepwise would take {srv_p4.prefill_tokens - 8} whole-batch "
          f"steps)")

    print("=== int8 paged + unified Pallas attention (prefill + decode "
          "through one chunk kernel; interpret on CPU) ===")
    srv_pl = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8,
                           page_size=16, attn_impl="pallas")
    reqs_pl = srv_pl.run(mk(), verbose=True)

    print("=== int8 paged + batched prefill (same-bucket prompts stacked "
          "into one [n, bucket] forward) ===")
    srv_bp = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8,
                           page_size=16, prefill_batch=4)
    srv_sp = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8,
                           page_size=16, prefill_batch=1)
    reqs_bp = srv_bp.run(mk(), verbose=True)
    reqs_sp = srv_sp.run(mk())
    print(f"  prefill forwards: {srv_sp.prefill_forwards} sequential -> "
          f"{srv_bp.prefill_forwards} batched "
          f"(token agreement {agreement(reqs_sp, reqs_bp):.1%}; "
          f"bitwise-identical under single-threaded XLA)")

    fp_b, q8_b = cache_bytes(srv_fp.caches), cache_bytes(srv_q8.caches)
    p4_b = cache_bytes(srv_p4.caches)
    print(f"\ncache footprint: fp32={fp_b / 2**20:.2f} MiB  "
          f"int8={q8_b / 2**20:.2f} MiB ({q8_b / fp_b:.2f}x)  "
          f"paged-int4={p4_b / 2**20:.2f} MiB ({p4_b / fp_b:.2f}x; "
          f"pool sized to live pages, not max_len)")
    print(f"token agreement fp vs int8-KV:        "
          f"{agreement(reqs_fp, reqs_q8):.1%}")
    print(f"token agreement fp vs paged-int4-KV:  "
          f"{agreement(reqs_fp, reqs_p4):.1%}")
    print(f"token agreement fp vs pallas-decode:  "
          f"{agreement(reqs_fp, reqs_pl):.1%}")
    print(f"pages free after run: {srv_p4.allocator.num_free}/"
          f"{srv_p4.allocator.num_pages - 1} (all requests released)")

    print("=== int8 paged + shared-prefix page cache ===")
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
    mk_shared = lambda: [
        Request(i, np.concatenate([sys_prompt,
                                   np.random.default_rng(i).integers(
                                       0, cfg.vocab_size, 4)
                                   .astype(np.int32)]), 10)
        for i in range(8)]
    srv_px = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8,
                           page_size=16, prefix_cache="on")
    srv_px.run(mk_shared(), verbose=True)
    st = srv_px.prefix_cache.stats()
    print(f"  {st['hits']}/{st['lookups']} prompts hit the cache "
          f"({st['hit_tokens']} tokens aliased, {st['cow_copies']} CoW "
          f"copies); {srv_px.prefill_forwards_saved} prefill forwards saved")
    print(f"  release_prefix_cache() -> {srv_px.release_prefix_cache()} "
          f"leaked pages (0 = every refcount balanced)")

    print("=== fused ragged forward: one program per scheduler cycle ===")
    srv_sep = BatchedServer(cfg, params, batch_size=4, max_len=96,
                            kv_bits=8, page_size=16, prefill_bucket=16,
                            prefix_cache="on", prefill_batch=1)
    reqs_sep = srv_sep.run(mk_shared(), verbose=True)
    srv_fu = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8,
                           page_size=16, prefill_bucket=16,
                           prefix_cache="on", fused="on")
    reqs_fu = srv_fu.run(mk_shared(), verbose=True)
    print(f"  programs: {srv_sep.program_launches} separate -> "
          f"{srv_fu.program_launches} fused over {srv_fu.cycles} cycles "
          f"(one per cycle: {srv_fu.program_launches == srv_fu.cycles}); "
          f"wave dedupe aliased {srv_fu.wave_dedup_pages} page(s); "
          f"token agreement {agreement(reqs_sep, reqs_fu):.1%} "
          f"(bitwise-identical under single-threaded XLA)")
    for s in (srv_sep, srv_fu):
        assert s.release_prefix_cache() == 0

    print("=== tiered page store: host offload + SLO preemption + "
          "restart ===")
    import os
    import tempfile
    mk_tiered = lambda: [
        Request(0, np.concatenate([sys_prompt, np.arange(3, dtype=np.int32)]),
                16, priority=0),                       # long, low priority
        Request(1, np.concatenate([sys_prompt, np.arange(2, dtype=np.int32)]),
                6, priority=5, arrive_step=4, deadline_step=24),  # urgent
        Request(2, np.concatenate([sys_prompt, np.arange(4, dtype=np.int32)]),
                8, priority=1, arrive_step=10),
    ]
    tiered_kw = dict(batch_size=1, max_len=96, kv_bits=8, page_size=16,
                     num_pages=5,                      # 4 usable: too small
                     prefix_cache="on", kv_offload="host", sched="slo")
    srv_t = BatchedServer(cfg, params, **tiered_kw)
    reqs_t = srv_t.run(mk_tiered(), verbose=True)
    print(f"  {srv_t.preempt_count} preemption(s), {srv_t.resume_count} "
          f"resume(s), {srv_t.realias_skipped} victim-page demotion(s) "
          f"skipped by re-aliasing still-resident prefix nodes; "
          f"every request completed: "
          f"{all(r.done and r.error is None for r in reqs_t)}")
    print(f"  kv inventory (device/host split): {srv_t.kv_inventory()}")
    snap = os.path.join(tempfile.mkdtemp(), "prefix_pages.npz")
    n = srv_t.snapshot_prefix_cache(snap)
    srv_t2 = BatchedServer(cfg, params, **tiered_kw)
    m = srv_t2.restore_prefix_cache(snap)
    srv_t2.run(mk_tiered())
    s2 = srv_t2.prefix_cache.stats()
    print(f"  restart: {n} pages snapshotted -> {m} restored to the host "
          f"tier; hit rate after restore {s2['hit_rate']:.0%} "
          f"({s2['promotions']} host pages promoted on demand)")
    for s in (srv_t, srv_t2):
        assert s.release_prefix_cache() == 0 and s.host_store.num_pages == 0

    print("=== online precision adaptation: requantize before demote ===")
    rng = np.random.default_rng(9)
    mk_adapt = lambda: [
        Request(i, np.concatenate([
            np.asarray(tenant, np.int32),
            rng.integers(0, cfg.vocab_size, 3).astype(np.int32)]), 8)
        for i, tenant in enumerate(
            rng.integers(0, cfg.vocab_size, (4, 18)))]
    srv_ad = BatchedServer(cfg, params, batch_size=2, max_len=96, kv_bits=8,
                           page_size=16, num_pages=6,   # 5 usable: too small
                           prefix_cache="on", kv_offload="host",
                           kv_adapt="on")
    reqs_ad = srv_ad.run(mk_adapt(), verbose=True)
    st = srv_ad.prefix_cache.stats()
    print(f"  eviction chain requant->demote->drop: {st['requants']} "
          f"page(s) narrowed in place, {st['deepens']} deepened toward the "
          f"int4 floor, {st['demotions']} host demotion(s) "
          f"(requants before the first: {st['requants_at_first_demotion']}), "
          f"{st['tier_promotions']} parked page(s) promoted on a later hit")
    print(f"  kv inventory (device/host/tier): {srv_ad.kv_inventory()}")
    print(f"  every request completed: "
          f"{all(r.done and r.error is None for r in reqs_ad)}")
    assert srv_ad.release_prefix_cache() == 0
    assert srv_ad.quant_tier.num_pages == 0
    assert srv_ad.host_store.num_pages == 0

    print("=== telemetry: lifecycle trace + SLO goodput (--metrics on) ===")
    srv_tm = BatchedServer(cfg, params, batch_size=1, max_len=96, kv_bits=8,
                           page_size=16, num_pages=5, prefix_cache="on",
                           kv_offload="host", sched="slo", metrics="on")
    srv_tm.run(mk_tiered(), verbose=True)
    slo = srv_tm.tracer.slo_summary()
    print(f"  slo_summary: goodput {slo['goodput']:.2f} "
          f"({slo['finished']}/{slo['requests']} finished, "
          f"{slo['deadline_misses']} deadline misses, "
          f"{slo['preemptions']} preemptions)")
    print(f"  ttft p50 {1e3 * slo['ttft_p50_s']:.1f} ms / p99 "
          f"{1e3 * slo['ttft_p99_s']:.1f} ms; "
          f"tpot p50 {1e3 * (slo['tpot_p50_s'] or 0):.2f} ms")
    m = srv_tm.metrics
    print(f"  registry: serve.decode_steps="
          f"{m.counter('serve.decode_steps').value} "
          f"serve.preempt_count={m.counter('serve.preempt_count').value} "
          f"prefix.hits={m.counter('prefix.hits').value} "
          f"kv.device_bytes={m.gauge('kv.device_bytes').value} "
          f"kv.host_pages={m.gauge('kv.host_pages').value}")
    trace_out = os.path.join(tempfile.mkdtemp(), "serve_trace.json")
    srv_tm.tracer.export_chrome(trace_out)
    print(f"  {len(srv_tm.tracer.events)} trace events -> {trace_out} "
          f"(load at https://ui.perfetto.dev or chrome://tracing)")
    assert srv_tm.release_prefix_cache() == 0

    print("=== traffic harness: seeded bursty trace -> SLO gauges + "
          "deadline-miss predictor ===")
    from repro.core.traffic import TenantSpec, TraceConfig, generate_trace, \
        trace_fingerprint
    trace = generate_trace(TraceConfig(
        seed=5, horizon=24, rate=0.1, process="bursty", burst_rate=1.2,
        p_enter_burst=0.2, p_exit_burst=0.3, vocab_size=cfg.vocab_size,
        tenants=(
            TenantSpec("chat", weight=0.7, priority=5, deadline_slack=6,
                       prompt_mean=8, prompt_cap=14, max_new_mean=3,
                       max_new_cap=5, shared_prefix_len=8, prefix_pool=2),
            TenantSpec("batch", weight=0.3, max_new_mean=8, max_new_cap=12),
        )))
    print(f"  trace: {len(trace.requests)} arrivals over "
          f"{trace.config.horizon} steps, burst overload "
          f"{trace.overload_ratio(batch_size=2):.1f}x sustainable, "
          f"fingerprint {trace_fingerprint(trace)[:12]}... "
          f"(same seed = same stream, any process)")
    srv_tr = BatchedServer(cfg, params, batch_size=2, max_len=96, kv_bits=8,
                           page_size=16, num_pages=9, prefix_cache="on",
                           kv_offload="host", sched="slo", preempt=False,
                           metrics="on", predictor="on", pager_async="on")
    srv_tr.run([Request(r.rid, np.array(r.prompt), r.max_new,
                        priority=r.priority, deadline_step=r.deadline_step,
                        arrive_step=r.arrive_step)
                for r in trace.requests], verbose=True)
    slo = srv_tr.tracer.slo_summary()
    gauges = {k: v for k, v in srv_tr.metrics.snapshot()["gauges"].items()
              if k.startswith("slo.")}
    print(f"  windowed slo.* gauges (live during the run, snapshot-streamed "
          f"via --metrics-out): goodput "
          f"{gauges['slo.window_goodput']:.2f} over "
          f"{gauges['slo.window_requests']:.0f} reqs, queue EWMA "
          f"{gauges['slo.queue_depth_ewma']:.1f}, arrival EWMA "
          f"{gauges['slo.arrival_rate_ewma']:.2f}/step")
    print(f"  predictor: {srv_tr.predictor.gated} speculative admission(s) "
          f"gated, {srv_tr.predictor.updates} online SGD update(s), final "
          f"hazard {srv_tr.predictor.hazard:.2f}; async pager "
          f"{srv_tr.pager.demotions} demotion(s) overlapped with decode")
    print(f"  exact post-hoc goodput {slo['goodput']:.2f} "
          f"({slo['deadline_misses']} deadline misses / {slo['requests']} "
          f"offered)")
    assert srv_tr.release_prefix_cache() == 0

    print("=== multi-replica frontend: prefix-affinity routing + shared "
          "prefix store ===")
    from repro.launch.frontend import (ReplicaFrontend, aggregate_goodput,
                                       make_replicas, requests_from_trace)
    common = dict(batch_size=2, max_len=96, kv_bits=8, page_size=16,
                  num_pages=9, prefix_cache="on", kv_offload="host",
                  sched="slo", preempt=False, metrics="on",
                  pager_async="on")
    goodput = {}
    for n in (1, 2):
        fe = ReplicaFrontend(make_replicas(n, cfg, params, **common))
        reqs, keys = requests_from_trace(trace)   # same offered stream
        fe.run(reqs, keys)
        goodput[n] = aggregate_goodput(reqs)
        if n == 2:
            c = fe.metrics.snapshot()["counters"]
            print(f"  2 replicas: routed "
                  f"[{c.get('frontend.routed_replica0', 0)}, "
                  f"{c.get('frontend.routed_replica1', 0)}], "
                  f"{c.get('frontend.affinity_hits', 0)} affinity hits, "
                  f"{c.get('frontend.rebalanced', 0)} rebalances, "
                  f"{c.get('frontend.shared_prefix_pages', 0)} prefix "
                  f"pages exchanged through the shared store")
    print(f"  aggregate goodput on the same trace: 1 replica "
          f"{goodput[1]:.2f} (== the plain server, bitwise) -> 2 replicas "
          f"{goodput[2]:.2f}")

    # admission preflight: a request whose prompt + max_new can never be
    # backed by the pool is rejected with counts — recorded on the request
    # and (FIFO mode) re-raised AFTER serviceable traffic drains, so a
    # too-large head no longer starves the queue behind it
    tiny = BatchedServer(cfg, params, batch_size=2, max_len=96, kv_bits=8,
                         page_size=16, num_pages=4)   # 3 usable pages
    ok_req = Request(100, np.arange(8, dtype=np.int32), 8)
    try:
        tiny.run([Request(99, np.arange(40, dtype=np.int32), 50), ok_req])
    except OutOfPagesError as e:
        print(f"\nOutOfPagesError (expected): {e}")
    print(f"request behind the rejected head still served: {ok_req.done} "
          f"({len(ok_req.out)} tokens)")


if __name__ == "__main__":
    main()
