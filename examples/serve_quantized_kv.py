"""Serving example: continuous-batching decode with quantized + paged KV.

The KV cache is the dominant decode traffic (paper §2.4's "data" at batch
scale). Three levers stack here:

* per-layer data bits (int8 Q(2,6) / int4 Q(2,2)) shrink every stored token,
* the paged layout (--page-size in launch.serve) allocates cache by pages
  actually used instead of batch * max_len slabs, and frees them per
  request,
* the serving hot path: **bucketed prefill** admits prompts in power-of-two
  chunks written straight into the paged pool (O(prompt/bucket) forwards
  instead of O(prompt) whole-batch steps; `prefill_bucket` caps the chunk,
  so at most log2(bucket)+1 prefill programs ever compile), and
  `attn_impl="pallas"` routes decode attention through the scalar-prefetch
  Pallas kernel (`kernels.paged_kv_attention`) — interpret-mode on CPU,
  compiled on TPU. `attn_impl="gather"` stays the bitwise-reference mode.

Two further levers ride the same paged pool:

* **shared-prefix page cache** (``prefix_cache="on"`` / ``--prefix-cache
  on``): requests sharing a system prompt alias its full pages (refcounted;
  freed only at refcount zero), copy-on-write the page where they diverge
  mid-page, and prefill only their suffix. Unreferenced cached prefixes are
  LRU-evicted under pool pressure; ``release_prefix_cache()`` drops them
  all and returns the leak count (0 = clean).
* **per-layer precision profiles** (``--kv-profile policy.json``, see
  examples/serve_policy_profile.py) store each layer's pages in the
  container its policy data format needs — the paper's per-layer result
  applied to serving HBM; ``--kv-scale page`` swaps the static Q(I,F) grid
  for dynamic per-page max-abs calibration.

Error semantics: paged admission preflights a request's WORST-CASE page
demand (prompt + max_new; with prefix sharing, only the non-shared suffix
is charged). A request that can never fit the pool raises
``core.paged_kv.OutOfPagesError`` with the counts (needed/free/usable plus
written vs reserved-but-unwritten vs evictable-cached); one that only has
to wait for live requests to release pages is deferred in the queue. The
free list can therefore never empty mid-prefill.

Prints token agreement between the runs and the cache footprint ratios.

Run:  PYTHONPATH=src python examples/serve_quantized_kv.py
"""
import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.paged_kv import OutOfPagesError
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model


def cache_bytes(caches):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(caches))


def agreement(a_reqs, b_reqs):
    return np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                    for a, b in zip(a_reqs, b_reqs)])


def main():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)

    def mk():
        rng = np.random.default_rng(0)
        return [Request(i, rng.integers(0, cfg.vocab_size, 10)
                        .astype(np.int32), 12) for i in range(8)]

    print("=== fp32 dense KV cache ===")
    srv_fp = BatchedServer(cfg, params, batch_size=4, max_len=96)
    reqs_fp = srv_fp.run(mk(), verbose=True)

    print("=== int8 Q(2,6) dense KV cache ===")
    srv_q8 = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8)
    reqs_q8 = srv_q8.run(mk(), verbose=True)

    print("=== int4 Q(2,2) paged KV cache (page_size=16, bucketed "
          "prefill) ===")
    srv_p4 = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=4,
                           page_size=16, num_pages=1 + 4 * 2,
                           prefill_bucket=16)
    reqs_p4 = srv_p4.run(mk(), verbose=True)
    print(f"  bucketed prefill: {srv_p4.prefill_forwards} chunk forwards for "
          f"{srv_p4.prefill_tokens} prompt tokens "
          f"(stepwise would take {srv_p4.prefill_tokens - 8} whole-batch "
          f"steps)")

    print("=== int8 paged + Pallas decode kernel (interpret on CPU) ===")
    srv_pl = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8,
                           page_size=16, attn_impl="pallas")
    reqs_pl = srv_pl.run(mk(), verbose=True)

    fp_b, q8_b = cache_bytes(srv_fp.caches), cache_bytes(srv_q8.caches)
    p4_b = cache_bytes(srv_p4.caches)
    print(f"\ncache footprint: fp32={fp_b / 2**20:.2f} MiB  "
          f"int8={q8_b / 2**20:.2f} MiB ({q8_b / fp_b:.2f}x)  "
          f"paged-int4={p4_b / 2**20:.2f} MiB ({p4_b / fp_b:.2f}x; "
          f"pool sized to live pages, not max_len)")
    print(f"token agreement fp vs int8-KV:        "
          f"{agreement(reqs_fp, reqs_q8):.1%}")
    print(f"token agreement fp vs paged-int4-KV:  "
          f"{agreement(reqs_fp, reqs_p4):.1%}")
    print(f"token agreement fp vs pallas-decode:  "
          f"{agreement(reqs_fp, reqs_pl):.1%}")
    print(f"pages free after run: {srv_p4.allocator.num_free}/"
          f"{srv_p4.allocator.num_pages - 1} (all requests released)")

    print("=== int8 paged + shared-prefix page cache ===")
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
    mk_shared = lambda: [
        Request(i, np.concatenate([sys_prompt,
                                   np.random.default_rng(i).integers(
                                       0, cfg.vocab_size, 4)
                                   .astype(np.int32)]), 10)
        for i in range(8)]
    srv_px = BatchedServer(cfg, params, batch_size=4, max_len=96, kv_bits=8,
                           page_size=16, prefix_cache="on")
    srv_px.run(mk_shared(), verbose=True)
    st = srv_px.prefix_cache.stats()
    print(f"  {st['hits']}/{st['lookups']} prompts hit the cache "
          f"({st['hit_tokens']} tokens aliased, {st['cow_copies']} CoW "
          f"copies); {srv_px.prefill_forwards_saved} prefill forwards saved")
    print(f"  release_prefix_cache() -> {srv_px.release_prefix_cache()} "
          f"leaked pages (0 = every refcount balanced)")

    # admission preflight: a request whose prompt + max_new can never be
    # backed by the pool is rejected up front with counts
    tiny = BatchedServer(cfg, params, batch_size=2, max_len=96, kv_bits=8,
                         page_size=16, num_pages=4)   # 3 usable pages
    try:
        tiny.run([Request(99, np.arange(40, dtype=np.int32), 50)])
    except OutOfPagesError as e:
        print(f"\nOutOfPagesError (expected): {e}")


if __name__ == "__main__":
    main()
