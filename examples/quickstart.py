"""Quickstart: the paper's method in ~60 lines.

1. Build a tiny LeNet on procedural digits,
2. quantize per layer with Q(I,F) formats,
3. run the paper's greedy search,
4. print the accuracy/traffic Pareto table (paper Table 2 format).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.fixedpoint import FixedPointFormat, fake_quant
from repro.core.policy import PrecisionPolicy
from repro.core.search import greedy_pareto_search
from repro.data.synthetic import digits_dataset
from repro.models.cnn import (LENET, cnn_accuracy, cnn_loss,
                              cnn_traffic_model, init_cnn)


def main():
    # --- the core op: the paper's memory-boundary conversion -------------
    x = jnp.asarray([0.7311, -1.2, 3.9, 0.01])
    print("fake_quant Q(2,4):", fake_quant(x, 2, 4))   # grid of 1/16ths

    # --- train LeNet quickly on synthetic digits -------------------------
    spec = LENET
    params = init_cnn(jax.random.PRNGKey(0), spec)
    xs, ys = digits_dataset(2048, seed=0)
    xv, yv = digits_dataset(512, seed=1)
    grad = jax.jit(jax.grad(lambda p, b: cnn_loss(p, b, spec)))
    print("training LeNet on procedural digits ...")
    for i in range(200):
        sl = slice((i * 64) % 1984, (i * 64) % 1984 + 64)
        g = grad(params, {"image": jnp.asarray(xs[sl]),
                          "label": jnp.asarray(ys[sl])})
        params = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params, g)
    base = cnn_accuracy(params, jnp.asarray(xv), jnp.asarray(yv), spec)
    print(f"baseline top-1: {base:.4f}")

    # --- the paper's §2.5 search ------------------------------------------
    tm = cnn_traffic_model(spec)
    init = PrecisionPolicy.uniform(spec.layer_names,
                                   FixedPointFormat(1, 10),  # weights Q1.10
                                   FixedPointFormat(10, 4))  # data Q10.4
    res = greedy_pareto_search(
        lambda pol: cnn_accuracy(params, jnp.asarray(xv), jnp.asarray(yv),
                                 spec, pol),
        tm, init, baseline_accuracy=base, batch_size=50, verbose=False)
    print(res.table())
    pick = res.select(0.01)
    if pick:
        print(f"\nchosen mixed config @1% tolerance "
              f"(TR={pick.traffic_ratio:.3f}):")
        print(pick.policy.table())


if __name__ == "__main__":
    main()
