"""Paper search -> per-layer KV profile -> serving, end to end.

The paper's §2.5 greedy search emits a per-layer PrecisionPolicy; this
example closes the loop the ROADMAP asks for — the search output drives the
SERVING memory footprint:

1. run ``core.search.greedy_pareto_search`` on a smoke LM, scoring each
   candidate policy by greedy-decode token agreement against the fp32
   rollout (the serving-relevant accuracy proxy), with KV-dominated decode
   traffic as the cost model;
2. pick the cheapest policy within tolerance and write it to JSON
   (``PrecisionPolicy.to_json`` — the same file ``--kv-profile`` loads);
3. serve with ``--kv-profile``: each layer's paged pool is built in the
   container its searched data format needs (int4 pages for <= 4 bits,
   int8 for <= 8, float pages for fp32 layers), plus the shared-prefix
   page cache on top (``--prefix-cache on``), and compare at-rest KV bytes
   and output quality against uniform int8.

Run:  PYTHONPATH=src python examples/serve_policy_profile.py
"""
import os

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.fixedpoint import FixedPointFormat
from repro.core.policy import PrecisionPolicy
from repro.core.search import greedy_pareto_search
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import decode_step, init_model, prefill
from repro.quant.apply import (build_model_quant, transformer_layer_names,
                               transformer_traffic_model)

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def main():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)

    # -- 1. search: score = decode token agreement vs the fp32 rollout ------
    # quant rides the jitted rollout as a pytree ARGUMENT: every candidate
    # policy shares one compiled program (the paper's search visits dozens)
    steps = 6

    def _rollout(params, tokens, quant):
        logits, caches, pos = prefill(params, {"tokens": tokens}, cfg,
                                      quant=quant,
                                      max_len=tokens.shape[1] + steps + 1)
        cur = logits.argmax(-1).astype(np.int32)
        out = [cur]
        for s in range(steps - 1):
            logits, caches = decode_step(params, cur, pos + s, caches, cfg,
                                         quant=quant)
            cur = logits.argmax(-1).astype(np.int32)
            out.append(cur)
        return jax.numpy.stack(out)

    rollout_j = jax.jit(_rollout)
    ref = np.asarray(rollout_j(params, toks, None))

    def eval_fn(policy):
        mq = build_model_quant(policy, cfg, quantize_kv=True,
                               quantize_activations=False)
        return float(np.mean(np.asarray(rollout_j(params, toks, mq)) == ref))

    names = transformer_layer_names(cfg)
    init = PrecisionPolicy.uniform(names, None, FixedPointFormat(2, 6))
    traffic = transformer_traffic_model(cfg, batch=1, seq_len=64,
                                        mode="decode")
    res = greedy_pareto_search(eval_fn, traffic, init,
                               fields=("data_int", "data_frac"),
                               max_steps=8, verbose=True)
    point = res.select(tolerance=0.05) or res.trajectory[-1]
    policy = point.policy
    print(f"\nsearched policy (traffic ratio "
          f"{point.traffic_ratio:.3f} vs fp32):\n{policy.table()}")

    # -- 2. the JSON file --kv-profile consumes -----------------------------
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "kv_policy_qwen2_72b_smoke.json")
    with open(path, "w") as f:
        f.write(policy.to_json())
    print(f"policy written to {path}")
    with open(path) as f:
        loaded = PrecisionPolicy.from_json(f.read())

    # -- 3. serve it: per-layer containers + shared-prefix cache ------------
    sys_prompt = rng.integers(0, cfg.vocab_size, 18).astype(np.int32)

    def mk():
        r = np.random.default_rng(7)
        return [Request(i, np.concatenate(
                    [sys_prompt, r.integers(0, cfg.vocab_size, 4)
                     .astype(np.int32)]), 8) for i in range(6)]

    def kv_bytes(srv):
        total = 0
        for seg in srv.caches:
            for entry in seg:
                for d in (entry if isinstance(entry, list) else [entry]):
                    if isinstance(d, dict) and "k_pages" in d:
                        total += sum(int(np.prod(a.shape)) * a.dtype.itemsize
                                     for a in d.values())
        return total

    base = dict(batch_size=3, max_len=64, page_size=8, prefix_cache="on")
    print("\n=== uniform int8 + prefix cache ===")
    srv8 = BatchedServer(cfg, params, kv_bits=8, **base)
    out8 = srv8.run(mk(), verbose=True)
    print("=== searched per-layer profile (--kv-profile) + prefix cache ===")
    srvp = BatchedServer(cfg, params, kv_profile=loaded, **base)
    outp = srvp.run(mk(), verbose=True)
    print(f"profile key: {srvp.profile_key}")

    agree = np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                     for a, b in zip(out8, outp)])
    b8, bp = kv_bytes(srv8), kv_bytes(srvp)
    print(f"\nat-rest KV pools: uniform-int8 {b8 / 2**10:.1f} KiB -> "
          f"profile {bp / 2**10:.1f} KiB ({bp / b8:.2f}x)")
    print(f"token agreement profile vs uniform-int8: {agree:.1%}")
    print(f"prefix stats (profile server): {srvp.prefix_cache.stats()}")
    leak8, leakp = srv8.release_prefix_cache(), srvp.release_prefix_cache()
    print(f"refcount leaks after release: int8={leak8} profile={leakp}")


if __name__ == "__main__":
    main()
