"""The paper's full experiment on LeNet: train, calibrate, search, report —
including the calibration-based initialization (core.calibrate) that replaces
the paper's empirical integer-bit sweeps.

Run:  PYTHONPATH=src python examples/precision_search_lenet.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import RangeStats, calibrated_policy
from repro.core.search import greedy_pareto_search, sensitivity_search
from repro.data.synthetic import digits_dataset
from repro.models.cnn import (LENET, cnn_accuracy, cnn_forward, cnn_loss,
                              cnn_traffic_model, init_cnn)


def main():
    spec = LENET
    params = init_cnn(jax.random.PRNGKey(0), spec)
    xs, ys = digits_dataset(3072, seed=0)
    xv, yv = digits_dataset(768, seed=1)
    grad = jax.jit(jax.grad(lambda p, b: cnn_loss(p, b, spec)))
    print("training ...")
    for i in range(250):
        sl = slice((i * 64) % 3008, (i * 64) % 3008 + 64)
        g = grad(params, {"image": jnp.asarray(xs[sl]),
                          "label": jnp.asarray(ys[sl])})
        params = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params, g)
    base = cnn_accuracy(params, jnp.asarray(xv), jnp.asarray(yv), spec)
    print(f"baseline top-1: {base:.4f}")

    # --- calibration: observed ranges -> integer bits ----------------------
    # weights: direct; data: per-layer outputs via truncated-prefix forwards
    import dataclasses
    stats_w, stats_d = RangeStats(), RangeStats()
    x = jnp.asarray(xv[:64])
    for i, l in enumerate(spec.layers):
        stats_w.update(l.name, params[l.name]["w"])
        sub = dataclasses.replace(spec, layers=spec.layers[:i + 1])
        out = cnn_forward({k: params[k] for k in sub.layer_names}, x, sub)
        stats_d.update(l.name, out)

    pol0 = calibrated_policy(
        spec.layer_names,
        {n: stats_w.max_abs[n] for n in spec.layer_names},
        {n: stats_d.max_abs[n] for n in spec.layer_names},
        frac_bits_weight=8, frac_bits_data=2)
    print("calibrated init policy:")
    print(pol0.table())

    tm = cnn_traffic_model(spec)
    eval_fn = lambda pol: cnn_accuracy(params, jnp.asarray(xv),
                                       jnp.asarray(yv), spec, pol)

    print("\npaper greedy search (slowest gradient descent):")
    res = greedy_pareto_search(eval_fn, tm, pol0, baseline_accuracy=base,
                               batch_size=50)
    print(res.table())

    print("\nbeyond-paper sensitivity-ordered search:")
    res2 = sensitivity_search(eval_fn, tm, pol0, baseline_accuracy=base,
                              batch_size=50, tolerance=0.10)
    print(res2.table())
    print(f"\nevaluations: paper={res.evaluations} "
          f"sensitivity={res2.evaluations} "
          f"({res.evaluations / max(res2.evaluations, 1):.1f}x fewer)")


if __name__ == "__main__":
    main()
