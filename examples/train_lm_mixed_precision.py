"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the paper's per-layer precision applied as a first-class training feature.

Uses launch.train (the production launcher) twice:
  1. fp32-boundary baseline,
  2. per-layer quantized run (10-bit weights / 12-bit data / int8 KV,
     int8 optimizer moments, int8-wire gradient compression),
and compares the loss curves — the quantized run should track the baseline
within a few percent while its boundary tensors carry 3x fewer bits.

~100M params: xlstm-350m reduced to 12 layers, d_model 512
(~97M with the tied embedding), CPU-trainable in minutes.

Run:  PYTHONPATH=src python examples/train_lm_mixed_precision.py [--steps N]
"""
import argparse
import json

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="deepseek-7b")
    args = ap.parse_args()

    common = ["--arch", args.arch, "--smoke", "--steps", str(args.steps),
              "--batch-size", "8", "--seq-len", "256", "--lr", "1e-3",
              "--log-every", "20"]

    print("=== baseline (fp boundaries) ===")
    base = train_mod.main(common)

    print("=== per-layer quantized (W10/D12/KV8 + int8 moments + "
          "int8 grad wire) ===")
    quant = train_mod.main(common + [
        "--weight-bits", "10", "--data-bits", "12", "--kv-bits", "8",
        "--int8-moments", "--grad-compress"])

    b, q = base[-1]["loss"], quant[-1]["loss"]
    print(f"\nfinal loss: baseline={b:.4f} quantized={q:.4f} "
          f"(+{(q - b) / b:+.2%})")
    print("boundary bits: weights 32->10, data 32->12, KV 32->8, "
          "optimizer moments 32->8(+scale), grad wire 32->8")


if __name__ == "__main__":
    main()
