"""Paper Fig. 3: per-layer precision tolerance — vary ONE layer at a time,
all other layers at full precision. The paper's key observation: the minimum
bits per layer varies WITHIN a network (>= a few bits of spread)."""
from __future__ import annotations

import numpy as np

from repro.core.fixedpoint import FixedPointFormat
from repro.core.policy import PrecisionPolicy

from .common import cnn_nets, get_cnn, make_eval_fn, save_json


def sweep_network(net: str, *, verbose=True):
    spec, params, (xv, yv), base = get_cnn(net, verbose=verbose)
    eval_fn = make_eval_fn(spec, params, xv, yv)
    names = spec.layer_names
    fp = PrecisionPolicy.fp32_baseline(names)
    out = {"baseline_accuracy": float(base), "per_layer": {}}

    for li, name in enumerate(names):
        rec = {"weight_frac": {}, "data_int": {}}
        for f in range(0, 9):
            pol = fp.replace_layer(li, fp.layers[li].__class__(
                FixedPointFormat(1, f), None))
            rec["weight_frac"][f] = float(eval_fn(pol))
        for i in range(1, 10):
            pol = fp.replace_layer(li, fp.layers[li].__class__(
                None, FixedPointFormat(i, 8)))
            rec["data_int"][i] = float(eval_fn(pol))

        def min_ok(d):
            t = base * 0.99
            ok = [int(k) for k, v in sorted(d.items(),
                                            key=lambda kv: int(kv[0]))
                  if v >= t]
            return ok[0] if ok else None

        rec["min_weight_frac@1%"] = min_ok(rec["weight_frac"])
        rec["min_data_int@1%"] = min_ok(rec["data_int"])
        out["per_layer"][name] = rec
        if verbose:
            print(f"  {net}/{name}: min W.F={rec['min_weight_frac@1%']} "
                  f"min D.I={rec['min_data_int@1%']}")

    wf = [r["min_weight_frac@1%"] for r in out["per_layer"].values()
          if r["min_weight_frac@1%"] is not None]
    out["weight_bits_spread"] = (max(wf) - min(wf)) if wf else None
    return out


def run(*, verbose=True, nets=None):
    results = {}
    for net in nets or cnn_nets():
        if verbose:
            print(f"[perlayer_sweep] {net}")
        results[net] = sweep_network(net, verbose=verbose)
        if verbose:
            print(f"  spread across layers (weight frac bits): "
                  f"{results[net]['weight_bits_spread']}")
    save_json("perlayer_sweep.json", results)
    return results


if __name__ == "__main__":
    run()
