"""Paper Fig. 5 + Table 2: the greedy per-layer precision search.

Per network: initialize at the <0.1%-error uniform config (from the uniform
sweep), run the paper's slowest-gradient-descent, report the minimum-traffic
config within each error tolerance (1/2/5/10%) and its TR vs the 32-bit
baseline. Also runs the beyond-paper sensitivity-ordered search and reports
the evaluation-count saving."""
from __future__ import annotations

import numpy as np

from repro.core.fixedpoint import FixedPointFormat
from repro.core.policy import PrecisionPolicy
from repro.core.search import greedy_pareto_search, sensitivity_search
from repro.models.cnn import cnn_traffic_model

from .common import cnn_nets, get_cnn, load_json, make_eval_fn, save_json

TOLERANCES = (0.01, 0.02, 0.05, 0.10)

# paper Table 2 TR values at 1% tolerance (32-bit baseline) for reference
PAPER_TR_1PCT = {"lenet": 0.08, "convnet": 0.24, "alexnet": 0.28,
                 "nin": 0.32, "googlenet": 0.36}


def _init_policy(net, names, uniform):
    """Paper step 1: uniform start below 0.1% error, from the Fig 2 data."""
    u = uniform.get(net, {})
    base = u.get("baseline_accuracy", 1.0)

    def pick(d, default):
        t = base * 0.999
        ok = [int(k) for k, v in sorted(d.items(), key=lambda kv: int(kv[0]))
              if v >= t]
        return ok[0] if ok else default

    wf = pick(u.get("weight_frac", {}), 10) + 1  # +1 margin like the paper
    di = pick(u.get("data_int", {}), 10) + 1
    df = pick(u.get("data_frac", {}), 4)
    return PrecisionPolicy.uniform(
        names, FixedPointFormat(1, min(wf, 12)),
        FixedPointFormat(min(di, 12), min(df, 8)))


def search_network(net: str, *, batch=50, verbose=True, uniform=None):
    spec, params, (xv, yv), base = get_cnn(net, verbose=verbose)
    eval_fn = make_eval_fn(spec, params, xv, yv)
    tm = cnn_traffic_model(spec)
    names = spec.layer_names
    uniform = uniform or {}
    init = _init_policy(net, names, uniform)

    # the paper fixes F for the deeper nets to shrink the space
    fields = ("weight_frac", "data_int") if len(names) > 5 else \
        ("weight_frac", "data_int", "data_frac")

    res = greedy_pareto_search(eval_fn, tm, init,
                               baseline_accuracy=float(base),
                               fields=fields, batch_size=batch,
                               mode="batch", verbose=False)
    out = {"baseline_accuracy": float(base),
           "evaluations": res.evaluations,
           "wall_seconds": res.wall_seconds,
           "tolerances": {}}
    for t in TOLERANCES:
        p = res.select(t)
        if p is None:
            continue
        bits = [f"{(lp.weight.total_bits if lp.weight else 32)}."
                f"{(lp.data.total_bits if lp.data else 32)}"
                for lp in p.policy.layers]
        out["tolerances"][f"{t:.0%}"] = {
            "traffic_ratio": p.traffic_ratio,
            "accuracy": p.accuracy,
            "bits_per_layer(W.D)": bits,
        }

    # beyond-paper: sensitivity-ordered search at 10% tolerance
    res2 = sensitivity_search(eval_fn, tm, init,
                              baseline_accuracy=float(base), fields=fields,
                              batch_size=batch, tolerance=0.10)
    p2 = res2.select(0.01)
    out["sensitivity_search"] = {
        "evaluations": res2.evaluations,
        "tr@1%": p2.traffic_ratio if p2 else None,
        "speedup_vs_paper_evals": res.evaluations / max(res2.evaluations, 1),
    }
    out["pareto"] = [{"tr": p.traffic_ratio, "acc": p.accuracy}
                     for p in res.pareto()]
    return out


def run(*, verbose=True, nets=None):
    try:
        uniform = load_json("uniform_sweep.json")
    except FileNotFoundError:
        uniform = {}
    results = {}
    for net in nets or cnn_nets():
        if verbose:
            print(f"[pareto_search] {net} (this is the paper's §2.5 loop)")
        results[net] = search_network(net, verbose=verbose, uniform=uniform)
        if verbose:
            for tol, r in results[net]["tolerances"].items():
                print(f"  tol={tol:4s} TR={r['traffic_ratio']:.3f} "
                      f"acc={r['accuracy']:.4f} "
                      f"bits={'-'.join(r['bits_per_layer(W.D)'])}")
            ss = results[net]["sensitivity_search"]
            print(f"  sensitivity-search: {ss['evaluations']} evals "
                  f"({ss['speedup_vs_paper_evals']:.1f}x fewer), "
                  f"TR@1%={ss['tr@1%'] if ss['tr@1%'] is None else round(ss['tr@1%'], 3)}")
    save_json("pareto_search.json", results)
    return results


if __name__ == "__main__":
    run()
