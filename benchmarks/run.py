"""Benchmark orchestrator: one module per paper table/figure + the roofline.

  uniform_sweep   — paper Fig. 2 (accuracy vs uniform bits, per network)
  perlayer_sweep  — paper Fig. 3 (per-layer tolerance; the key observation)
  traffic         — paper Fig. 4 (single vs batch traffic; + LM analogue)
  traffic_serve   — traffic-at-scale harness: bursty overload trace through
                    the SLO scheduler, --predictor off vs on (goodput gate),
                    async host pager overlap proof (Chrome trace)
  pareto_search   — paper Fig. 5 / Table 2 (greedy search, TR@1/2/5/10%)
  lm_precision    — beyond-paper: same machinery on a transformer LM
  kernel_bench    — Pallas kernels vs oracles + footprint ratios
  paged_serve     — paged vs dense KV-cache serving (tok/s, prefill latency,
                    HBM B/token; also appends a BENCH_serve.json trajectory
                    point at the repo root — the cross-PR perf trend)
  prefix_serve    — shared-prefix page cache workload (hit rate, prefill
                    forwards saved, per-layer-profile at-rest KV bytes,
                    refcount-leak gate); also part of paged_serve's
                    default workload
  overcommit_serve — tiered page store workload: offered pages >> device
                    pool via host offload + SLO preemption + snapshot
                    restart parity (refcount/host-leak gates); also part
                    of paged_serve's default workload
  roofline        — EXPERIMENTS.md §Roofline terms from the dry-run JSONs

``python -m benchmarks.run [--only a,b] [--fast]``
(--fast restricts CNNs to lenet+convnet and shrinks the search budget)
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args(argv)

    import json
    import os

    from . import (kernel_bench, lm_precision, paged_serve, pareto_search,
                   perlayer_sweep, report, roofline, traffic, uniform_sweep)

    nets = ["lenet", "convnet"] if args.fast else None
    stages = {
        "uniform_sweep": lambda: uniform_sweep.run(nets=nets),
        "perlayer_sweep": lambda: perlayer_sweep.run(nets=nets),
        "traffic": traffic.run_accounting,
        "traffic_serve": lambda: traffic.run_serve(fast=args.fast),
        "pareto_search": lambda: pareto_search.run(nets=nets),
        "lm_precision": lambda: lm_precision.run(
            steps=120 if args.fast else 300),
        "kernel_bench": kernel_bench.run,
        "paged_serve": lambda: paged_serve.run(fast=args.fast,
                                               workload="mixed"),
        "prefix_serve": lambda: paged_serve.run(fast=args.fast,
                                                workload="prefix"),
        "overcommit_serve": lambda: paged_serve.run(fast=args.fast,
                                                    workload="overcommit"),
        "roofline": roofline.run,
    }
    # expensive searches reuse their saved results unless --force
    cached = {"uniform_sweep": "uniform_sweep.json",
              "perlayer_sweep": "perlayer_sweep.json",
              "pareto_search": "pareto_search.json",
              "lm_precision": "lm_precision.json"}
    results_dir = os.environ.get("REPRO_RESULTS", "results")
    only = [s for s in args.only.split(",") if s]
    t00 = time.time()
    for name, fn in stages.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n===== {name} =====")
        cpath = cached.get(name)
        if cpath and os.path.exists(os.path.join(results_dir, cpath)) \
                and not getattr(args, "force", False) and not only:
            with open(os.path.join(results_dir, cpath)) as f:
                data = json.load(f)
            print(f"[cached] results/{cpath} "
                  f"(pass --only {name} to recompute). Summary:")
            print(json.dumps(data, indent=1)[:2500])
        else:
            try:
                fn()
            except Exception as e:  # noqa: BLE001 — stage-isolate failures
                import traceback
                traceback.print_exc()
                print(f"[stage {name} FAILED: {e!r} — continuing]")
        print(f"===== {name} done in {time.time() - t0:.0f}s =====")
    print(f"\nall benchmarks done in {time.time() - t00:.0f}s")


if __name__ == "__main__":
    main()
