"""Paged vs dense KV-cache serving bench: tokens/sec, prefill latency, and
HBM bytes per token — the serving hot-path trajectory.

Runs the same mixed-length request trace through several BatchedServer
configurations on a smoke-scale GQA arch:

  dense-fp32        — the seed layout: one (B, max_len) fp32 slab per layer
  paged-int8-step   — page pool, int8 pages, SLOT-GRANULAR prefill (the PR 1
                      hot path: O(prompt_len) whole-batch forwards/request)
  paged-int8        — same pool, BUCKETED chunked prefill (O(prompt/bucket)
                      forwards) — the before/after pair for the prefill work
  paged-int4        — bucketed prefill, 4-bit lane-packed pages
  paged-int8-pallas — bucketed prefill + decode routed through the Pallas
                      paged-attention kernel (interpret-mode on CPU, so CPU
                      tok/s is NOT indicative; the row tracks routing +
                      numerics, the kernel is bench'd on TPU)

and reports, per configuration:

  * decode throughput (generated tokens / wall second),
  * prefill latency (wall seconds of prefill per admitted request) and the
    number of prefill forward-program executions,
  * KV **at-rest bytes per token-slot** — stored cache bytes divided by the
    token capacity they back (~4x smaller for int8, ~8x for int4 vs fp32),
  * total cache HBM actually allocated.

Results land in results/paged_serve.json AND append a trajectory point to
the repo-root BENCH_serve.json so the perf trend is tracked across PRs.

Run:  PYTHONPATH=src python -m benchmarks.paged_serve [--arch qwen2-72b]
      [--page-size 16] [--requests 12] [--fast]
(--fast = CI smoke: tiny trace, one bench iteration per config.)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

from .common import save_json

BENCH_TRAJECTORY = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json")


def _kv_cache_leaves(caches):
    """Yield (kind, array) for attention-cache storage leaves."""
    for seg in caches:
        for layer in seg:
            if isinstance(layer, dict):
                if "k_pages" in layer:
                    for k in ("k_pages", "v_pages", "k_scale", "v_scale"):
                        yield k, layer[k]
                elif "k" in layer and "v" in layer:
                    yield "k", layer["k"]
                    yield "v", layer["v"]


def cache_stats(srv):
    """(stored_bytes, token_capacity) of the serving KV cache.

    For paged pools the reserved scratch page backs no tokens; its (single
    page) share is excluded from the per-token figure but still counted in
    the reported total MiB."""
    total = sum(a.size * a.dtype.itemsize
                for _, a in _kv_cache_leaves(srv.caches))
    if srv.paged:
        P = srv.allocator.num_pages
        return total, total * (P - 1) / P, (P - 1) * srv.page_size
    return total, total, srv.B * srv.max_len


MAX_PROMPT = 13


def mk_requests(vocab, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, MAX_PROMPT + 1, n)
    return [Request(i, rng.integers(0, vocab, L).astype(np.int32), max_new)
            for i, L in enumerate(lens)]


def bench_one(cfg, params, *, name, requests, batch, max_len, kv_bits,
              page_size, num_pages, attn_impl="gather", prefill="auto",
              prefill_bucket=16, warmup=True):
    srv = BatchedServer(cfg, params, batch_size=batch, max_len=max_len,
                        kv_bits=kv_bits, page_size=page_size,
                        num_pages=num_pages, attn_impl=attn_impl,
                        prefill=prefill, prefill_bucket=prefill_bucket)
    if warmup:
        # compile the decode step AND every power-of-two bucket program the
        # trace can hit (prompt lens 3..MAX_PROMPT -> buckets 2..16), so the
        # measured run is execution only
        rng = np.random.default_rng(99)
        reqs = [Request(1000 + i,
                        rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                        2)
                for i, L in enumerate([2, 3, 5, 9, min(13, max_len // 2)])]
        srv.run(reqs)
        srv.prefill_forwards = srv.prefill_tokens = 0
        srv.prefill_s = 0.0
        srv.decode_steps = 0
    reqs = mk_requests(cfg.vocab_size, requests,
                       max_new=srv.max_len // 2, seed=0)
    t0 = time.time()
    srv.run(reqs)
    dt = time.time() - t0
    gen = sum(len(r.out) for r in reqs)
    stored, usable, capacity = cache_stats(srv)
    res = {
        "name": name,
        "kv_bits": kv_bits,
        "page_size": page_size,
        "attn_impl": srv.attn_impl,
        "prefill": srv.prefill_mode,
        "tokens_per_s": gen / max(dt, 1e-9),
        "prefill_forwards": srv.prefill_forwards,
        "prefill_latency_ms": 1e3 * srv.prefill_s / max(len(reqs), 1),
        "prefill_s": srv.prefill_s,
        "kv_bytes_per_token_slot": usable / capacity,
        "kv_cache_mib": stored / 2 ** 20,
        "token_capacity": capacity,
        "wall_s": dt,
    }
    return res


def _append_trajectory(point):
    """BENCH_serve.json accumulates one point per bench run, so the serving
    perf trend is visible across PRs (the driver diffs it)."""
    traj = {"bench": "paged_serve", "trajectory": []}
    if os.path.exists(BENCH_TRAJECTORY):
        try:
            with open(BENCH_TRAJECTORY) as f:
                traj = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
    traj.setdefault("trajectory", []).append(point)
    with open(BENCH_TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)
    return BENCH_TRAJECTORY


def run(*, arch="qwen2-72b", requests=10, batch=4, max_len=64, page_size=16,
        verbose=True, fast=False):
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    if fast:   # CI smoke: one tiny iteration per config, no warmup pass
        requests, batch, max_len, page_size = 2, 2, 32, 8
    # pool sized to the traffic's worst concurrent demand, not batch*max_len:
    # this is the allocation the dense layout cannot shrink
    per_slot = -(-(MAX_PROMPT + max_len // 2) // page_size)
    num_pages = 1 + batch * per_slot
    common = dict(requests=requests, batch=batch, max_len=max_len,
                  warmup=not fast)
    rows = [
        bench_one(cfg, params, name="dense-fp32", kv_bits=0, page_size=0,
                  num_pages=None, **common),
        bench_one(cfg, params, name="paged-int8-step", kv_bits=8,
                  page_size=page_size, num_pages=num_pages,
                  prefill="stepwise", **common),
        bench_one(cfg, params, name="paged-int8", kv_bits=8,
                  page_size=page_size, num_pages=num_pages, **common),
        bench_one(cfg, params, name="paged-int4", kv_bits=4,
                  page_size=page_size, num_pages=num_pages, **common),
        bench_one(cfg, params, name="paged-int8-pallas", kv_bits=8,
                  page_size=page_size, num_pages=num_pages,
                  attn_impl="pallas", **common),
    ]
    base = rows[0]["kv_bytes_per_token_slot"]
    for r in rows:
        r["footprint_reduction_vs_fp32"] = base / r["kv_bytes_per_token_slot"]
    step, bucketed = rows[1], rows[2]
    summary = {
        "prefill_forwards_stepwise": step["prefill_forwards"],
        "prefill_forwards_bucketed": bucketed["prefill_forwards"],
        "prefill_forwards_reduction": (
            step["prefill_forwards"] / max(bucketed["prefill_forwards"], 1)),
        "prefill_latency_ms_stepwise": step["prefill_latency_ms"],
        "prefill_latency_ms_bucketed": bucketed["prefill_latency_ms"],
        "tokens_per_s": {r["name"]: r["tokens_per_s"] for r in rows},
        "kv_bytes_per_token_slot": {r["name"]: r["kv_bytes_per_token_slot"]
                                    for r in rows},
    }
    if verbose:
        print(f"[paged_serve] arch={arch} batch={batch} max_len={max_len} "
              f"page_size={page_size}")
        for r in rows:
            print(f"  {r['name']:17s} {r['tokens_per_s']:8.1f} tok/s  "
                  f"prefill {r['prefill_forwards']:3d} fwd "
                  f"{r['prefill_latency_ms']:7.1f} ms/req  "
                  f"{r['kv_bytes_per_token_slot']:7.1f} B/token-slot "
                  f"({r['footprint_reduction_vs_fp32']:4.1f}x vs fp32)  "
                  f"cache {r['kv_cache_mib']:6.2f} MiB")
        print(f"  prefill forwards: {summary['prefill_forwards_stepwise']} "
              f"(stepwise) -> {summary['prefill_forwards_bucketed']} "
              f"(bucketed), "
              f"{summary['prefill_forwards_reduction']:.1f}x fewer")
    out = {"arch": arch, "batch": batch, "max_len": max_len,
           "page_size": page_size, "rows": rows, "summary": summary}
    save_json("paged_serve.json", out)
    point = {"when": time.strftime("%Y-%m-%d %H:%M:%S"), "arch": arch,
             "fast": fast, "summary": summary}
    path = _append_trajectory(point)
    if verbose:
        print(f"  trajectory point appended to {os.path.basename(path)}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: tiny trace, single iteration per config")
    args = ap.parse_args(argv)
    run(arch=args.arch, requests=args.requests, batch=args.batch,
        max_len=args.max_len, page_size=args.page_size, fast=args.fast)


if __name__ == "__main__":
    main()
