"""Paged vs dense KV-cache serving bench: tokens/sec + HBM bytes per token.

Runs the same mixed-length request trace through three BatchedServer
configurations on a smoke-scale GQA arch:

  dense-fp32   — the seed layout: one (B, max_len) fp32-dtype slab per layer
  paged-int8   — page pool, int8 Q(2,6) pages, per-page scales
  paged-int4   — page pool, 4-bit Q(2,2) grid lane-packed into int32 words

and reports, per configuration:

  * decode throughput (generated tokens / wall second),
  * KV **at-rest bytes per token-slot** — stored cache bytes divided by the
    token capacity they back. This is the paper's footprint ratio made
    concrete at serving time: ~4x smaller for int8, ~8x for int4 vs fp32
    (per-page scales cost <1% at page_size >= 16).
  * total cache HBM actually allocated (paged pools size to --num-pages, so
    memory follows expected live tokens, not batch * max_len).

Run:  PYTHONPATH=src python -m benchmarks.paged_serve [--arch qwen2-72b]
      [--page-size 16] [--requests 12] [--max-new 24]
Results land in results/paged_serve.json (benchmarks.common.save_json).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

from .common import save_json


def _kv_cache_leaves(caches):
    """Yield (kind, array) for attention-cache storage leaves."""
    for seg in caches:
        for layer in seg:
            if isinstance(layer, dict):
                if "k_pages" in layer:
                    for k in ("k_pages", "v_pages", "k_scale", "v_scale"):
                        yield k, layer[k]
                elif "k" in layer and "v" in layer:
                    yield "k", layer["k"]
                    yield "v", layer["v"]


def cache_stats(srv):
    """(stored_bytes, token_capacity) of the serving KV cache.

    For paged pools the reserved scratch page backs no tokens; its (single
    page) share is excluded from the per-token figure but still counted in
    the reported total MiB."""
    total = sum(a.size * a.dtype.itemsize
                for _, a in _kv_cache_leaves(srv.caches))
    if srv.paged:
        P = srv.allocator.num_pages
        return total, total * (P - 1) / P, (P - 1) * srv.page_size
    return total, total, srv.B * srv.max_len


MAX_PROMPT = 13


def mk_requests(vocab, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, MAX_PROMPT + 1, n)
    return [Request(i, rng.integers(0, vocab, L).astype(np.int32), max_new)
            for i, L in enumerate(lens)]


def bench_one(cfg, params, *, name, requests, batch, max_len, kv_bits,
              page_size, num_pages):
    srv = BatchedServer(cfg, params, batch_size=batch, max_len=max_len,
                        kv_bits=kv_bits, page_size=page_size,
                        num_pages=num_pages)
    reqs = mk_requests(cfg.vocab_size, 2, 2, seed=99)   # warmup/compile
    srv.run(reqs)
    reqs = mk_requests(cfg.vocab_size, requests,
                       max_new=srv.max_len // 2, seed=0)
    t0 = time.time()
    srv.run(reqs)
    dt = time.time() - t0
    gen = sum(len(r.out) for r in reqs)
    stored, usable, capacity = cache_stats(srv)
    res = {
        "name": name,
        "kv_bits": kv_bits,
        "page_size": page_size,
        "tokens_per_s": gen / max(dt, 1e-9),
        "kv_bytes_per_token_slot": usable / capacity,
        "kv_cache_mib": stored / 2 ** 20,
        "token_capacity": capacity,
        "wall_s": dt,
    }
    return res


def run(*, arch="qwen2-72b", requests=10, batch=4, max_len=64, page_size=16,
        verbose=True):
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    # pool sized to the traffic's worst concurrent demand, not batch*max_len:
    # this is the allocation the dense layout cannot shrink
    per_slot = -(-(MAX_PROMPT + max_len // 2) // page_size)
    num_pages = 1 + batch * per_slot
    rows = [
        bench_one(cfg, params, name="dense-fp32", requests=requests,
                  batch=batch, max_len=max_len, kv_bits=0, page_size=0,
                  num_pages=None),
        bench_one(cfg, params, name="paged-int8", requests=requests,
                  batch=batch, max_len=max_len, kv_bits=8,
                  page_size=page_size, num_pages=num_pages),
        bench_one(cfg, params, name="paged-int4", requests=requests,
                  batch=batch, max_len=max_len, kv_bits=4,
                  page_size=page_size, num_pages=num_pages),
    ]
    base = rows[0]["kv_bytes_per_token_slot"]
    for r in rows:
        r["footprint_reduction_vs_fp32"] = base / r["kv_bytes_per_token_slot"]
    if verbose:
        print(f"[paged_serve] arch={arch} batch={batch} max_len={max_len} "
              f"page_size={page_size}")
        for r in rows:
            print(f"  {r['name']:11s} {r['tokens_per_s']:8.1f} tok/s  "
                  f"{r['kv_bytes_per_token_slot']:8.1f} B/token-slot "
                  f"({r['footprint_reduction_vs_fp32']:4.1f}x vs fp32)  "
                  f"cache {r['kv_cache_mib']:6.2f} MiB "
                  f"for {r['token_capacity']} token-slots")
    out = {"arch": arch, "batch": batch, "max_len": max_len,
           "page_size": page_size, "rows": rows}
    save_json("paged_serve.json", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args(argv)
    run(arch=args.arch, requests=args.requests, batch=args.batch,
        max_len=args.max_len, page_size=args.page_size)


if __name__ == "__main__":
    main()
