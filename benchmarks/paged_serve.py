"""Paged vs dense KV-cache serving bench: tokens/sec, prefill latency, and
HBM bytes per token — the serving hot-path trajectory.

Runs the same mixed-length request trace through several BatchedServer
configurations on a smoke-scale GQA arch:

  dense-fp32        — the seed layout: one (B, max_len) fp32 slab per layer
  paged-int8-step   — page pool, int8 pages, SLOT-GRANULAR prefill (the PR 1
                      hot path: O(prompt_len) whole-batch forwards/request)
  paged-int8        — same pool, BUCKETED chunked prefill (O(prompt/bucket)
                      forwards) — the before/after pair for the prefill work
  paged-int4        — bucketed prefill, 4-bit lane-packed pages
  paged-int8-pallas — bucketed prefill + decode routed through the Pallas
                      paged-attention kernel (interpret-mode on CPU, so CPU
                      tok/s is NOT indicative; the row tracks routing +
                      numerics, the kernel is bench'd on TPU)

and reports, per configuration:

  * decode throughput (generated tokens / wall second),
  * prefill latency (wall seconds of prefill per admitted request) and the
    number of prefill forward-program executions,
  * KV **at-rest bytes per token-slot** — stored cache bytes divided by the
    token capacity they back (~4x smaller for int8, ~8x for int4 vs fp32),
  * total cache HBM actually allocated.

A second, **shared-prefix workload** (``run_prefix`` / ``--workload
prefix``) serves N requests that share a long system prompt and measures
the prefix page cache: request/token hit rates, prefill forwards with
sharing off vs on (the O(prompt/bucket) -> O(suffix/bucket) admission win),
CoW copies and evictions, and at-rest KV bytes under uniform int8 vs a
mixed per-layer precision profile vs int4. It RAISES on a prefix-cache
refcount leak (allocator end-state check) — the CI bench-smoke gate.

A third, **overcommit workload** (``run_overcommit`` / ``--workload
overcommit``) offers ~2.5x the device pool's page capacity through the
tiered page store: --kv-offload host + --sched slo + preemption. It
reports the device/host byte split (per container), preempt/resume and
demote/promote counts, and prefix hit-rate parity after a simulated
restart (snapshot -> fresh server -> restore). It RAISES on any rejected
waitable request, an unresumed preemption victim, an allocator refcount
leak, or a host-tier page leak — the CI overcommit-smoke gate.

A fourth, **adaptation workload** (``run_adapt`` / ``--workload adapt``)
serves a many-tenant overcommitted trace with --kv-adapt off vs on: with
adaptation on, pool pressure REQUANTIZES cold cached prefix pages one
container step narrower (fp -> int8 -> int4) into a bounded device tier
*before* any host round trip. It gates (RAISES) on >=1 requantization
before the first host demotion, >=2x device-held tokens before the first
round trip vs adapt-off, the lm_precision accuracy gate (>=0.9 token
agreement vs the byte-exact adapt-off reference, zero violations), and
pool/host/tier leak checks — the CI adapt-smoke gate.

A fifth, **ragged fused-step workload** (``run_ragged`` / ``--workload
ragged``) serves a SATURATED shared-prefix backlog (every request queued at
t=0, queue depth >> batch) three ways: sequential admission, batched
admission with prefix-aware wave dedupe (--prefill-batch x --prefix-cache
composition), and ``--fused on`` (ONE ragged variable-length program per
scheduler cycle). It gates (RAISES) on fused running strictly fewer total
program launches than the separate-program path, exactly one launch per
cycle, wave dedupe running strictly fewer prefill forwards than sequential
admission, >=0.9 token agreement for both, and pool leak checks — the CI
ragged-smoke gate.

Results land in results/paged_serve.json (+ results/prefix_serve.json,
results/overcommit_serve.json, results/adapt_serve.json,
results/ragged_serve.json) AND append a trajectory point to the repo-root
BENCH_serve.json so the perf trend is tracked across PRs.

Run:  PYTHONPATH=src python -m benchmarks.paged_serve [--arch qwen2-72b]
      [--page-size 16] [--requests 12] [--fast]
      [--workload all|mixed|prefix|overcommit|adapt|ragged]
(--fast = CI smoke: tiny trace, one bench iteration per config.)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

from .common import RESULTS, save_json

BENCH_TRAJECTORY = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json")


def _export_trace(srv, path):
    """Chrome-trace export guarded on live tracing: with ``metrics="off"``
    the server carries a NullTracer whose export writes nothing (it warns
    and returns None) — skip it explicitly so the bench never advertises
    a trace artifact it did not produce. Returns the written path, or
    None when tracing is disabled."""
    if not srv.tracer.enabled:
        print(f"  [trace] skipped {os.path.basename(path)}: tracing "
              f"disabled (metrics off)")
        return None
    os.makedirs(RESULTS, exist_ok=True)
    return srv.tracer.export_chrome(path)


def _kv_cache_leaves(caches):
    """Yield (kind, array) for attention-cache storage leaves.

    Handles both the stacked (periods, ...) layout and the per-period LIST
    layout the per-layer precision profiles use (mixed containers cannot
    stack)."""
    for seg in caches:
        for entry in seg:
            layers = entry if isinstance(entry, list) else [entry]
            for layer in layers:
                if isinstance(layer, dict):
                    if "k_pages" in layer:
                        for k in ("k_pages", "v_pages", "k_scale", "v_scale"):
                            yield k, layer[k]
                    elif "k" in layer and "v" in layer:
                        yield "k", layer["k"]
                        yield "v", layer["v"]


def cache_stats(srv):
    """(stored_bytes, token_capacity) of the serving KV cache.

    For paged pools the reserved scratch page backs no tokens; its (single
    page) share is excluded from the per-token figure but still counted in
    the reported total MiB."""
    total = sum(a.size * a.dtype.itemsize
                for _, a in _kv_cache_leaves(srv.caches))
    if srv.paged:
        P = srv.allocator.num_pages
        return total, total * (P - 1) / P, (P - 1) * srv.page_size
    return total, total, srv.B * srv.max_len


MAX_PROMPT = 13


def mk_requests(vocab, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, MAX_PROMPT + 1, n)
    return [Request(i, rng.integers(0, vocab, L).astype(np.int32), max_new)
            for i, L in enumerate(lens)]


def bench_one(cfg, params, *, name, requests, batch, max_len, kv_bits,
              page_size, num_pages, attn_impl="gather", prefill="auto",
              prefill_bucket=16, prefill_batch=0, warmup=True):
    srv = BatchedServer(cfg, params, batch_size=batch, max_len=max_len,
                        kv_bits=kv_bits, page_size=page_size,
                        num_pages=num_pages, attn_impl=attn_impl,
                        prefill=prefill, prefill_bucket=prefill_bucket,
                        prefill_batch=prefill_batch)
    if warmup:
        # compile the decode step AND every power-of-two bucket program the
        # trace can hit (prompt lens 3..MAX_PROMPT -> buckets 2..16), so the
        # measured run is execution only
        rng = np.random.default_rng(99)
        reqs = [Request(1000 + i,
                        rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                        2)
                for i, L in enumerate([2, 3, 5, 9, min(13, max_len // 2)])]
        srv.run(reqs)
        # registry-wide zero at the warmup boundary: every serve counter is
        # registry-backed now, so one reset() replaces the old per-counter
        # hand-zeroing (and can't silently miss a newly added counter)
        srv.metrics.reset()
    reqs = mk_requests(cfg.vocab_size, requests,
                       max_new=srv.max_len // 2, seed=0)
    t0 = time.time()
    srv.run(reqs)
    dt = time.time() - t0
    gen = sum(len(r.out) for r in reqs)
    stored, usable, capacity = cache_stats(srv)
    res = {
        "name": name,
        "kv_bits": kv_bits,
        "page_size": page_size,
        "attn_impl": srv.attn_impl,
        "prefill": srv.prefill_mode,
        "tokens_per_s": gen / max(dt, 1e-9),
        "prefill_forwards": srv.prefill_forwards,
        "prefill_latency_ms": 1e3 * srv.prefill_s / max(len(reqs), 1),
        "prefill_s": srv.prefill_s,
        "kv_bytes_per_token_slot": usable / capacity,
        "kv_cache_mib": stored / 2 ** 20,
        "token_capacity": capacity,
        "wall_s": dt,
    }
    return res


def run_batched_prefill(cfg, params, *, requests=8, batch=4, verbose=True,
                        fast=False):
    """Shared-bucket batched-prefill bench: same-length prompts arriving
    together, so every admission cycle surfaces several same-bucket rows.
    Sequential (--prefill-batch 1) vs batched (auto = batch size) admission
    — the multi-request batched prefill win is FEWER prefill forwards at
    equal tokens, which is what TTFT on a real accelerator tracks.

    GATES (RAISES — the CI mixed bench-smoke step): batched must run
    strictly fewer prefill forwards than sequential, and the generated
    token streams must agree (bitwise identity is asserted separately in
    the single-threaded-XLA subprocess test; multithreaded CPU GEMMs can
    flip argmax ties here, hence agreement)."""
    if fast:
        requests, batch = 4, 2
    plen, max_new, max_len, page_size = 11, 8, 64, 8
    per_slot = -(-(plen + max_new) // page_size)
    num_pages = 1 + batch * per_slot

    def mk():
        rng = np.random.default_rng(3)
        return [Request(i, rng.integers(0, cfg.vocab_size, plen)
                        .astype(np.int32), max_new) for i in range(requests)]

    def serve(pb):
        srv = BatchedServer(cfg, params, batch_size=batch, max_len=max_len,
                            page_size=page_size, num_pages=num_pages,
                            kv_bits=8, prefill="bucketed", prefill_bucket=16,
                            prefill_batch=pb)
        t0 = time.time()
        reqs = srv.run(mk())
        return srv, reqs, time.time() - t0

    seq, reqs_seq, _ = serve(1)
    bat, reqs_bat, _ = serve(batch)
    agree = np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                     for a, b in zip(reqs_seq, reqs_bat)])
    if agree < 0.9:
        raise RuntimeError(f"batched prefill broke decode: only {agree:.1%} "
                           f"token agreement with sequential admission")
    if bat.prefill_forwards >= seq.prefill_forwards:
        raise RuntimeError(
            f"batched prefill failed to reduce forwards on the shared-bucket "
            f"trace: {seq.prefill_forwards} sequential vs "
            f"{bat.prefill_forwards} batched")
    res = {
        "requests": requests, "batch": batch, "prompt_len": plen,
        "prefill_forwards_sequential": seq.prefill_forwards,
        "prefill_forwards_batched": bat.prefill_forwards,
        "prefill_forwards_reduction": (seq.prefill_forwards
                                       / max(bat.prefill_forwards, 1)),
        "ttft_ms_sequential": 1e3 * seq.prefill_s / requests,
        "ttft_ms_batched": 1e3 * bat.prefill_s / requests,
        "token_agreement": float(agree),
    }
    if verbose:
        print(f"[batched_prefill] {requests} same-bucket prompts "
              f"(len {plen}, batch={batch}): "
              f"{res['prefill_forwards_sequential']} -> "
              f"{res['prefill_forwards_batched']} prefill forwards "
              f"({res['prefill_forwards_reduction']:.1f}x fewer), "
              f"TTFT {res['ttft_ms_sequential']:.1f} -> "
              f"{res['ttft_ms_batched']:.1f} ms/req, "
              f"agreement {agree:.1%}")
    return res


def _mixed_profile(cfg):
    """Per-layer KV policy with two distinct bit-widths: even layers int8
    Q(2,6), odd layers int4 Q(2,2) — the shape of a core.search output."""
    from repro.core.fixedpoint import FixedPointFormat
    from repro.core.policy import LayerPolicy, PrecisionPolicy
    return PrecisionPolicy(
        tuple(f"layer_{i:03d}" for i in range(cfg.num_layers)),
        tuple(LayerPolicy(None, FixedPointFormat(2, 6 if i % 2 == 0 else 2))
              for i in range(cfg.num_layers)))


def mk_prefix_requests(vocab, n, sys_len, max_new, seed=0):
    """N requests sharing a common system prompt + a short random suffix —
    the multi-user traffic shape the prefix cache exists for."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, sys_len).astype(np.int32)
    return [Request(i, np.concatenate(
                [sys_prompt,
                 rng.integers(0, vocab, int(rng.integers(3, 8)))
                 .astype(np.int32)]), max_new)
            for i in range(n)]


def _kv_at_rest_bytes(srv):
    return sum(a.size * a.dtype.itemsize
               for _, a in _kv_cache_leaves(srv.caches))


def run_prefix(*, arch="qwen2-72b", requests=8, batch=4, verbose=True,
               fast=False):
    """Shared-prefix serving workload: prefix cache on vs off, uniform int8
    vs per-layer profile vs int4.

    Reports the prefix hit rate, prefill forwards saved (the O(prompt) ->
    O(suffix) admission win), and at-rest KV bytes per configuration; the
    run RAISES on a prefix-cache refcount leak (allocator end-state check),
    which is what the CI bench-smoke step gates on."""
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    if fast:
        requests, batch = 4, 2
    # 42 = 5 full pages + 2 tokens into page 6: every hit aliases 5 pages
    # AND copies-on-write the partially shared sixth
    sys_len, page_size, max_new, max_len = 42, 8, 8, 64
    # pool sized for the UNSHARED worst case so on/off see identical pools
    per_slot = -(-(sys_len + 7 + max_new) // page_size)
    num_pages = 1 + batch * per_slot + 2
    mk = lambda: mk_prefix_requests(cfg.vocab_size, requests, sys_len,
                                    max_new, seed=0)
    # prefill_batch pinned to 1: the off-vs-on comparison measures PREFIX
    # SHARING alone (batched admission is the other forward-count axis,
    # measured by run_batched_prefill; auto would batch only the off side)
    common = dict(batch_size=batch, max_len=max_len, page_size=page_size,
                  num_pages=num_pages, prefill_bucket=16, prefill_batch=1)

    def serve(**kw):
        srv = BatchedServer(cfg, params, **common, **kw)
        t0 = time.time()
        reqs = srv.run(mk())
        return srv, reqs, time.time() - t0

    off, reqs_off, dt_off = serve(kv_bits=8, prefix_cache="off")
    on, reqs_on, dt_on = serve(kv_bits=8, prefix_cache="on")
    prof, _, _ = serve(kv_profile=_mixed_profile(cfg), prefix_cache="on")
    pscale, _, _ = serve(kv_bits=8, kv_scale="page", prefix_cache="on")
    int4, _, _ = serve(kv_bits=4, prefix_cache="on")

    agree = np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                     for a, b in zip(reqs_off, reqs_on)])
    if agree < 0.9:
        raise RuntimeError(f"prefix sharing broke decode: only {agree:.1%} "
                           f"token agreement with sharing off")
    stats = on.prefix_cache.stats()
    for tag, srv in [("on", on), ("profile", prof), ("pscale", pscale),
                     ("int4", int4)]:
        leaked = srv.release_prefix_cache()
        if leaked or srv.allocator.num_free != srv.allocator.num_usable:
            raise RuntimeError(
                f"prefix-cache refcount leak in config {tag!r}: {leaked} "
                f"pages still cache-referenced, "
                f"{srv.allocator.num_usable - srv.allocator.num_free} "
                f"pages unreturned after all requests completed")
    bytes_int8 = _kv_at_rest_bytes(on)
    bytes_prof = _kv_at_rest_bytes(prof)
    bytes_int4 = _kv_at_rest_bytes(int4)
    res = {
        "arch": arch, "requests": requests, "batch": batch,
        "sys_prompt_len": sys_len, "page_size": page_size,
        "prefix_hit_rate": stats["hit_rate"],
        "prefix_token_hit_rate": stats["token_hit_rate"],
        "prefix_hit_tokens": stats["hit_tokens"],
        "cow_copies": stats["cow_copies"],
        "evictions": stats["evictions"],
        "prefill_forwards_off": off.prefill_forwards,
        "prefill_forwards_on": on.prefill_forwards,
        "prefill_forwards_saved": on.prefill_forwards_saved,
        "prefill_forwards_reduction": (
            off.prefill_forwards / max(on.prefill_forwards, 1)),
        "prefill_s_off": off.prefill_s,
        "prefill_s_on": on.prefill_s,
        "token_agreement_on_vs_off": float(agree),
        "kv_at_rest_bytes": {"uniform-int8": bytes_int8,
                             "profile-int8/int4": bytes_prof,
                             "uniform-int4": bytes_int4},
        "profile_bytes_vs_int8": bytes_prof / bytes_int8,
        "tokens_per_s_on": sum(len(r.out) for r in reqs_on) / max(dt_on,
                                                                  1e-9),
        "tokens_per_s_off": sum(len(r.out) for r in reqs_off) / max(dt_off,
                                                                    1e-9),
    }
    if verbose:
        print(f"[prefix_serve] arch={arch} {requests} reqs sharing a "
              f"{sys_len}-token system prompt (batch={batch})")
        print(f"  hit rate {res['prefix_hit_rate']:.0%} requests / "
              f"{res['prefix_token_hit_rate']:.0%} prompt tokens; "
              f"{res['cow_copies']} CoW copies, {res['evictions']} evictions")
        print(f"  prefill forwards {res['prefill_forwards_off']} (off) -> "
              f"{res['prefill_forwards_on']} (on), "
              f"{res['prefill_forwards_reduction']:.1f}x fewer "
              f"({res['prefill_forwards_saved']} saved)")
        print(f"  at-rest KV: int8 {bytes_int8 / 2**10:.1f} KiB, "
              f"profile {bytes_prof / 2**10:.1f} KiB "
              f"({res['profile_bytes_vs_int8']:.2f}x), "
              f"int4 {bytes_int4 / 2**10:.1f} KiB")
        print(f"  token agreement on/off {agree:.1%}; no refcount leaks")
    save_json("prefix_serve.json", res)
    return res


def mk_overcommit_requests(vocab, sys_len, *, waves, seed=0):
    """Overcommitted trace in three deterministic waves (decode-step
    arrivals): (1) low-priority long decodes that oversubscribe the pool,
    (2) later high-priority short SLO requests that must PREEMPT, (3) a
    tail re-using the shared system prompt (hits demoted/promoted prefix
    pages). ``waves = (n_long, n_urgent, n_tail)``."""
    from repro.launch.serve import Request
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, sys_len).astype(np.int32)
    n_long, n_urgent, n_tail = waves
    reqs, rid = [], 0

    def add(n, sfx_len, max_new, priority, arrive, deadline=None):
        nonlocal rid
        for _ in range(n):
            prompt = np.concatenate(
                [sys_prompt, rng.integers(0, vocab, sfx_len)
                 .astype(np.int32)])
            reqs.append(Request(rid, prompt, max_new, priority=priority,
                                arrive_step=arrive, deadline_step=deadline))
            rid += 1
    add(n_long, 3, 16, priority=0, arrive=0)
    add(n_urgent, 2, 6, priority=5, arrive=6, deadline=30)
    add(n_tail, 4, 8, priority=1, arrive=18)
    return reqs


def run_overcommit(*, arch="qwen2-72b", verbose=True, fast=False):
    """Overcommit workload: offered page demand ~2.5x the device pool,
    served through the tiered page store (--kv-offload host) with SLO
    scheduling + preemption and a simulated restart.

    Gates (RAISES — the CI bench-smoke step): zero rejected waitable
    requests, every preempted request resumed and completed, prefix
    hit-rate parity after snapshot restore, no allocator refcount leaks,
    and no host-tier page leaks after release."""
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    # fast needs 4 long decodes: 3 no longer oversubscribe the pool since
    # re-aliasing + requant relief landed, so preemption would never fire
    waves = (4, 1, 2) if fast else (4, 2, 3)
    sys_len, page_size, max_len, batch = 21, 8, 64, 3
    # pool sized to ~2 concurrent long requests; the OFFERED demand
    # (waves[0] alone needs waves[0]*5 pages) oversubscribes it ~2.5x
    num_pages = 1 + 11
    mk = lambda: mk_overcommit_requests(cfg.vocab_size, sys_len,
                                        waves=waves, seed=0)
    common = dict(batch_size=batch, max_len=max_len, page_size=page_size,
                  num_pages=num_pages, kv_bits=8, prefix_cache="on",
                  kv_offload="host", sched="slo", metrics="on")

    srv = BatchedServer(cfg, params, **common)
    t0 = time.time()
    reqs = srv.run(mk())
    dt = time.time() - t0
    offered_pages = sum(srv._pages_needed(r) for r in reqs)
    # SLO fields + trace artifact come from the COLD run only: the warm
    # restart pass below re-issues the same rids, which would fold a second
    # incarnation of every request into the goodput denominator
    slo = srv.tracer.slo_summary()
    trace_path = _export_trace(srv,
                               os.path.join(RESULTS,
                                            "trace_overcommit.json"))
    n_events = len(srv.tracer.events)

    # --- gate: a bounded pool served an overcommitted offered load ---
    rejected = [r for r in reqs if r.error is not None]
    if rejected:
        raise RuntimeError(f"overcommit: {len(rejected)} waitable requests "
                           f"rejected with --kv-offload host (expected 0): "
                           f"{[r.rid for r in rejected]}")
    if not all(r.done and len(r.out) > 0 for r in reqs):
        raise RuntimeError("overcommit: not every request completed")
    if srv.preempt_count < 1:
        raise RuntimeError("overcommit trace failed to trigger preemption")
    if srv.resume_count != srv.preempt_count:
        raise RuntimeError(f"preempted {srv.preempt_count} but resumed "
                           f"{srv.resume_count} — a victim never came back")

    # --- preempted streams match an uninterrupted run (agreement: argmax
    # can flip on float ties under multithreaded XLA; the subprocess test
    # in tests/test_scheduler.py asserts bitwise identity) ---
    big = BatchedServer(cfg, params, batch_size=batch, max_len=max_len,
                        page_size=page_size, kv_bits=8)
    reqs_ref = big.run(mk())
    by_rid = {r.rid: r for r in reqs_ref}
    agree = np.mean([np.mean(np.asarray(r.out)
                             == np.asarray(by_rid[r.rid].out))
                     for r in reqs])
    if agree < 0.9:
        raise RuntimeError(f"overcommit decode disagrees with the "
                           f"uninterrupted reference: {agree:.1%}")

    inv = srv.kv_inventory()
    stats = srv.prefix_cache.stats()

    # --- simulated restart: snapshot -> fresh server -> restore ---
    import tempfile
    snap = os.path.join(tempfile.mkdtemp(prefix="kv_snapshot_"),
                        "prefix_pages.npz")
    snap_pages = srv.snapshot_prefix_cache(snap)
    # warm reference: second pass on the ORIGINAL server
    l0, h0 = srv.prefix_cache.lookups, srv.prefix_cache.hits
    srv.run(mk())
    warm_rate = ((srv.prefix_cache.hits - h0)
                 / max(srv.prefix_cache.lookups - l0, 1))
    srv2 = BatchedServer(cfg, params, **common)
    restored = srv2.restore_prefix_cache(snap)
    reqs2 = srv2.run(mk())
    s2 = srv2.prefix_cache.stats()
    if not all(r.done and r.error is None for r in reqs2):
        raise RuntimeError("restored server failed the overcommit trace")
    if s2["hit_rate"] < warm_rate - 0.05:
        raise RuntimeError(
            f"restart hit-rate parity broken: restored {s2['hit_rate']:.0%}"
            f" vs warm {warm_rate:.0%}")

    # --- leak gates: refcounts AND host tier drain to zero ---
    for tag, s in [("primary", srv), ("restored", srv2)]:
        leaked = s.release_prefix_cache()
        if leaked or s.allocator.num_free != s.allocator.num_usable:
            raise RuntimeError(
                f"allocator refcount leak ({tag}): {leaked} cache pages, "
                f"{s.allocator.num_usable - s.allocator.num_free} "
                f"unreturned")
        if s.host_store.num_pages != 0:
            raise RuntimeError(
                f"host-tier page leak ({tag}): {s.host_store.num_pages} "
                f"pages still parked after release")

    res = {
        "arch": arch, "requests": len(reqs), "batch": batch,
        "page_size": page_size, "device_pages": num_pages - 1,
        "offered_pages": offered_pages,
        "overcommit_ratio": offered_pages / (num_pages - 1),
        "completed": len(reqs), "rejected": 0,
        "preemptions": srv.preempt_count, "resumes": srv.resume_count,
        "realias_skipped_demotions": srv.realias_skipped,
        "ooo_admissions": srv.scheduler.ooo_admissions,
        "demotions": stats["demotions"], "promotions": stats["promotions"],
        "host_peak_pages": srv.host_store.peak_pages,
        "host_peak_bytes": srv.host_store.peak_bytes,
        "kv_inventory": inv,
        "prefix_hit_rate_cold": stats["hit_rate"],
        "prefix_hit_rate_warm": warm_rate,
        "prefix_hit_rate_restored": s2["hit_rate"],
        "snapshot_pages": snap_pages, "restored_pages": restored,
        "token_agreement_vs_uninterrupted": float(agree),
        "tokens_per_s": sum(len(r.out) for r in reqs) / max(dt, 1e-9),
        "wall_s": dt,
        # SLO fields computed from the request-lifecycle trace (cold run)
        "goodput": slo["goodput"],
        "deadline_misses": slo["deadline_misses"],
        "ttft_p50_s": slo["ttft_p50_s"], "ttft_p99_s": slo["ttft_p99_s"],
        "tpot_p50_s": slo["tpot_p50_s"], "tpot_p99_s": slo["tpot_p99_s"],
        "trace_path": trace_path, "trace_events": n_events,
    }
    if verbose:
        print(f"[overcommit_serve] arch={arch} offered "
              f"{offered_pages} pages onto a {num_pages - 1}-page pool "
              f"({res['overcommit_ratio']:.1f}x overcommit, batch={batch})")
        print(f"  {len(reqs)} completed / 0 rejected; "
              f"{srv.preempt_count} preemptions (all resumed, "
              f"{res['realias_skipped_demotions']} victim-page demotions "
              f"skipped by re-aliasing), "
              f"{res['ooo_admissions']} out-of-order admissions")
        print(f"  tiers: device {inv['device_bytes'] / 2**10:.1f} KiB "
              f"{inv['device_by_container']} | host peak "
              f"{res['host_peak_pages']} pages "
              f"{res['host_peak_bytes'] / 2**10:.1f} KiB "
              f"({stats['demotions']} demotions, {stats['promotions']} "
              f"promotions)")
        print(f"  restart: {snap_pages} pages snapshotted, {restored} "
              f"restored; hit rate cold {res['prefix_hit_rate_cold']:.0%} "
              f"-> warm {warm_rate:.0%} -> restored {s2['hit_rate']:.0%}")
        print(f"  agreement vs uninterrupted run {agree:.1%}; no leaks")
        print(f"  slo: goodput {res['goodput']:.2f} "
              f"({res['deadline_misses']} deadline misses), ttft p50 "
              f"{1e3 * (res['ttft_p50_s'] or 0):.1f} ms / p99 "
              f"{1e3 * (res['ttft_p99_s'] or 0):.1f} ms, tpot p50 "
              f"{1e3 * (res['tpot_p50_s'] or 0):.2f} ms; {n_events} trace "
              f"events -> {os.path.basename(trace_path)}")
    save_json("overcommit_serve.json", res)
    return res


def mk_adapt_requests(vocab, sys_len, *, groups, per_group, reuse_groups,
                      seed=0):
    """Adaptation trace: ``groups`` tenants, each sharing its OWN system
    prompt across ``per_group`` requests — many distinct cached chains, so
    pool pressure must park cold ones — plus a late second wave re-issuing
    the first ``reuse_groups`` tenants' prompts verbatim (their pages are
    parked in the quant tier by then: the re-hits exercise the LOSSY
    promotion path, which is where the accuracy gate earns its keep)."""
    from repro.launch.serve import Request
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, sys_len).astype(np.int32)
                for _ in range(groups)]
    reqs, rid = [], 0
    wave2 = []
    for g in range(groups):
        for _ in range(per_group):
            prompt = np.concatenate(
                [prefixes[g], rng.integers(0, vocab, 5).astype(np.int32)])
            reqs.append(Request(rid, prompt, 4))
            rid += 1
            if g < reuse_groups:
                wave2.append(prompt)
    for prompt in wave2:
        reqs.append(Request(rid, prompt.copy(), 4, arrive_step=30))
        rid += 1
    return reqs


def run_adapt(*, arch="qwen2-72b", verbose=True, fast=False):
    """Online-precision-adaptation workload (--kv-adapt): the same
    many-tenant overcommitted trace served twice through an identical
    small pool + host tier, adapt OFF (byte-exact demote/drop relief
    only) vs adapt ON (cold cached pages REQUANTIZE one container step
    narrower into the bounded device tier before any host round trip).

    Gates (RAISES — the CI adapt-smoke step):
      * the off run must actually pressure the pool into host demotions
        (otherwise the comparison is vacuous);
      * the adapt run must requantize >= 1 page BEFORE its first host
        demotion (here: absorb the whole trace with ZERO demotions);
      * device-held tokens before the first host round trip must be
        >= 2x the off run's (pool capacity + peak parked tier pages);
      * the lm_precision accuracy gate must pass with ZERO violations:
        >= 0.9 overall token agreement vs the adapt-off reference and no
        single request below the per-request floor (requant error is
        bounded; a garbled request would hide inside a high average)."""
    from .lm_precision import accuracy_gate
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    groups, per_group, reuse = (5, 2, 2) if fast else (7, 2, 2)
    sys_len, page_size, max_len, batch = 8, 4, 64, 2
    num_pages = 1 + 9
    usable = num_pages - 1
    mk = lambda: mk_adapt_requests(cfg.vocab_size, sys_len, groups=groups,
                                   per_group=per_group, reuse_groups=reuse,
                                   seed=0)
    # fp pool: the requant ladder starts at fp -> int8 (deepen reaches
    # int4), so the one-step promotion error stays small; the tier byte
    # budget is 4x the pool, quoted in int4-page equivalents (= 2x the
    # pool in int8-parked pages)
    common = dict(batch_size=batch, max_len=max_len, page_size=page_size,
                  num_pages=num_pages, prefix_cache="on",
                  kv_offload="host", prefill_batch=1)

    def serve(**kw):
        srv = BatchedServer(cfg, params, **common, **kw)
        t0 = time.time()
        reqs = srv.run(mk())
        return srv, reqs, time.time() - t0

    off, reqs_off, dt_off = serve(kv_adapt="off")
    on, reqs_on, dt_on = serve(kv_adapt="on", adapt_pages=4 * usable)
    s_off = off.prefix_cache.stats()
    s_on = on.prefix_cache.stats()

    # --- gate: the trace genuinely overcommits the pool ---
    if s_off["demotions"] < 1:
        raise RuntimeError(
            f"adapt trace failed to pressure the pool: adapt-off run paid "
            f"{s_off['demotions']} host demotions (expected >= 1)")
    # --- gate: requantization relieves pressure BEFORE the host tier ---
    if s_on["requants"] < 1:
        raise RuntimeError("adapt run performed no requantizations")
    if s_on["demotions"] > 0 and not (s_on["requants_at_first_demotion"]
                                      or 0) >= 1:
        raise RuntimeError(
            f"adapt run demoted to host before its first requantization "
            f"(requants_at_first_demotion="
            f"{s_on['requants_at_first_demotion']})")
    # --- gate: >= 2x tokens held on device before the first round trip ---
    tier_peak = on.quant_tier.peak_pages
    tokens_off = usable * page_size
    tokens_on = ((usable + tier_peak) * page_size
                 if s_on["demotions"] == 0 else tokens_off)
    token_ratio = tokens_on / tokens_off
    if token_ratio < 2.0:
        raise RuntimeError(
            f"adaptation held only {token_ratio:.2f}x the off run's tokens "
            f"before the first host round trip (expected >= 2x: pool "
            f"{usable} pages + tier peak {tier_peak}, "
            f"{s_on['demotions']} demotions)")
    # --- gate: accuracy within tolerance (the off run round-trips bytes
    # exactly, so it IS the faithful reference) ---
    # allowed_below_floor: on a random-init smoke model one argmax tie
    # flip fully diverges a 4-token request — a bounded fraction of those
    # is tie chaos (see lm_precision.accuracy_gate), systematic garbling
    # still trips the overall floor
    gate = accuracy_gate([r.out for r in reqs_off],
                         [r.out for r in reqs_on],
                         min_agreement=0.9, request_floor=0.5,
                         allowed_below_floor=0.15)
    if not gate["passed"]:
        raise RuntimeError(
            f"accuracy gate: {gate['violations']} violations "
            f"(overall agreement {gate['agreement']:.1%}, "
            f"per-request min {min(gate['per_request']):.1%})")

    inv = on.kv_inventory()
    # --- leak gates: pool, host tier AND quant tier drain to zero ---
    for tag, s in [("off", off), ("on", on)]:
        leaked = s.release_prefix_cache()
        if leaked or s.allocator.num_free != s.allocator.num_usable:
            raise RuntimeError(
                f"refcount leak (adapt {tag}): {leaked} cache pages, "
                f"{s.allocator.num_usable - s.allocator.num_free} "
                f"unreturned")
        if s.host_store.num_pages != 0:
            raise RuntimeError(f"host-tier leak (adapt {tag}): "
                               f"{s.host_store.num_pages} pages parked")
    if on.quant_tier.num_pages != 0 or on.quant_tier.nbytes != 0:
        raise RuntimeError(
            f"quant-tier leak: {on.quant_tier.num_pages} pages / "
            f"{on.quant_tier.nbytes} bytes still parked after release")

    res = {
        "arch": arch, "requests": len(reqs_on), "batch": batch,
        "page_size": page_size, "device_pages": usable,
        "tenant_groups": groups,
        "requants": s_on["requants"], "deepens": s_on["deepens"],
        "tier_promotions": s_on["tier_promotions"],
        "tier_peak_pages": tier_peak,
        "tier_peak_bytes": on.quant_tier.peak_bytes,
        "requants_at_first_demotion": s_on["requants_at_first_demotion"],
        "demotions_off": s_off["demotions"],
        "demotions_on": s_on["demotions"],
        "evictions_off": s_off["evictions"],
        "evictions_on": s_on["evictions"],
        "tokens_before_host_off": tokens_off,
        "tokens_before_host_on": tokens_on,
        "token_ratio_vs_off": token_ratio,
        "accuracy_gate": {k: gate[k] for k in
                          ("agreement", "violations", "passed")},
        "kv_inventory": inv,
        "tokens_per_s_on": sum(len(r.out) for r in reqs_on) / max(dt_on,
                                                                  1e-9),
        "tokens_per_s_off": sum(len(r.out) for r in reqs_off) / max(dt_off,
                                                                    1e-9),
    }
    if verbose:
        print(f"[adapt_serve] arch={arch} {groups} tenants x {per_group} "
              f"reqs + {reuse * per_group} re-hits onto a {usable}-page "
              f"pool (batch={batch})")
        print(f"  adapt off: {s_off['demotions']} host demotions, "
              f"{s_off['evictions']} destructive evictions")
        print(f"  adapt on: {s_on['requants']} requants "
              f"({s_on['deepens']} deepens, {s_on['tier_promotions']} lossy "
              f"promotions), {s_on['demotions']} host demotions; tier peak "
              f"{tier_peak} pages / {on.quant_tier.peak_bytes / 2**10:.1f} "
              f"KiB {inv['tier_by_container']}")
        print(f"  tokens before first host round trip: {tokens_off} -> "
              f"{tokens_on} ({token_ratio:.1f}x)")
        print(f"  accuracy gate: agreement {gate['agreement']:.1%}, "
              f"{gate['violations']} violations; no leaks")
    save_json("adapt_serve.json", res)
    return res


def run_ragged(*, arch="qwen2-72b", requests=12, batch=4, verbose=True,
               fast=False):
    """Ragged fused-step workload: a SATURATED shared-prefix backlog (all
    requests queued at t=0, queue depth >> batch) served three ways —

      seq — sequential admission (--prefill-batch 1), separate prefill +
            decode programs: the program-count reference
      bat — batched admission (auto cap = batch size) + prefix cache: the
            prefix-aware wave dedupe composition (--prefill-batch no longer
            falls back to sequential under --prefix-cache)
      fus — ``--fused on``: ONE ragged variable-length program per
            scheduler cycle (decode rows S=1 riding in prefill buckets)

    Program-count economics only favor fused under saturation (steady
    decode occupancy + admission folded into decode cycles); drain-phase
    desync can eat the savings on thin traces, which is why this trace
    keeps the queue deep.

    GATES (RAISES — the CI ragged-smoke step): fused must run strictly
    fewer total program launches than the separate-program path at exactly
    one launch per cycle, wave dedupe must run strictly fewer prefill
    forwards than sequential admission, both must hold >=0.9 token
    agreement vs seq (bitwise identity is asserted separately in the
    single-threaded-XLA subprocess test), and the pool must end leak-free.
    """
    if fast:
        requests = 8
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    sys_len, max_new, max_len, page_size = 18, 12 if fast else 16, 64, 8
    per_slot = -(-(sys_len + 8 + max_new) // page_size)
    num_pages = 1 + batch * per_slot + 10   # headroom for retained prefixes

    def mk():
        return mk_prefix_requests(cfg.vocab_size, requests, sys_len,
                                  max_new, seed=5)

    def serve(name, **kw):
        srv = BatchedServer(cfg, params, batch_size=batch, max_len=max_len,
                            page_size=page_size, num_pages=num_pages,
                            kv_bits=8, prefill="bucketed",
                            prefill_bucket=16, prefix_cache="on",
                            metrics="on", **kw)
        t0 = time.time()
        reqs = srv.run(mk())
        dt = time.time() - t0
        assert all(r.done for r in reqs), f"{name}: unfinished requests"
        srv.prefix_cache.clear()
        if srv.allocator.num_free != srv.allocator.num_usable:
            raise RuntimeError(f"ragged bench leaked pages in {name} mode")
        return srv, reqs, dt

    seq, reqs_seq, t_seq = serve("seq", prefill_batch=1, fused="off")
    bat, reqs_bat, _ = serve("bat", prefill_batch=batch, fused="off")
    fus, reqs_fus, t_fus = serve("fus", fused="on")

    def agreement(a_reqs, b_reqs):
        return float(np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                              for a, b in zip(a_reqs, b_reqs)]))

    agree_fus = agreement(reqs_seq, reqs_fus)
    agree_bat = agreement(reqs_seq, reqs_bat)
    if min(agree_fus, agree_bat) < 0.9:
        raise RuntimeError(f"ragged modes broke decode: fused {agree_fus:.1%}"
                           f" / batched {agree_bat:.1%} token agreement")
    if fus.program_launches != fus.cycles:
        raise RuntimeError(
            f"fused serving launched {fus.program_launches} programs over "
            f"{fus.cycles} cycles; the contract is exactly one per cycle")
    if fus.program_launches >= seq.program_launches:
        raise RuntimeError(
            f"fused serving failed to reduce total programs on the "
            f"saturated trace: {seq.program_launches} separate vs "
            f"{fus.program_launches} fused")
    if bat.prefill_forwards >= seq.prefill_forwards:
        raise RuntimeError(
            f"prefix-aware wave dedupe failed to reduce prefill forwards: "
            f"{seq.prefill_forwards} sequential vs "
            f"{bat.prefill_forwards} batched under the prefix cache")
    # SLO reduction from the fused run's lifecycle trace + Chrome artifact;
    # the registry double-checks the one-launch-per-cycle contract through
    # the same counters the gate above read via legacy attributes
    slo = fus.tracer.slo_summary()
    assert (fus.metrics.counter("serve.program_launches").value
            == fus.metrics.counter("serve.cycles").value)
    trace_path = _export_trace(fus,
                               os.path.join(RESULTS, "trace_ragged.json"))
    res = {
        "requests": requests, "batch": batch, "sys_len": sys_len,
        "max_new": max_new,
        "programs_separate": seq.program_launches,
        "programs_fused": fus.program_launches,
        "cycles_fused": fus.cycles,
        "program_reduction": seq.program_launches / fus.program_launches,
        "decode_steps_separate": seq.decode_steps,
        "decode_steps_fused": fus.decode_steps,
        "prefill_forwards_sequential": seq.prefill_forwards,
        "prefill_forwards_batched": bat.prefill_forwards,
        "wave_dedup_pages": bat.wave_dedup_pages + fus.wave_dedup_pages,
        "token_agreement_fused": agree_fus,
        "token_agreement_batched": agree_bat,
        "wall_s_separate": t_seq, "wall_s_fused": t_fus,
        # SLO fields computed from the fused run's lifecycle trace
        "goodput": slo["goodput"],
        "ttft_p50_s": slo["ttft_p50_s"], "ttft_p99_s": slo["ttft_p99_s"],
        "tpot_p50_s": slo["tpot_p50_s"], "tpot_p99_s": slo["tpot_p99_s"],
        "trace_path": trace_path, "trace_events": len(fus.tracer.events),
    }
    if verbose:
        print(f"[ragged] {requests} queued shared-prefix requests "
              f"(batch={batch}): {res['programs_separate']} -> "
              f"{res['programs_fused']} programs "
              f"({res['program_reduction']:.2f}x, one per cycle), "
              f"prefill fwd {res['prefill_forwards_sequential']} -> "
              f"{res['prefill_forwards_batched']} (wave dedupe), "
              f"agreement fused {agree_fus:.1%} / batched {agree_bat:.1%}")
        print(f"  slo (fused): goodput {res['goodput']:.2f}, ttft p50 "
              f"{1e3 * (res['ttft_p50_s'] or 0):.1f} ms / p99 "
              f"{1e3 * (res['ttft_p99_s'] or 0):.1f} ms, tpot p50 "
              f"{1e3 * (res['tpot_p50_s'] or 0):.2f} ms; "
              f"{res['trace_events']} trace events -> "
              f"{os.path.basename(trace_path)}")
    save_json("ragged_serve.json", res)
    return res


def _append_trajectory(point):
    """BENCH_serve.json accumulates one point per bench run, so the serving
    perf trend is visible across PRs (the driver diffs it)."""
    traj = {"bench": "paged_serve", "trajectory": []}
    if os.path.exists(BENCH_TRAJECTORY):
        try:
            with open(BENCH_TRAJECTORY) as f:
                traj = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
    traj.setdefault("trajectory", []).append(point)
    with open(BENCH_TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)
    return BENCH_TRAJECTORY


def run(*, arch="qwen2-72b", requests=10, batch=4, max_len=64, page_size=16,
        verbose=True, fast=False, workload="all"):
    if workload in ("prefix", "overcommit", "adapt", "ragged"):
        fn = {"prefix": run_prefix, "overcommit": run_overcommit,
              "adapt": run_adapt, "ragged": run_ragged}[workload]
        res = fn(arch=arch, verbose=verbose, fast=fast)
        point = {"when": time.strftime("%Y-%m-%d %H:%M:%S"), "arch": arch,
                 "fast": fast, "summary": {workload: res}}
        path = _append_trajectory(point)
        if verbose:
            print(f"  trajectory point appended to {os.path.basename(path)}")
        return res
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    if fast:   # CI smoke: one tiny iteration per config, no warmup pass
        requests, batch, max_len, page_size = 2, 2, 32, 8
    # pool sized to the traffic's worst concurrent demand, not batch*max_len:
    # this is the allocation the dense layout cannot shrink
    per_slot = -(-(MAX_PROMPT + max_len // 2) // page_size)
    num_pages = 1 + batch * per_slot
    common = dict(requests=requests, batch=batch, max_len=max_len,
                  warmup=not fast)
    rows = [
        bench_one(cfg, params, name="dense-fp32", kv_bits=0, page_size=0,
                  num_pages=None, **common),
        bench_one(cfg, params, name="paged-int8-step", kv_bits=8,
                  page_size=page_size, num_pages=num_pages,
                  prefill="stepwise", **common),
        bench_one(cfg, params, name="paged-int8", kv_bits=8,
                  page_size=page_size, num_pages=num_pages, **common),
        bench_one(cfg, params, name="paged-int4", kv_bits=4,
                  page_size=page_size, num_pages=num_pages, **common),
        bench_one(cfg, params, name="paged-int8-pallas", kv_bits=8,
                  page_size=page_size, num_pages=num_pages,
                  attn_impl="pallas", **common),
    ]
    base = rows[0]["kv_bytes_per_token_slot"]
    for r in rows:
        r["footprint_reduction_vs_fp32"] = base / r["kv_bytes_per_token_slot"]
    step, bucketed = rows[1], rows[2]
    summary = {
        "prefill_forwards_stepwise": step["prefill_forwards"],
        "prefill_forwards_bucketed": bucketed["prefill_forwards"],
        "prefill_forwards_reduction": (
            step["prefill_forwards"] / max(bucketed["prefill_forwards"], 1)),
        "prefill_latency_ms_stepwise": step["prefill_latency_ms"],
        "prefill_latency_ms_bucketed": bucketed["prefill_latency_ms"],
        "tokens_per_s": {r["name"]: r["tokens_per_s"] for r in rows},
        "kv_bytes_per_token_slot": {r["name"]: r["kv_bytes_per_token_slot"]
                                    for r in rows},
    }
    # shared-bucket batched-prefill stage: forward counts + TTFT sequential
    # vs batched (RAISES unless batching reduces forwards — the CI gate)
    summary["batched_prefill"] = run_batched_prefill(
        cfg, params, verbose=verbose, fast=fast)
    if verbose:
        print(f"[paged_serve] arch={arch} batch={batch} max_len={max_len} "
              f"page_size={page_size}")
        for r in rows:
            print(f"  {r['name']:17s} {r['tokens_per_s']:8.1f} tok/s  "
                  f"prefill {r['prefill_forwards']:3d} fwd "
                  f"{r['prefill_latency_ms']:7.1f} ms/req  "
                  f"{r['kv_bytes_per_token_slot']:7.1f} B/token-slot "
                  f"({r['footprint_reduction_vs_fp32']:4.1f}x vs fp32)  "
                  f"cache {r['kv_cache_mib']:6.2f} MiB")
        print(f"  prefill forwards: {summary['prefill_forwards_stepwise']} "
              f"(stepwise) -> {summary['prefill_forwards_bucketed']} "
              f"(bucketed), "
              f"{summary['prefill_forwards_reduction']:.1f}x fewer")
    if workload == "all":
        prefix = run_prefix(arch=arch, verbose=verbose, fast=fast)
        summary["prefix"] = {
            k: prefix[k] for k in
            ("prefix_hit_rate", "prefix_token_hit_rate",
             "prefill_forwards_off", "prefill_forwards_on",
             "prefill_forwards_saved", "prefill_forwards_reduction",
             "cow_copies", "evictions", "kv_at_rest_bytes",
             "profile_bytes_vs_int8", "token_agreement_on_vs_off")}
        over = run_overcommit(arch=arch, verbose=verbose, fast=fast)
        summary["overcommit"] = {
            k: over[k] for k in
            ("overcommit_ratio", "completed", "rejected", "preemptions",
             "resumes", "realias_skipped_demotions", "ooo_admissions",
             "demotions", "promotions",
             "host_peak_pages", "kv_inventory",
             "prefix_hit_rate_restored", "prefix_hit_rate_warm",
             "token_agreement_vs_uninterrupted",
             "goodput", "deadline_misses", "ttft_p50_s", "ttft_p99_s",
             "tpot_p50_s", "tpot_p99_s")}
    out = {"arch": arch, "batch": batch, "max_len": max_len,
           "page_size": page_size, "rows": rows, "summary": summary}
    save_json("paged_serve.json", out)
    point = {"when": time.strftime("%Y-%m-%d %H:%M:%S"), "arch": arch,
             "fast": fast, "summary": summary}
    path = _append_trajectory(point)
    if verbose:
        print(f"  trajectory point appended to {os.path.basename(path)}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: tiny trace, single iteration per config")
    ap.add_argument("--workload",
                    choices=["all", "mixed", "prefix", "overcommit",
                             "adapt", "ragged"],
                    default="all",
                    help="mixed = the PR-2 mixed-length trace; prefix = the "
                         "shared-system-prompt trace (prefix cache on/off, "
                         "per-layer profile, refcount-leak gate); "
                         "overcommit = offered pages >> device pool through "
                         "the tiered store (offload + preemption + restart "
                         "parity; refcount/host-leak gates); adapt = the "
                         "online-requantization trace (--kv-adapt on vs "
                         "off: requant-before-demote ordering, >=2x tokens "
                         "before the first host round trip, lm_precision "
                         "accuracy gate); ragged = the saturated "
                         "shared-prefix backlog (--fused on: fewer total "
                         "programs at one launch/cycle + prefill-batch x "
                         "prefix-cache wave dedupe, agreement gates)")
    args = ap.parse_args(argv)
    run(arch=args.arch, requests=args.requests, batch=args.batch,
        max_len=args.max_len, page_size=args.page_size, fast=args.fast,
        workload=args.workload)


if __name__ == "__main__":
    main()
