"""Paper Fig. 2: accuracy vs UNIFORM representation length across networks.

Three sweeps per network (all layers forced to the same format):
  (a) weight fractional bits (I fixed at 1 — weights live in [-1, 1]),
  (b) data integer bits (F fixed generous),
  (c) data fractional bits (I fixed from calibration).
"""
from __future__ import annotations

import numpy as np

from repro.core.fixedpoint import FixedPointFormat
from repro.core.policy import PrecisionPolicy

from .common import cnn_nets, get_cnn, make_eval_fn, save_json


def sweep_network(net: str, *, verbose=True):
    spec, params, (xv, yv), base = get_cnn(net, verbose=verbose)
    eval_fn = make_eval_fn(spec, params, xv, yv)
    names = spec.layer_names
    out = {"baseline_accuracy": float(base), "weight_frac": {},
           "data_int": {}, "data_frac": {}}

    for f in range(0, 11):
        pol = PrecisionPolicy.uniform(names, FixedPointFormat(1, f), None)
        out["weight_frac"][f] = float(eval_fn(pol))
    for i in range(1, 13):
        pol = PrecisionPolicy.uniform(names, None, FixedPointFormat(i, 8))
        out["data_int"][i] = float(eval_fn(pol))
    for f in range(0, 9):
        pol = PrecisionPolicy.uniform(names, None, FixedPointFormat(8, f))
        out["data_frac"][f] = float(eval_fn(pol))

    def min_bits(d, thresh):
        ok = [int(k) for k, v in sorted(d.items(), key=lambda kv: int(kv[0]))
              if v >= thresh]
        return ok[0] if ok else None

    t = base * 0.99
    out["min_weight_frac@1%"] = min_bits(out["weight_frac"], t)
    out["min_data_int@1%"] = min_bits(out["data_int"], t)
    out["min_data_frac@1%"] = min_bits(out["data_frac"], t)
    return out


def run(*, verbose=True, nets=None):
    results = {}
    for net in nets or cnn_nets():
        if verbose:
            print(f"[uniform_sweep] {net}")
        results[net] = sweep_network(net, verbose=verbose)
        if verbose:
            r = results[net]
            print(f"  base={r['baseline_accuracy']:.4f} "
                  f"min W.F@1%={r['min_weight_frac@1%']} "
                  f"min D.I@1%={r['min_data_int@1%']} "
                  f"min D.F@1%={r['min_data_frac@1%']}")
    save_json("uniform_sweep.json", results)
    return results


if __name__ == "__main__":
    run()
