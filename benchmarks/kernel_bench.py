"""Kernel benchmarks: correctness deltas vs oracle + footprint/traffic model.

This container executes Pallas in interpret mode (Python), so WALL TIMES
here characterize the oracle/kernel agreement and the memory model, not TPU
speed. The TPU-side throughput claim is structural: bytes-per-element moved
by each kernel at its BlockSpec tiling, reported as the compression ratio
the paper's formats buy."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import save_json


def _timeit(fn, *args, reps=3):
    fn(*args)  # warm/compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def bench_quant_cast():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024)) * 4
    out = {}
    for (i, f) in [(2, 6), (4, 4), (2, 14), (8, 8)]:
        y = ops.quant_cast(x, i, f)
        yr = ref.quant_cast_ref(x, i, f)
        out[f"Q{i}.{f}"] = {
            "max_err_vs_ref": float(jnp.abs(y - yr).max()),
            "interpret_s": _timeit(ops.quant_cast, x, i, f),
            "hbm_bytes_fp32": x.size * 4 * 2,
            "container_bits": 8 if i + f <= 8 else 16,
        }
    return out


def bench_pack():
    out = {}
    for bits in (2, 4, 8, 16):
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        q = jax.random.randint(jax.random.PRNGKey(1), (2048, 512), lo, hi + 1,
                               jnp.int32)
        w = ops.pack(q, bits)
        rt = ops.unpack(w, bits)
        out[f"{bits}b"] = {
            "roundtrip_exact": bool(jnp.array_equal(q, rt)),
            "footprint_ratio_vs_int32": w.size / q.size,
            "footprint_ratio_vs_fp32": w.size / q.size,
            "interpret_pack_s": _timeit(ops.pack, q, bits),
        }
    return out


def bench_quant_matmul():
    out = {}
    for (m, k, n) in [(256, 1024, 256), (512, 4096, 512)]:
        a = jax.random.normal(jax.random.PRNGKey(2), (m, k), jnp.float32)
        wq = jax.random.randint(jax.random.PRNGKey(3), (k, n), -128, 128,
                                jnp.int32).astype(jnp.int8)
        s = jax.random.uniform(jax.random.PRNGKey(4), (n,), minval=0.001,
                               maxval=0.02)
        y = ops.qmatmul(a, wq, s)
        yr = ref.quant_matmul_ref(a, wq, s)
        rel = float(jnp.abs(y - yr).max() / (jnp.abs(yr).max() + 1e-9))
        out[f"{m}x{k}x{n}"] = {
            "rel_err_vs_ref": rel,
            "weight_hbm_bytes": int(wq.size + n * 4),
            "weight_hbm_bytes_bf16": int(k * n * 2),
            "weight_traffic_ratio": (wq.size + n * 4) / (k * n * 2),
            "interpret_s": _timeit(ops.qmatmul, a, wq, s),
        }
    return out


def bench_kv_attention():
    out = {}
    for (b, h, kv, hd, t) in [(4, 8, 2, 64, 512), (2, 16, 16, 128, 1024)]:
        q = jax.random.normal(jax.random.PRNGKey(5), (b, h, hd))
        k_q = jax.random.randint(jax.random.PRNGKey(6), (b, t, kv, hd), -128,
                                 128, jnp.int32).astype(jnp.int8)
        v_q = jax.random.randint(jax.random.PRNGKey(7), (b, t, kv, hd), -128,
                                 128, jnp.int32).astype(jnp.int8)
        y = ops.kv_attention(q, k_q, v_q, t - 5, int_bits=2, frac_bits=6,
                             block_t=128)
        yr = ref.kv_attention_ref(q, k_q, v_q, 2, 6, t - 5)
        out[f"B{b}H{h}KV{kv}hd{hd}T{t}"] = {
            "max_err_vs_ref": float(jnp.abs(y - yr).max()),
            "cache_bytes_int8": int(k_q.size + v_q.size),
            "cache_bytes_bf16": int((k_q.size + v_q.size) * 2),
            "cache_traffic_ratio": 0.5,
            "interpret_s": _timeit(
                lambda q, k, v: ops.kv_attention(
                    q, k, v, t - 5, int_bits=2, frac_bits=6, block_t=128),
                q, k_q, v_q),
        }
    return out


def bench_paged_prefill_chunk():
    """Prefill-chunk attention: the variable-length paged chunk kernel
    (interpret mode) vs the jnp gather path, S in {8, 32, 128}, fragmented
    page tables, int4/int8/fp containers, per-row starts that straddle page
    boundaries. Errors are vs the dense-gather oracle; ``gather_s`` times a
    jitted gather-path equivalent (the serving reference mode)."""
    out = {}
    B, kv, g, hd, ps = 2, 2, 2, 32, 16
    for S in (8, 32, 128):
        starts = np.array([3, ps - 1], np.int32)[:B]   # straddle boundaries
        NP = -(-int(starts.max() + S) // ps)
        for bits, cont in ((0, "fp"), (8, "int8"), (4, "int4")):
            rng = np.random.default_rng(S * 10 + bits)
            kq, vq, ks, vs, pt = ref.make_fragmented_pool(rng, B, NP, ps,
                                                          kv, hd, bits)
            q = jnp.asarray(rng.normal(size=(B, S, kv * g, hd)), jnp.float32)
            qs = jnp.asarray(starts)
            lens = jnp.asarray(starts + S)
            y = ops.paged_kv_attention_chunk(q, kq, vq, ks, vs, pt, qs, lens,
                                             bits=bits)
            yr = ref.paged_kv_attention_chunk_ref(q, kq, vq, ks, vs, pt, qs,
                                                  lens, bits=bits)
            gather_fn = jax.jit(functools.partial(
                ref.paged_kv_attention_chunk_ref, bits=bits))
            out[f"S{S}-{cont}"] = {
                "max_err_vs_gather": float(jnp.abs(y - yr).max()),
                "pages": int(NP), "page_size": ps, "fragmented": True,
                "pallas_interpret_s": _timeit(
                    lambda q, *a: ops.paged_kv_attention_chunk(
                        q, *a, bits=bits),
                    q, kq, vq, ks, vs, pt, qs, lens, reps=1),
                "gather_s": _timeit(gather_fn, q, kq, vq, ks, vs, pt, qs,
                                    lens, reps=1),
            }
    return out


def bench_fused_step():
    """Ragged fused-cycle attention: ONE chunk launch where a decode row
    (1 real query, padded into the prefill bucket S) rides alongside a
    prefill chunk row, vs TWO separate launches (S=1 decode + S-chunk
    prefill) — the program-count saving ``--fused on`` serving buys every
    scheduler cycle. ``decode_pad_err`` is the gather-path delta between
    the padded decode row's real output and the standalone S=1 launch (the
    fused serving mode's identity contract; 0.0 under deterministic XLA),
    and the kernel errors are the pallas interpret-mode agreement on the
    REAL (non-padding) outputs of the same launch."""
    out = {}
    kv, g, hd, ps = 2, 2, 32, 16
    for S in (8, 32):
        for bits, cont in ((0, "fp"), (8, "int8"), (4, "int4")):
            rng = np.random.default_rng(S * 7 + bits)
            dec_pos = 2 * ps + 3              # decode row: 1 query at pos
            pre_start = ps - 1                # prefill row: straddles pages
            NP = -(-max(dec_pos + 1, pre_start + S) // ps)
            kq, vq, ks, vs, pt = ref.make_fragmented_pool(rng, 2, NP, ps,
                                                          kv, hd, bits)
            q = jnp.asarray(rng.normal(size=(2, S, kv * g, hd)), jnp.float32)
            qs = jnp.asarray(np.array([dec_pos, pre_start], np.int32))
            lens = jnp.asarray(np.array([dec_pos + 1, pre_start + S],
                                        np.int32))
            ref_fn = jax.jit(functools.partial(
                ref.paged_kv_attention_chunk_ref, bits=bits))
            fused = ref_fn(q, kq, vq, ks, vs, pt, qs, lens)
            dec = ref_fn(q[:1, :1], kq, vq, ks, vs, pt[:1], qs[:1],
                         lens[:1])
            pre = ref_fn(q[1:], kq, vq, ks, vs, pt[1:], qs[1:], lens[1:])
            y = ops.paged_kv_attention_chunk(q, kq, vq, ks, vs, pt, qs,
                                             lens, bits=bits)

            def two_launches(q, kq, vq, ks, vs, pt, qs, lens):
                return (ref_fn(q[:1, :1], kq, vq, ks, vs, pt[:1], qs[:1],
                               lens[:1]),
                        ref_fn(q[1:], kq, vq, ks, vs, pt[1:], qs[1:],
                               lens[1:]))

            out[f"S{S}-{cont}"] = {
                "decode_pad_err": float(
                    jnp.abs(fused[0, 0] - dec[0, 0]).max()),
                "prefill_row_err": float(jnp.abs(fused[1] - pre[0]).max()),
                "max_err_vs_gather": float(jnp.maximum(
                    jnp.abs(y[0, 0] - fused[0, 0]).max(),
                    jnp.abs(y[1] - fused[1]).max())),
                "launches_per_cycle_fused": 1,
                "launches_per_cycle_separate": 2,
                "fused_1launch_s": _timeit(ref_fn, q, kq, vq, ks, vs, pt,
                                           qs, lens, reps=1),
                "separate_2launch_s": _timeit(two_launches, q, kq, vq, ks,
                                              vs, pt, qs, lens, reps=1),
            }
    return out


def bench_paged_decode_gap():
    """Decode-step attention gap: the pallas paged chunk kernel at S=1
    (the shape every scheduler cycle issues per decode row) vs the jitted
    jnp gather path, on IDENTICAL fragmented page tables. The tuning
    lever is ``block_kv=True``: whole (ps, KV, hdw) pages per DMA and a
    (B, nq, NP) grid — KVx fewer grid steps and page fetches than the
    per-head default, same math (``blocked_vs_default_err`` is float-ULP
    noise, exact 0.0 for fp pages). Interpret-mode wall time tracks
    grid-step count, so the blocked variant's speedup here mirrors the
    TPU-side DMA-descriptor saving; ``grid_steps_*`` is the structural
    claim."""
    out = {}
    B, kv, g, hd, ps = 2, 2, 2, 32, 16
    for ctx in (64, 256):
        NP = -(-ctx // ps)
        for bits, cont in ((0, "fp"), (8, "int8"), (4, "int4")):
            rng = np.random.default_rng(ctx + bits)
            kq, vq, ks, vs, pt = ref.make_fragmented_pool(rng, B, NP, ps,
                                                          kv, hd, bits)
            q = jnp.asarray(rng.normal(size=(B, 1, kv * g, hd)), jnp.float32)
            qs = jnp.asarray(np.full((B,), ctx - 1, np.int32))
            lens = jnp.asarray(np.full((B,), ctx, np.int32))
            y = ops.paged_kv_attention_chunk(q, kq, vq, ks, vs, pt, qs,
                                             lens, bits=bits)
            yb = ops.paged_kv_attention_chunk(q, kq, vq, ks, vs, pt, qs,
                                              lens, bits=bits, block_kv=True)
            yr = ref.paged_kv_attention_chunk_ref(q, kq, vq, ks, vs, pt, qs,
                                                  lens, bits=bits)
            gather_fn = jax.jit(functools.partial(
                ref.paged_kv_attention_chunk_ref, bits=bits))
            out[f"ctx{ctx}-{cont}"] = {
                "max_err_vs_gather": float(jnp.abs(y - yr).max()),
                "blocked_vs_default_err": float(jnp.abs(yb - y).max()),
                "pages": int(NP), "page_size": ps, "fragmented": True,
                "grid_steps_default": int(B * kv * NP),
                "grid_steps_blocked": int(B * NP),
                "page_fetches_default": int(B * kv * NP * 2),
                "page_fetches_blocked": int(B * NP * 2),
                "gather_s": _timeit(gather_fn, q, kq, vq, ks, vs, pt, qs,
                                    lens, reps=3),
                "pallas_default_s": _timeit(
                    lambda q, *a: ops.paged_kv_attention_chunk(
                        q, *a, bits=bits),
                    q, kq, vq, ks, vs, pt, qs, lens, reps=3),
                "pallas_blocked_s": _timeit(
                    lambda q, *a: ops.paged_kv_attention_chunk(
                        q, *a, bits=bits, block_kv=True),
                    q, kq, vq, ks, vs, pt, qs, lens, reps=3),
            }
    return out


_STAGES = {
    "quant_cast": bench_quant_cast,
    "pack": bench_pack,
    "quant_matmul": bench_quant_matmul,
    "kv_attention": bench_kv_attention,
    "paged_prefill_chunk": bench_paged_prefill_chunk,
    "fused_step": bench_fused_step,
    "paged_decode_gap": bench_paged_decode_gap,
}


def run(*, verbose=True, only=None):
    res = {name: fn() for name, fn in _STAGES.items()
           if only is None or name in only}
    if verbose:
        print("[kernel_bench]")
        for kname, rows in res.items():
            for cfg, r in rows.items():
                err = r.get("max_err_vs_ref",
                            r.get("max_err_vs_gather",
                                  r.get("rel_err_vs_ref",
                                        r.get("roundtrip_exact"))))
                print(f"  {kname:19s} {cfg:18s} err/ok={err} ")
    save_json("kernel_bench.json" if only is None
              else f"kernel_bench_{'_'.join(sorted(only))}.json", res)
    if "paged_decode_gap" in res:
        # land the decode-gap numbers on the serving trend the driver diffs
        import time as _time

        from .paged_serve import _append_trajectory
        rows = res["paged_decode_gap"]
        speedups = [r["pallas_default_s"] / r["pallas_blocked_s"]
                    for r in rows.values() if r["pallas_blocked_s"] > 0]
        point = {"when": _time.strftime("%Y-%m-%d %H:%M:%S"),
                 "arch": "kernel", "fast": False,
                 "summary": {"decode_gap": {
                     "configs": len(rows),
                     "blocked_vs_default_err_max": max(
                         r["blocked_vs_default_err"] for r in rows.values()),
                     "max_err_vs_gather": max(
                         r["max_err_vs_gather"] for r in rows.values()),
                     "grid_step_ratio": rows[next(iter(rows))][
                         "grid_steps_default"] / rows[next(iter(rows))][
                         "grid_steps_blocked"],
                     "blocked_speedup_geomean": float(
                         np.exp(np.mean(np.log(speedups)))),
                 }}}
        path = _append_trajectory(point)
        if verbose:
            print(f"  decode-gap point appended to {path.rsplit('/', 1)[-1]}")
    return res


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help=f"comma list of stages ({','.join(_STAGES)})")
    args = ap.parse_args(argv)
    only = [s for s in args.only.split(",") if s] or None
    if only:
        unknown = set(only) - set(_STAGES)
        if unknown:
            raise SystemExit(f"unknown kernel_bench stages: {unknown}")
    run(only=only)


if __name__ == "__main__":
    main()
