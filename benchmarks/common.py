"""Shared benchmark substrate: train-and-cache the paper's CNNs, build
accuracy eval_fns, result IO.

The paper evaluates pretrained zoo models; this container is offline, so
each network is trained once on its procedural dataset (data.synthetic) and
cached under results/cnn/ — every benchmark then measures accuracy-vs-
precision exactly like the paper does (Top-1, fixed eval set).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import digits_dataset, shapes32_dataset
from repro.models.cnn import (ALEXNET_SMALL, CONVNET, LENET, SPECS,
                              cnn_accuracy, cnn_loss, cnn_traffic_model,
                              init_cnn)

RESULTS = os.environ.get("REPRO_RESULTS", "results")

_DATASETS = {
    "lenet": (digits_dataset, 28),
    "convnet": (shapes32_dataset, 32),
    "alexnet_small": (shapes32_dataset, 32),
}

_TRAIN_STEPS = {"lenet": 400, "convnet": 700, "alexnet_small": 900}
_LR = {"lenet": 0.05, "convnet": 0.03, "alexnet_small": 0.02}


def save_json(name: str, obj):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


def load_json(name: str):
    with open(os.path.join(RESULTS, name)) as f:
        return json.load(f)


def _params_path(net: str) -> str:
    return os.path.join(RESULTS, "cnn", f"{net}.npz")


def train_cnn(net: str, *, steps=None, verbose=True):
    spec = SPECS[net]
    make, _ = _DATASETS[net]
    steps = steps or _TRAIN_STEPS[net]
    xs, ys = make(4096, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), spec)
    lr = _LR[net]
    grad = jax.jit(jax.value_and_grad(lambda p, b: cnn_loss(p, b, spec)))
    # SGD + momentum
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)
    t0 = time.time()
    n = (len(xs) // 64) * 64
    for i in range(steps):
        sl = slice((i * 64) % n, (i * 64) % n + 64)
        loss, g = grad(params, {"image": jnp.asarray(xs[sl]),
                                "label": jnp.asarray(ys[sl])})
        mom = jax.tree_util.tree_map(lambda m, g: 0.9 * m + g, mom, g)
        params = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, mom)
        if verbose and i % 100 == 0:
            print(f"  [{net}] step {i} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)")
    return params


def get_cnn(net: str, *, retrain=False, verbose=True):
    """Returns (spec, params, eval set (x, y), baseline accuracy)."""
    spec = SPECS[net]
    make, _ = _DATASETS[net]
    xv, yv = make(1024, seed=99)
    xv, yv = jnp.asarray(xv), jnp.asarray(yv)
    path = _params_path(net)
    if os.path.exists(path) and not retrain:
        npz = np.load(path)
        params = {l.name: {"w": jnp.asarray(npz[f"{l.name}_w"]),
                           "b": jnp.asarray(npz[f"{l.name}_b"])}
                  for l in spec.layers}
    else:
        params = train_cnn(net, verbose=verbose)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        np.savez(path, **{f"{k}_{kk}": np.asarray(v)
                          for k, d in params.items() for kk, v in d.items()})
    base_acc = cnn_accuracy(params, xv, yv, spec)
    return spec, params, (xv, yv), base_acc


def make_eval_fn(spec, params, xv, yv):
    """policy -> top-1 accuracy (the search's eval_fn), jit-cached by the
    distinct (I, F) tuple signature."""
    def eval_fn(policy):
        return cnn_accuracy(params, xv, yv, spec, policy)
    return eval_fn


def cnn_nets():
    return ["lenet", "convnet", "alexnet_small"]
