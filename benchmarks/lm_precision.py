"""Beyond-paper bridge: the SAME per-layer precision machinery applied to a
transformer LM (reduced config, trained here on the synthetic Markov corpus).

Accuracy metric = held-out next-token top-1 (the LM analogue of the paper's
classification top-1). The search descends per-layer weight/data bits with
the transformer traffic model pricing decode traffic — the modern case where
"data" (KV cache) dominates (paper §2.4's batch regime)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.search import greedy_pareto_search
from repro.data.lm import LMDataConfig, lm_batch, lm_eval_stream
from repro.models.transformer import forward, init_model, train_loss
from repro.quant.apply import (build_model_quant, transformer_layer_names,
                               transformer_traffic_model)

from .common import save_json


def train_small_lm(cfg, dcfg, steps=300, lr=1e-3, verbose=True):
    params = init_model(jax.random.PRNGKey(0), cfg)
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    acfg = AdamWConfig(weight_decay=0.01)
    state = adamw_init(params, acfg)

    @jax.jit
    def step(params, state, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: train_loss(p, batch, cfg), has_aux=True)(params)
        params, state, _ = adamw_update(params, g, state, lr, acfg)
        return params, state, loss

    for i in range(steps):
        params, state, loss = step(params, state, lm_batch(dcfg, i))
        if verbose and i % 100 == 0:
            print(f"  [lm] step {i} loss {float(loss):.4f}")
    return params


def decode_agreement(ref_outs, test_outs):
    """Token agreement between two decode runs of the SAME requests.

    ``ref_outs``/``test_outs`` are parallel lists of generated-token
    sequences (e.g. ``[r.out for r in requests]`` from launch.serve).
    Returns per-request agreement plus the token-weighted overall rate —
    the serving analogue of next-token top-1 against a reference run
    (the paper's accuracy-vs-precision gate, §2.3, applied to online
    requantization instead of a static policy)."""
    per_request, hits, total = [], 0, 0
    for ref, test in zip(ref_outs, test_outs):
        ref, test = np.asarray(ref), np.asarray(test)
        n = min(len(ref), len(test))
        h = int(np.sum(ref[:n] == test[:n]))
        per_request.append(h / max(n, 1))
        hits += h
        total += n
    return {"overall": hits / max(total, 1), "per_request": per_request}


def accuracy_gate(ref_outs, test_outs, *, min_agreement=0.9,
                  request_floor=0.5, allowed_below_floor=0.0):
    """Gate a reduced-precision decode run against its reference: overall
    token agreement must reach ``min_agreement`` AND at most an
    ``allowed_below_floor`` fraction of requests may fall below
    ``request_floor`` (an average hiding garbled requests is not within
    tolerance). The allowance exists because near-uniform logits — e.g. a
    random-init smoke model — can flip an argmax tie under ANY bounded KV
    perturbation and a short request then diverges completely; that is
    tie chaos, not garbling, so a bounded fraction is tolerated while a
    systematic failure still trips the gate. Returns the agreement stats
    with a ``violations`` count — 0 means the gate passed."""
    agg = decode_agreement(ref_outs, test_outs)
    below = sum(1 for a in agg["per_request"] if a < request_floor)
    allowance = int(allowed_below_floor * len(agg["per_request"]))
    violations = max(0, below - allowance)
    if agg["overall"] < min_agreement:
        violations += 1
    return {"agreement": agg["overall"],
            "per_request": agg["per_request"],
            "min_agreement": min_agreement,
            "request_floor": request_floor,
            "below_floor": below,
            "allowed_below_floor": allowance,
            "violations": violations,
            "passed": violations == 0}


def lm_topk_accuracy(params, cfg, dcfg, quant=None, batches=2):
    hits = tot = 0
    for b in lm_eval_stream(dcfg, batches):
        _, logits, _, _ = forward(params, {"tokens": b["tokens"]}, cfg,
                                  quant=quant)
        pred = jnp.argmax(logits[:, :-1], -1)
        lab = b["labels"][:, :-1]
        hits += int(jnp.sum(pred == lab))
        tot += lab.size
    return hits / tot


def run(*, verbose=True, arch="deepseek-7b", steps=200):
    cfg = dataclasses.replace(get_smoke_config(arch), num_layers=4,
                              dtype="float32")
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=96, batch_size=12,
                        num_mixtures=2, branching=8, seed=11)
    if verbose:
        print(f"[lm_precision] training reduced {arch} LM "
              f"({cfg.num_layers}L d={cfg.d_model})")
    params = train_small_lm(cfg, dcfg, steps=steps, verbose=verbose)
    base = lm_topk_accuracy(params, cfg, dcfg)
    if verbose:
        print(f"  baseline next-token top-1: {base:.4f}")

    names = transformer_layer_names(cfg)
    from repro.core.fixedpoint import FixedPointFormat
    from repro.core.policy import PrecisionPolicy
    init = PrecisionPolicy.uniform(names, FixedPointFormat(2, 10),
                                   FixedPointFormat(6, 6))
    tm = transformer_traffic_model(cfg, batch=16, seq_len=2048, mode="decode")

    def eval_fn(policy):
        quant = build_model_quant(policy, cfg, quantize_kv=False)
        return lm_topk_accuracy(params, cfg, dcfg, quant=quant, batches=1)

    res = greedy_pareto_search(eval_fn, tm, init, baseline_accuracy=base,
                               fields=("weight_frac", "data_int",
                                       "data_frac"),
                               max_steps=16, stop_rel_acc=0.15)
    out = {"arch": arch, "baseline_topk1": base,
           "evaluations": res.evaluations, "tolerances": {}}
    for t in (0.01, 0.02, 0.05, 0.10):
        p = res.select(t)
        if p:
            out["tolerances"][f"{t:.0%}"] = {
                "traffic_ratio": p.traffic_ratio, "accuracy": p.accuracy,
                "policy": p.policy.short()}
            if verbose:
                print(f"  tol={t:.0%} TR={p.traffic_ratio:.3f} "
                      f"acc={p.accuracy:.4f}")
    # per-layer variance exists in the chosen config (paper's key result,
    # now on a transformer)
    p1 = res.select(0.05)
    if p1:
        wbits = [lp.weight.total_bits for lp in p1.policy.layers if lp.weight]
        out["weight_bits_spread"] = max(wbits) - min(wbits) if wbits else 0
    save_json("lm_precision.json", out)
    return out


if __name__ == "__main__":
    run()
