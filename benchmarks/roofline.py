"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all PER-DEVICE-PER-STEP seconds:

  compute    = HLO_FLOPs_dev / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes_dev / HBM_bw              (819 GB/s)
  collective = wire_bytes_dev / link_bw            (~50 GB/s/link ICI)

HLO_FLOPs/bytes come from the loop-aware HLO cost model (launch.hlo_cost —
XLA's cost_analysis counts while bodies once and is reported alongside for
reference). wire_bytes uses ring-model factors per collective.

MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (inference);
the ratio MODEL_FLOPS / (HLO_FLOPs_dev * n_dev) exposes remat/redundancy.

Memory-fit: CPU dry-runs cannot alias donated buffers (XLA:CPU lacks
donation), so argument+temp double-counts the donated train state / decode
cache; ``fit_bytes`` subtracts the donated argument estimate back out.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s/link (ICI)
HBM_BYTES = 16 * 2**30       # v5e HBM per chip

RESULTS = os.environ.get("REPRO_RESULTS", "results")


def _batch_arg_bytes(rec):
    m = rec["model"]
    ndev_batch = min(m["global_batch"],
                     32 if rec["mesh"] == "multi" else 16)
    if m["kind"] == "train":
        per = m["global_batch"] * m["seq_len"] * 8  # tokens+labels int32
    elif m["kind"] == "prefill":
        per = m["global_batch"] * m["seq_len"] * 4
    else:
        per = m["global_batch"] * 8
    return per / max(ndev_batch, 1)


def analyze_record(rec) -> dict:
    n_dev = 512 if rec["mesh"] == "multi" else 256
    lc = rec["loop_cost"]
    mem = rec.get("memory", {})
    m = rec["model"]

    compute_s = lc["flops"] / PEAK_FLOPS
    memory_s = lc["hbm_bytes"] / HBM_BW
    coll_s = lc["wire_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # MFU-style roofline fraction: useful model flops over the time the
    # dominant term implies, against the compute peak
    model_flops_dev = m["model_flops"] / n_dev
    roofline_frac = (model_flops_dev / PEAK_FLOPS) / bound if bound else 0.0

    args = mem.get("argument_size_in_bytes", 0)
    temp = mem.get("temp_size_in_bytes", 0)
    donated = 0
    if m["kind"] == "train":
        donated = max(args - _batch_arg_bytes(rec), 0)   # the train state
    elif m["kind"] == "decode":
        # caches are donated; params are not
        param_bytes = 2 * m["n_params"] / n_dev
        donated = max(args - param_bytes - _batch_arg_bytes(rec), 0)
    fit_bytes = args + temp - donated
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "step_time_lower_bound_s": bound,
        "model_flops": m["model_flops"],
        "hlo_flops_dev": lc["flops"],
        "useful_flops_ratio": model_flops_dev / lc["flops"]
        if lc["flops"] else 0.0,
        "roofline_fraction": roofline_frac,
        "fit_bytes": fit_bytes, "fits_hbm": bool(fit_bytes <= HBM_BYTES),
        "arg_bytes": args, "temp_bytes": temp,
        "collective_breakdown": lc.get("collectives", {}),
        "compile_s": rec.get("compile_s"),
    }


_SUGGEST = {
    "compute": "compute-bound: raise MXU utilization (bigger per-device "
               "tiles, fewer remat recomputes) or accept — this is the "
               "healthy regime",
    "memory": "HBM-bound: cut bytes/step — lower-precision residents "
              "(paper's per-layer bits / int8 KV), better fusion, larger "
              "arithmetic intensity per pass",
    "collective": "ICI-bound: reshard to cut all-gather/all-reduce volume, "
                  "overlap collectives with compute, or quantize the wire "
                  "format (int8 dispatch / grad compression)",
}


def load_all(tag="baseline"):
    recs = []
    for path in sorted(glob.glob(
            os.path.join(RESULTS, "dryrun", tag, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("skipped") and "loop_cost" in rec:
            recs.append(analyze_record(rec))
    return recs


def table(recs, *, mesh="single") -> str:
    rows = [f"| arch | shape | compute s | memory s | collective s | "
            f"dominant | roofline frac | useful/HLO flops | fits 16G |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(rows)


def run(*, verbose=True, tag="baseline"):
    recs = load_all(tag)
    if not recs:
        if verbose:
            print("[roofline] no dry-run records found; run "
                  "python -m repro.launch.dryrun first")
        return []
    out = {"records": recs,
           "suggestions": {r["arch"] + "/" + r["shape"]:
                           _SUGGEST[r["dominant"]]
                           for r in recs if r["mesh"] == "single"}}
    with open(os.path.join(RESULTS, f"roofline_{tag}.json"), "w") as f:
        json.dump(out, f, indent=1)
    if verbose:
        print(f"[roofline] single-pod table (tag={tag}):")
        print(table(recs, mesh="single"))
    return recs


if __name__ == "__main__":
    import sys
    run(tag=sys.argv[1] if len(sys.argv) > 1 else "baseline")
