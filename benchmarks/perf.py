"""§Perf hillclimb tooling: diff dry-run variants + append to the log.

Workflow per iteration (EXPERIMENTS.md §Perf):
  1. baseline cell exists under results/dryrun/baseline/
  2. run the candidate: ``python -m repro.launch.dryrun --arch A --shape S
     --mesh single --tag <variant> [--set field=value] [--kv-bits N]``
  3. ``python -m benchmarks.perf diff A S <variant>`` prints the term deltas
  4. ``python -m benchmarks.perf log ...`` appends hypothesis/verdict to
     results/perf_log.json (rendered into EXPERIMENTS.md by benchmarks.report)
"""
from __future__ import annotations

import argparse
import json
import os

from .roofline import analyze_record

RESULTS = os.environ.get("REPRO_RESULTS", "results")


def load_cell(arch, shape, tag="baseline", mesh="single"):
    path = os.path.join(RESULTS, "dryrun", tag,
                        f"{mesh}_{arch}_{shape}.json")
    with open(path) as f:
        return json.load(f)


def terms(rec):
    a = analyze_record(rec)
    return {k: a[k] for k in ("compute_s", "memory_s", "collective_s",
                              "dominant", "roofline_fraction", "fit_bytes")}


def diff(arch, shape, tag, base_tag="baseline", mesh="single"):
    b = terms(load_cell(arch, shape, base_tag, mesh))
    v = terms(load_cell(arch, shape, tag, mesh))
    print(f"{arch}/{shape} [{base_tag} -> {tag}]")
    for k in ("compute_s", "memory_s", "collective_s"):
        delta = (v[k] - b[k]) / b[k] if b[k] else float("inf")
        print(f"  {k:14s} {b[k]:10.3f} -> {v[k]:10.3f}  ({delta:+.1%})")
    print(f"  dominant       {b['dominant']} -> {v['dominant']}")
    print(f"  roofline frac  {b['roofline_fraction']:.3f} -> "
          f"{v['roofline_fraction']:.3f}")
    print(f"  fit GiB        {b['fit_bytes'] / 2**30:.1f} -> "
          f"{v['fit_bytes'] / 2**30:.1f}")
    return b, v


def log_entry(**e):
    path = os.path.join(RESULTS, "perf_log.json")
    log = []
    if os.path.exists(path):
        with open(path) as f:
            log = json.load(f)
    log.append(e)
    with open(path, "w") as f:
        json.dump(log, f, indent=1)
    print(f"logged iteration {e.get('iter')} for "
          f"{e.get('arch')}/{e.get('shape')}")


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diff")
    for a in ("arch", "shape", "tag"):
        d.add_argument(a)
    d.add_argument("--base", default="baseline")
    d.add_argument("--mesh", default="single")
    args = ap.parse_args()
    if args.cmd == "diff":
        diff(args.arch, args.shape, args.tag, args.base, args.mesh)


if __name__ == "__main__":
    main()
