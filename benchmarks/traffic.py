"""Paper Fig. 4: data traffic accounting — single-image vs batch use cases,
weights vs intermediate data, per network. Extended beyond the paper with
the transformer analogue: prefill (weight-dominated) vs decode (KV-data-
dominated) per assigned LM arch."""
from __future__ import annotations

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.cnn import SPECS, cnn_traffic_model
from repro.quant.apply import transformer_traffic_model

from .common import cnn_nets, save_json


def cnn_traffic(batch=50):
    out = {}
    for net in cnn_nets():
        tm = cnn_traffic_model(SPECS[net])
        w_s, d_s = tm.accesses(batch, "single")
        w_b, d_b = tm.accesses(batch, "batch")
        out[net] = {
            "single": {"weights_M": w_s / 1e6, "data_M": d_s / 1e6},
            "batch": {"weights_M": w_b / 1e6, "data_M": d_b / 1e6},
            "weights_dominate_single": bool(w_s > d_s),
            "data_dominate_batch": bool(d_b > w_b),
        }
    return out


def lm_traffic():
    """Prefill vs decode access counts for the LM archs (per device-step,
    analytic)."""
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        tm_p = transformer_traffic_model(cfg, batch=32, seq_len=32768,
                                         mode="prefill")
        w_p, d_p = tm_p.accesses(1, "batch")
        if cfg.family != "encoder":
            tm_d = transformer_traffic_model(cfg, batch=128, seq_len=32768,
                                             mode="decode")
            w_d, d_d = tm_d.accesses(1, "batch")
        else:
            w_d = d_d = 0
        out[arch] = {
            "prefill": {"weights_G": w_p / 1e9, "data_G": d_p / 1e9},
            "decode_per_token": {"weights_G": w_d / 1e9, "data_G": d_d / 1e9},
            "kv_data_dominates_decode": bool(d_d > w_d) if w_d else None,
        }
    return out


def run(*, verbose=True):
    res = {"cnn": cnn_traffic(), "lm": lm_traffic()}
    if verbose:
        print("[traffic] CNN (accesses in millions, batch=50):")
        for net, r in res["cnn"].items():
            print(f"  {net:14s} single: W={r['single']['weights_M']:8.1f} "
                  f"D={r['single']['data_M']:8.1f} | batch: "
                  f"W={r['batch']['weights_M']:8.1f} "
                  f"D={r['batch']['data_M']:8.1f}")
        print("[traffic] LM (accesses in billions):")
        for arch, r in res["lm"].items():
            d = r["decode_per_token"]
            print(f"  {arch:26s} prefill W={r['prefill']['weights_G']:8.2f} "
                  f"D={r['prefill']['data_G']:8.2f} | decode/tok "
                  f"W={d['weights_G']:7.2f} D={d['data_G']:7.2f}")
    save_json("traffic.json", res)
    return res


if __name__ == "__main__":
    run()
