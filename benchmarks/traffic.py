"""Traffic benches: the paper's Fig. 4 byte-traffic accounting AND the
traffic-at-scale serving harness (the PR 9 headline).

**Accounting** (``run_accounting`` / ``--mode accounting``): paper Fig. 4
data-traffic counts — single-image vs batch use cases, weights vs
intermediate data, per network — extended with the transformer analogue
(prefill weight-dominated, decode KV-data-dominated per LM arch). Lands
in results/traffic.json.

**Serving harness** (``run_serve`` / ``--mode serve``): replays a seeded
BURSTY overload trace (core.traffic.generate_trace — 2-state MMPP
arrivals, heavy-tailed lengths, an interactive deadlined tenant sharing
Zipf-weighted system prompts + a no-deadline batch tenant) through the
SLO scheduler twice — ``--predictor off`` vs ``--predictor on`` — with
the async double-buffered host pager on, and gates (RAISES — the CI
traffic-smoke step) on:

  * the trace actually overloading: burst arrival rate >= 1.5x the
    sustainable decode throughput (``Trace.overload_ratio``),
  * predictor-on goodput STRICTLY exceeding predictor-off (the
    telemetry control loop converts bursts it has seen into speculative
    admissions it refuses to make in front of the next one),
  * >= 0.9 token agreement for BOTH arms vs an ample-pool reference
    server (the predictor only reorders admission, never decode math),
  * the exported Chrome trace showing a ``pager.*`` span on the pager
    track overlapping a ``decode_span`` (the async D2H copies really ran
    under decode compute).

Results land in results/traffic_serve.json, the predictor-on run streams
windowed ``slo.*`` gauges into results/metrics_traffic.jsonl, the Chrome
trace in results/trace_traffic.json, and a trajectory point appends to
the repo-root BENCH_serve.json.

**Multi-replica A/B** (``run_replicas`` / ``--mode replicas``): the same
fingerprinted overload trace through ``launch.frontend.ReplicaFrontend``
at 1 vs 2 replicas — prefix-affinity routing, per-replica ``slo.*``/page
headroom balancing, cross-replica shared prefix store — gating (RAISES —
the CI replica-smoke step) on the 1-replica frontend being token-
identical to the plain server, >= 0.9 token agreement for both arms vs
the ample-pool reference, and 2-replica aggregate goodput strictly above
1-replica. Results land in results/traffic_replicas.json.

Run:  PYTHONPATH=src python -m benchmarks.traffic [--fast]
      [--mode all|serve|accounting|replicas]
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.traffic import (TenantSpec, TraceConfig, generate_trace,
                                trace_fingerprint)
from repro.launch.serve import BatchedServer, Request
from repro.models.cnn import SPECS, cnn_traffic_model
from repro.models.transformer import init_model
from repro.quant.apply import transformer_traffic_model
from repro.runtime.telemetry import PAGER_TID

from .common import RESULTS, cnn_nets, save_json


# ---------------------------------------------------------------------------
# Paper Fig. 4 accounting (the original traffic bench)
# ---------------------------------------------------------------------------
def cnn_traffic(batch=50):
    out = {}
    for net in cnn_nets():
        tm = cnn_traffic_model(SPECS[net])
        w_s, d_s = tm.accesses(batch, "single")
        w_b, d_b = tm.accesses(batch, "batch")
        out[net] = {
            "single": {"weights_M": w_s / 1e6, "data_M": d_s / 1e6},
            "batch": {"weights_M": w_b / 1e6, "data_M": d_b / 1e6},
            "weights_dominate_single": bool(w_s > d_s),
            "data_dominate_batch": bool(d_b > w_b),
        }
    return out


def lm_traffic():
    """Prefill vs decode access counts for the LM archs (per device-step,
    analytic)."""
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        tm_p = transformer_traffic_model(cfg, batch=32, seq_len=32768,
                                         mode="prefill")
        w_p, d_p = tm_p.accesses(1, "batch")
        if cfg.family != "encoder":
            tm_d = transformer_traffic_model(cfg, batch=128, seq_len=32768,
                                             mode="decode")
            w_d, d_d = tm_d.accesses(1, "batch")
        else:
            w_d = d_d = 0
        out[arch] = {
            "prefill": {"weights_G": w_p / 1e9, "data_G": d_p / 1e9},
            "decode_per_token": {"weights_G": w_d / 1e9, "data_G": d_d / 1e9},
            "kv_data_dominates_decode": bool(d_d > w_d) if w_d else None,
        }
    return out


def run_accounting(*, verbose=True):
    res = {"cnn": cnn_traffic(), "lm": lm_traffic()}
    if verbose:
        print("[traffic] CNN (accesses in millions, batch=50):")
        for net, r in res["cnn"].items():
            print(f"  {net:14s} single: W={r['single']['weights_M']:8.1f} "
                  f"D={r['single']['data_M']:8.1f} | batch: "
                  f"W={r['batch']['weights_M']:8.1f} "
                  f"D={r['batch']['data_M']:8.1f}")
        print("[traffic] LM (accesses in billions):")
        for arch, r in res["lm"].items():
            d = r["decode_per_token"]
            print(f"  {arch:26s} prefill W={r['prefill']['weights_G']:8.2f} "
                  f"D={r['prefill']['data_G']:8.2f} | decode/tok "
                  f"W={d['weights_G']:7.2f} D={d['data_G']:7.2f}")
    save_json("traffic.json", res)
    return res


# ---------------------------------------------------------------------------
# Traffic-at-scale serving harness (PR 9 headline)
# ---------------------------------------------------------------------------
def overload_trace_config(vocab_size: int, *, fast=False) -> TraceConfig:
    """The saturated bursty mix: a deadlined interactive tenant (short
    decodes, shared Zipf-weighted system prompts) and a no-deadline batch
    tenant (long decodes that occupy slots across bursts — exactly the
    speculative work the predictor should hold back)."""
    return TraceConfig(
        seed=7, horizon=40 if fast else 72,
        rate=0.06, process="bursty", burst_rate=2.2,
        p_enter_burst=0.10, p_exit_burst=0.30,
        vocab_size=vocab_size,
        tenants=(
            TenantSpec("interactive", weight=0.72, priority=5,
                       deadline_slack=4,
                       prompt_mean=9.0, prompt_sigma=0.5, prompt_cap=15,
                       max_new_mean=3.0, max_new_sigma=0.4, max_new_cap=5,
                       shared_prefix_len=8, prefix_pool=2),
            TenantSpec("batch", weight=0.28, priority=0,
                       deadline_slack=None,
                       prompt_mean=12.0, prompt_sigma=0.5, prompt_cap=23,
                       max_new_mean=14.0, max_new_sigma=0.3,
                       max_new_cap=20),
        ))


def to_requests(trace):
    """Fresh serve.Request objects for one replay arm (Request is mutable
    run state — arms must never share instances)."""
    return [Request(r.rid, np.array(r.prompt), r.max_new,
                    priority=r.priority, deadline_step=r.deadline_step,
                    arrive_step=r.arrive_step)
            for r in trace.requests]


def _token_agreement(reqs, ref_by_rid) -> float:
    per_req = []
    for r in reqs:
        ref = ref_by_rid[r.rid].out
        if not ref and not r.out:
            per_req.append(1.0)
            continue
        n = min(len(r.out), len(ref))
        if n == 0:
            per_req.append(0.0)
            continue
        per_req.append(float(np.mean(
            np.asarray(r.out[:n]) == np.asarray(ref[:n]))))
    return float(np.mean(per_req))


def _pager_overlaps_decode(events) -> bool:
    """Does any async ``pager.*`` span on the pager track overlap a
    ``decode_span`` in time? (Half-open interval intersection over the
    Chrome X events.)"""
    pager = [(e["ts"], e["ts"] + e["dur"]) for e in events
             if e.get("ph") == "X" and e.get("tid") == PAGER_TID
             and str(e.get("name", "")).startswith("pager.")
             and (e.get("args") or {}).get("async")]
    decode = [(e["ts"], e["ts"] + e["dur"]) for e in events
              if e.get("ph") == "X" and e.get("name") == "decode_span"]
    return any(p0 < d1 and d0 < p1
               for p0, p1 in pager for d0, d1 in decode)


def _slo_gauges(registry) -> dict:
    snap = registry.snapshot()["gauges"]
    return {k: v for k, v in sorted(snap.items())
            if k.startswith("slo.")}


def run_serve(*, arch="qwen2-72b", verbose=True, fast=False):
    """Replay the overload trace predictor-off vs predictor-on and gate
    the control loop's win (see module docstring)."""
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch, page_size, max_len = 3, 8, 64
    # pool sized WELL below the working set so cached prefixes demote to
    # the host tier under pressure (the async pager's traffic source)
    num_pages = 1 + 13
    trace = generate_trace(overload_trace_config(cfg.vocab_size, fast=fast))
    overload = trace.overload_ratio(batch)
    if overload < 1.5:
        raise RuntimeError(
            f"traffic trace is not an overload: burst arrivals are only "
            f"{overload:.2f}x sustainable throughput (need >= 1.5) — "
            f"{len(trace.requests)} requests, burst rate "
            f"{trace.burst_rate_observed():.2f}/step")

    common = dict(batch_size=batch, max_len=max_len, page_size=page_size,
                  num_pages=num_pages, kv_bits=8, prefix_cache="on",
                  kv_offload="host", sched="slo", preempt=False,
                  metrics="on", pager_async="on")
    os.makedirs(RESULTS, exist_ok=True)
    snap_path = os.path.join(RESULTS, "metrics_traffic.jsonl")
    if os.path.exists(snap_path):
        os.remove(snap_path)   # append-mode stream: one bench, one stream

    def arm(predictor, **extra):
        srv = BatchedServer(cfg, params, predictor=predictor,
                            **common, **extra)
        t0 = time.time()
        reqs = srv.run(to_requests(trace))
        return srv, reqs, time.time() - t0

    srv_off, reqs_off, t_off = arm("off")
    srv_on, reqs_on, t_on = arm("on", snapshot_out=snap_path,
                                snapshot_every=5)
    slo_off = srv_off.tracer.slo_summary()
    slo_on = srv_on.tracer.slo_summary()

    # --- reference for token agreement: ample pool, no admission policy
    # in the way (full capacity, FIFO order is irrelevant — every request
    # fits on arrival) ---
    ref = BatchedServer(cfg, params, batch_size=batch, max_len=max_len,
                        page_size=page_size, kv_bits=8)
    ref_reqs = ref.run(to_requests(trace))
    ref_by_rid = {r.rid: r for r in ref_reqs}
    agree_off = _token_agreement(reqs_off, ref_by_rid)
    agree_on = _token_agreement(reqs_on, ref_by_rid)

    trace_path = srv_on.tracer.export_chrome(
        os.path.join(RESULTS, "trace_traffic.json"))

    # --- gates (the CI traffic-smoke step) ---
    if min(agree_off, agree_on) < 0.9:
        raise RuntimeError(
            f"traffic replay broke decode numerics: token agreement "
            f"off={agree_off:.1%} on={agree_on:.1%} vs reference "
            f"(need >= 0.9 — admission policy must not touch math)")
    if slo_on["goodput"] is None or slo_off["goodput"] is None:
        raise RuntimeError("traffic replay produced no goodput — empty "
                           "trace or no finished requests")
    if slo_on["goodput"] <= slo_off["goodput"]:
        raise RuntimeError(
            f"deadline-miss predictor failed to buy goodput on the "
            f"overload trace: on={slo_on['goodput']:.3f} <= "
            f"off={slo_off['goodput']:.3f} "
            f"(misses {slo_on['deadline_misses']} vs "
            f"{slo_off['deadline_misses']})")
    if not _pager_overlaps_decode(srv_on.tracer.events):
        raise RuntimeError(
            "async pager produced no pager.* span overlapping a "
            "decode_span — D2H transfers are not hiding under decode")
    if not os.path.exists(snap_path):
        raise RuntimeError("predictor-on run emitted no JSONL metrics "
                           "snapshot stream")

    gauges = _slo_gauges(srv_on.metrics)
    res = {
        "arch": arch, "fast": fast, "batch": batch,
        "page_size": page_size, "num_pages": num_pages,
        "trace": {
            "requests": len(trace.requests),
            "horizon": trace.config.horizon,
            "offered_rate": trace.offered_rate,
            "burst_rate": trace.burst_rate_observed(),
            "burst_steps": len(trace.burst_steps),
            "overload_ratio": overload,
            "fingerprint": trace_fingerprint(trace),
        },
        "predictor_off": {
            "goodput": slo_off["goodput"],
            "deadline_misses": slo_off["deadline_misses"],
            "ttft_p50_s": slo_off["ttft_p50_s"],
            "ttft_p99_s": slo_off["ttft_p99_s"],
            "tpot_p50_s": slo_off["tpot_p50_s"],
            "tpot_p99_s": slo_off["tpot_p99_s"],
            "wall_s": t_off,
            "token_agreement": agree_off,
        },
        "predictor_on": {
            "goodput": slo_on["goodput"],
            "deadline_misses": slo_on["deadline_misses"],
            "ttft_p50_s": slo_on["ttft_p50_s"],
            "ttft_p99_s": slo_on["ttft_p99_s"],
            "tpot_p50_s": slo_on["tpot_p50_s"],
            "tpot_p99_s": slo_on["tpot_p99_s"],
            "wall_s": t_on,
            "token_agreement": agree_on,
            "predictor_updates":
                srv_on.metrics.counter("sched.predictor_updates").value,
            "predictor_gated":
                srv_on.metrics.counter("sched.predictor_gated").value,
            "pager_demotions":
                srv_on.metrics.counter("pager.demotions").value,
            "pager_promotions":
                srv_on.metrics.counter("pager.promotions").value,
        },
        "goodput_delta": slo_on["goodput"] - slo_off["goodput"],
        "slo_gauges_on": gauges,
        "trace_path": trace_path,
        "metrics_jsonl": snap_path,
    }
    if verbose:
        print(f"[traffic] {len(trace.requests)} requests over "
              f"{trace.config.horizon} steps "
              f"({len(trace.burst_steps)} burst steps, "
              f"{overload:.1f}x overload at batch={batch})")
        print(f"  predictor off: goodput {slo_off['goodput']:.3f} "
              f"({slo_off['deadline_misses']} misses), "
              f"ttft p99 {1e3 * (slo_off['ttft_p99_s'] or 0):.1f} ms, "
              f"agreement {agree_off:.1%}")
        print(f"  predictor on:  goodput {slo_on['goodput']:.3f} "
              f"({slo_on['deadline_misses']} misses, "
              f"{res['predictor_on']['predictor_gated']} admissions "
              f"gated, {res['predictor_on']['predictor_updates']} SGD "
              f"updates), ttft p99 "
              f"{1e3 * (slo_on['ttft_p99_s'] or 0):.1f} ms, "
              f"agreement {agree_on:.1%}")
        print(f"  goodput delta +{res['goodput_delta']:.3f}; async pager "
              f"{res['predictor_on']['pager_demotions']} demotions / "
              f"{res['predictor_on']['pager_promotions']} promotions "
              f"overlapping decode -> {os.path.basename(trace_path)}")
        print(f"  windowed gauges: "
              + ", ".join(f"{k.split('.', 1)[1]}={v:.3g}"
                          for k, v in gauges.items()))
    save_json("traffic_serve.json", res)
    from .paged_serve import _append_trajectory
    point = {"when": time.strftime("%Y-%m-%d %H:%M:%S"), "arch": arch,
             "fast": fast, "summary": {"traffic": {
                 "goodput": slo_on["goodput"],
                 "goodput_off": slo_off["goodput"],
                 "goodput_delta": res["goodput_delta"],
                 "ttft_p99_s": slo_on["ttft_p99_s"],
                 "tpot_p50_s": slo_on["tpot_p50_s"],
                 "token_agreement": agree_on,
                 "overload_ratio": overload}}}
    path = _append_trajectory(point)
    if verbose:
        print(f"  trajectory point appended to {os.path.basename(path)}")
    return res


def run_replicas(*, arch="qwen2-72b", verbose=True, fast=False):
    """Sharded multi-replica A/B on the same fingerprinted overload trace
    (the PR 10 headline): a 1-replica :class:`ReplicaFrontend` vs a
    2-replica pool with prefix-affinity routing and the cross-replica
    shared prefix store. Gates (RAISE — the CI replica-smoke step):

      * the 1-replica frontend being THE SAME SERVER: token streams,
        done flags and finish steps bitwise-equal to a plain
        ``BatchedServer.run`` replay (the frontend's identity contract;
        also subprocess-asserted at kv-bits 0/8/4 in
        tests/test_frontend.py),
      * >= 0.9 token agreement for BOTH arms vs the ample-pool reference
        (routing must never touch decode math),
      * 2-replica aggregate goodput STRICTLY above 1-replica (scaling
        out buys deadline hits on the overload trace).
    """
    from repro.launch.frontend import (ReplicaFrontend, aggregate_goodput,
                                       make_replicas, merged_snapshot,
                                       requests_from_trace)
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch, page_size, max_len = 3, 8, 64
    num_pages = 1 + 13
    trace = generate_trace(overload_trace_config(cfg.vocab_size, fast=fast))
    overload = trace.overload_ratio(batch)
    common = dict(batch_size=batch, max_len=max_len, page_size=page_size,
                  num_pages=num_pages, kv_bits=8, prefix_cache="on",
                  kv_offload="host", sched="slo", preempt=False,
                  metrics="on", pager_async="on")

    def arm(n):
        fe = ReplicaFrontend(make_replicas(n, cfg, params, **common))
        reqs, keys = requests_from_trace(trace)
        t0 = time.time()
        fe.run(reqs, keys)
        return fe, reqs, time.time() - t0

    fe1, reqs1, t1 = arm(1)
    fe2, reqs2, t2 = arm(2)

    # --- identity: the 1-replica frontend IS the plain server ---
    plain = BatchedServer(cfg, params, **common)
    plain_by_rid = {r.rid: r for r in plain.run(to_requests(trace))}
    for r in reqs1:
        p = plain_by_rid[r.rid]
        if (list(r.out) != list(p.out) or r.done != p.done
                or r.finish_step != p.finish_step):
            raise RuntimeError(
                f"1-replica frontend diverged from the plain server on "
                f"rid={r.rid}: out {r.out} vs {p.out}, done {r.done} vs "
                f"{p.done}, finish {r.finish_step} vs {p.finish_step}")

    # --- agreement vs the ample-pool reference (no admission pressure) ---
    ref = BatchedServer(cfg, params, batch_size=batch, max_len=max_len,
                        page_size=page_size, kv_bits=8)
    ref_by_rid = {r.rid: r for r in ref.run(to_requests(trace))}
    agree1 = _token_agreement(reqs1, ref_by_rid)
    agree2 = _token_agreement(reqs2, ref_by_rid)
    if min(agree1, agree2) < 0.9:
        raise RuntimeError(
            f"replica routing broke decode numerics: token agreement "
            f"1rep={agree1:.1%} 2rep={agree2:.1%} vs reference "
            f"(need >= 0.9 — the frontend must not touch math)")

    g1 = aggregate_goodput(reqs1)
    g2 = aggregate_goodput(reqs2)
    if g1 is None or g2 is None:
        raise RuntimeError("replica replay produced no goodput")
    if g2 <= g1:
        raise RuntimeError(
            f"2-replica pool failed to buy goodput on the overload "
            f"trace: 2rep={g2:.3f} <= 1rep={g1:.3f} — scaling out must "
            f"convert the burst backlog into deadline hits")

    c2 = merged_snapshot(fe2)["counters"]
    res = {
        "arch": arch, "fast": fast, "batch": batch,
        "page_size": page_size, "num_pages": num_pages,
        "trace": {
            "requests": len(trace.requests),
            "horizon": trace.config.horizon,
            "overload_ratio": overload,
            "fingerprint": trace_fingerprint(trace),
        },
        "one_replica": {"goodput": g1, "token_agreement": agree1,
                        "wall_s": t1},
        "two_replica": {
            "goodput": g2, "token_agreement": agree2, "wall_s": t2,
            "routed": c2.get("frontend.routed", 0),
            "routed_per_replica": [
                c2.get(f"frontend.routed_replica{i}", 0) for i in (0, 1)],
            "affinity_hits": c2.get("frontend.affinity_hits", 0),
            "rebalanced": c2.get("frontend.rebalanced", 0),
            "shared_prefix_pages": c2.get("frontend.shared_prefix_pages", 0),
        },
        "goodput_delta": g2 - g1,
    }
    if verbose:
        two = res["two_replica"]
        print(f"[traffic:replicas] {len(trace.requests)} requests, "
              f"{overload:.1f}x overload at batch={batch}")
        print(f"  1 replica:  aggregate goodput {g1:.3f}, "
              f"agreement {agree1:.1%} (identical to plain server)")
        print(f"  2 replicas: aggregate goodput {g2:.3f}, "
              f"agreement {agree2:.1%}, routed "
              f"{two['routed_per_replica']}, "
              f"{two['affinity_hits']} affinity hits / "
              f"{two['rebalanced']} rebalances, "
              f"{two['shared_prefix_pages']} shared prefix pages")
        print(f"  goodput delta +{res['goodput_delta']:.3f}")
    save_json("traffic_replicas.json", res)
    from .paged_serve import _append_trajectory
    point = {"when": time.strftime("%Y-%m-%d %H:%M:%S"), "arch": arch,
             "fast": fast, "summary": {"replicas": {
                 "goodput_1rep": g1,
                 "goodput_2rep": g2,
                 "goodput_delta": res["goodput_delta"],
                 "token_agreement_2rep": agree2,
                 "affinity_hits": res["two_replica"]["affinity_hits"],
                 "shared_prefix_pages":
                     res["two_replica"]["shared_prefix_pages"],
                 "overload_ratio": overload}}}
    path = _append_trajectory(point)
    if verbose:
        print(f"  trajectory point appended to {os.path.basename(path)}")
    return res


def run(*, verbose=True, fast=False, mode="all"):
    res = {}
    if mode in ("all", "accounting"):
        res["accounting"] = run_accounting(verbose=verbose)
    if mode in ("all", "serve"):
        res["serve"] = run_serve(verbose=verbose, fast=fast)
    if mode in ("all", "replicas"):
        res["replicas"] = run_replicas(verbose=verbose, fast=fast)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--mode",
                    choices=["all", "serve", "accounting", "replicas"],
                    default="all")
    args = ap.parse_args()
    run(fast=args.fast, mode=args.mode)
