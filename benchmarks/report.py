"""Assemble EXPERIMENTS.md from the results/ JSONs.

Usage: PYTHONPATH=src python -m benchmarks.report [--write]
       PYTHONPATH=src python -m benchmarks.report --serve
Sections: §Repro (paper tables), §Dry-run, §Roofline, §Perf (hillclimb log
read from results/perf_log.json, appended by the perf iterations).

``--serve`` prints the BENCH_serve.json trajectory instead: per-workload
latest-vs-first deltas for tok/s, goodput and ttft_p99 (points compared at
the same --fast flag), so the cross-PR serving perf history is readable
without hand-parsing the JSON.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .roofline import HBM_BYTES, analyze_record, load_all, table

RESULTS = os.environ.get("REPRO_RESULTS", "results")


def _load(name, default=None):
    try:
        with open(os.path.join(RESULTS, name)) as f:
            return json.load(f)
    except FileNotFoundError:
        return default


def repro_section() -> str:
    out = ["## §Repro — paper-faithful reproduction", ""]
    uni = _load("uniform_sweep.json", {})
    per = _load("perlayer_sweep.json", {})
    par = _load("pareto_search.json", {})
    tra = _load("traffic.json", {})
    if uni:
        out += ["### Uniform precision across all layers (paper Fig. 2)", "",
                "| network | baseline top-1 | min weight frac bits @1% | "
                "min data int bits @1% | min data frac bits @1% |",
                "|---|---|---|---|---|"]
        for net, r in uni.items():
            out.append(f"| {net} | {r['baseline_accuracy']:.4f} | "
                       f"{r['min_weight_frac@1%']} | {r['min_data_int@1%']} "
                       f"| {r['min_data_frac@1%']} |")
        out += ["", "Paper's finding reproduced: ~10 weight bits / <=12 data "
                "int bits suffice uniformly; requirements differ per "
                "network.", ""]
    if per:
        out += ["### Per-layer tolerance (paper Fig. 3 — the key result)",
                "", "| network | per-layer min weight-frac bits @1% | "
                "spread (bits) |", "|---|---|---|"]
        for net, r in per.items():
            bits = [str(v["min_weight_frac@1%"])
                    for v in r["per_layer"].values()]
            out.append(f"| {net} | {'-'.join(bits)} | "
                       f"{r['weight_bits_spread']} |")
        out += ["", "Precision tolerance varies WITHIN each network "
                "(nonzero spread) — the paper's central observation.", ""]
    if tra:
        out += ["### Traffic accounting (paper Fig. 4)", "",
                "| network | single: W/D (M accesses) | batch: W/D | "
                "batch data-dominated |", "|---|---|---|---|"]
        for net, r in tra.get("cnn", {}).items():
            s, b = r["single"], r["batch"]
            out.append(
                f"| {net} | {s['weights_M']:.1f}/{s['data_M']:.1f} | "
                f"{b['weights_M']:.1f}/{b['data_M']:.1f} | "
                f"{r['data_dominate_batch']} |")
        out += [""]
    if par:
        out += ["### Greedy per-layer search (paper Fig. 5 / Table 2)", "",
                "| network | tol | traffic ratio (TR) | accuracy | paper "
                "TR@1% |", "|---|---|---|---|---|"]
        paper_tr = {"lenet": 0.08, "convnet": 0.24, "alexnet_small": 0.28}
        for net, r in par.items():
            for tol, t in r["tolerances"].items():
                ref = paper_tr.get(net, "—") if tol == "1%" else ""
                out.append(f"| {net} | {tol} | {t['traffic_ratio']:.3f} | "
                           f"{t['accuracy']:.4f} | {ref} |")
        out += ["", "TR = priced traffic / 32-bit baseline. The search "
                "reproduces the paper's 3-10x traffic cuts at small "
                "accuracy loss; absolute TRs depend on our procedural "
                "datasets (easier than ImageNet => lower TR for the small "
                "nets, same qualitative band).", ""]
    lm = _load("lm_precision.json")
    if lm:
        out += ["### Beyond paper: same machinery on a transformer LM", "",
                f"arch={lm['arch']} baseline next-token top-1 = "
                f"{lm['baseline_topk1']:.4f}", ""]
        for tol, t in lm.get("tolerances", {}).items():
            out.append(f"- tol {tol}: TR={t['traffic_ratio']:.3f} "
                       f"acc={t['accuracy']:.4f}")
        out += [""]
    return "\n".join(out)


def dryrun_section(tag="baseline") -> str:
    rows = ["## §Dry-run — 512-chip multi-pod compile matrix", "",
            "Meshes: single pod (16,16) data x model = 256 chips; "
            "multi-pod (2,16,16) pod x data x model = 512 chips. Every "
            "applicable (arch x shape) cell lowers AND compiles on both "
            "(`python -m repro.launch.dryrun --arch all --shape all "
            "--mesh both`).", "",
            "| arch | shape | mesh | compile s | HLO flops/dev | HBM "
            "bytes/dev | wire bytes/dev | dev args+temp GiB |",
            "|---|---|---|---|---|---|---|---|"]
    n_ok = 0
    for path in sorted(glob.glob(
            os.path.join(RESULTS, "dryrun", tag, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            continue
        n_ok += 1
        lc = rec["loop_cost"]
        mem = rec.get("memory", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{rec['compile_s']} | {lc['flops']:.2e} | "
            f"{lc['hbm_bytes']:.2e} | {lc['wire_bytes']:.2e} | {gib:.1f} |")
    rows.insert(2, f"**{n_ok} cells compiled OK** (31 applicable cells x 2 "
                   "meshes; 9 skips are principled — see DESIGN.md "
                   "§Arch-applicability).")
    rows += ["", "Costs are per-device-per-step from the loop-aware HLO "
             "model (launch.hlo_cost): while bodies x known_trip_count, "
             "fusion-boundary bytes, ring-model collective wire bytes. "
             "NOTE: XLA:CPU cannot alias donated buffers, so args+temp "
             "double-counts the donated train state / decode caches; the "
             "roofline's fit column corrects for this.", ""]
    return "\n".join(rows)


def roofline_section(tag="baseline") -> str:
    recs = load_all(tag)
    out = ["## §Roofline — per (arch x shape), single pod (v5e constants)",
           "",
           "compute = FLOPs/dev / 197e12; memory = HBM bytes/dev / 819e9; "
           "collective = ring wire bytes/dev / 50e9 (seconds/step).",
           "roofline frac = (MODEL_FLOPS/dev / 197e12) / max(term) — the "
           "fraction of peak the step-time lower bound achieves; "
           "useful/HLO = MODEL_FLOPS / compiled FLOPs (remat+attention "
           "overhead).", "",
           table(recs, mesh="single"), ""]
    sug = {}
    for r in recs:
        if r["mesh"] == "single":
            sug.setdefault(r["dominant"], []).append(
                f"{r['arch']}/{r['shape']}")
    out += ["### Dominant bottleneck per cell", ""]
    for dom, cells in sug.items():
        out.append(f"- **{dom}-bound**: {', '.join(cells)}")
    out += [""]
    return "\n".join(out)


def perf_section() -> str:
    log = _load("perf_log.json", [])
    out = ["## §Perf — hillclimb log (hypothesis -> change -> measure)", ""]
    if not log:
        out.append("(no perf iterations recorded yet)")
        return "\n".join(out)
    cur = None
    for e in log:
        cell = f"{e['arch']}/{e['shape']}"
        if cell != cur:
            out += [f"### {cell} ({e.get('why', '')})", ""]
            cur = cell
        out += [f"**[{e['iter']}] {e['title']}**",
                f"- hypothesis: {e['hypothesis']}",
                f"- change: {e['change']}",
                f"- before: {e['before']}",
                f"- after: {e['after']}",
                f"- verdict: {e['verdict']}", ""]
    return "\n".join(out)


BENCH_TRAJECTORY = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json")

# scalar fields worth trending, per workload result dict (nested dicts —
# e.g. the mixed bench's per-config tokens_per_s — expand per sub-key)
_SERVE_METRICS = ("tokens_per_s", "goodput", "goodput_off", "goodput_delta",
                  "ttft_p99_s", "token_agreement", "program_reduction",
                  "prefill_forwards_reduction", "goodput_1rep",
                  "goodput_2rep", "token_agreement_2rep",
                  "blocked_speedup_geomean", "grid_step_ratio")


def _serve_points():
    """BENCH_serve.json trajectory grouped into (workload, fast) series.

    A mixed-bench point carries its metrics at summary top level (keyed by
    ``tokens_per_s``); workload points nest them one level down under the
    workload name. ``--workload all`` points contribute to both."""
    try:
        with open(BENCH_TRAJECTORY) as f:
            traj = json.load(f).get("trajectory", [])
    except (OSError, json.JSONDecodeError):
        return {}
    series = {}
    for p in traj:
        summary = p.get("summary") or {}
        items = []
        if "tokens_per_s" in summary:
            items.append(("mixed", summary))
        items += [(k, v) for k, v in summary.items()
                  if isinstance(v, dict) and k != "tokens_per_s"
                  and any(m in v for m in _SERVE_METRICS)]
        for wl, res in items:
            series.setdefault((wl, bool(p.get("fast"))), []).append(
                (p.get("when", "?"), res))
    return series


def _flat_metrics(res: dict) -> dict:
    out = {}
    for m in _SERVE_METRICS:
        if m not in res:
            continue
        v = res[m]
        if isinstance(v, dict):
            for k, vv in v.items():
                if isinstance(vv, (int, float)):
                    out[f"{m}[{k}]"] = float(vv)
        elif isinstance(v, (int, float)):
            out[m] = float(v)
        else:
            # present but unusable (pre-PR-8 runs emit None for SLO
            # fields the telemetry layer didn't exist to fill) — keep the
            # row so the trend table shows an explicit n/a, not a gap
            out[m] = None
    return out


def _fmt_metric(v) -> str:
    return f"{v:.4g}" if isinstance(v, (int, float)) else "n/a"


def serve_section() -> str:
    series = _serve_points()
    out = ["## §Serve — BENCH_serve.json trajectory "
           "(latest vs first, per workload)", ""]
    if not series:
        out.append("(no BENCH_serve.json trajectory recorded yet)")
        return "\n".join(out)
    for (wl, fast), points in sorted(series.items()):
        first_when, first = points[0]
        last_when, last = points[-1]
        f0, f1 = _flat_metrics(first), _flat_metrics(last)
        label = f"{wl} ({'fast' if fast else 'full'}, {len(points)} point"
        label += "s)" if len(points) != 1 else ")"
        out += [f"### {label}",
                f"first {first_when} -> latest {last_when}", "",
                "| metric | first | latest | delta |", "|---|---|---|---|"]
        keys = [k for k in f1 if k in f0] \
            + [k for k in f1 if k not in f0] \
            + [k for k in f0 if k not in f1]
        for k in keys:
            v0, v1 = f0.get(k), f1.get(k)
            if isinstance(v0, float) and isinstance(v1, float):
                d = v1 - v0
                rel = f" ({d / abs(v0):+.1%})" if v0 else ""
                out.append(f"| {k} | {v0:.4g} | {v1:.4g} | "
                           f"{d:+.4g}{rel} |")
            elif k not in f0:
                out.append(f"| {k} | n/a | {_fmt_metric(v1)} | new |")
            else:
                # one side is missing or non-numeric (e.g. the first point
                # predates the SLO fields): print n/a, never crash
                out.append(f"| {k} | {_fmt_metric(v0)} | "
                           f"{_fmt_metric(v1)} | n/a |")
        out.append("")
    return "\n".join(out)


HEADER = """# EXPERIMENTS

Reproduction of *Reduced-Precision Strategies for Bounded Memory in Deep
Neural Nets* (Judd et al., 2015) + pod-scale JAX framework results.
All numbers regenerate via:

```
PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
PYTHONPATH=src python -m benchmarks.run
PYTHONPATH=src python -m benchmarks.report --write
```

## Headline results

* **Paper validated** (on procedural datasets — the container is offline):
  per-layer precision tolerance varies within every network; the greedy
  search reaches TR = 0.14-0.17 at <=1% accuracy loss (83-86% traffic cut;
  paper: 74% avg). Both the paper's exact algorithm and a beyond-paper
  sensitivity-ordered search (8-11x fewer evaluations) are implemented.
* **62/62 dry-run cells compile** on the (16,16) single-pod and (2,16,16)
  multi-pod meshes — every assigned (arch x shape) combination.
* **§Perf hillclimb** (three cells, hypothesis -> change -> measure):
  - qwen2-72b/decode_32k: step-time lower bound 3.56s -> 2.05s (1.74x);
    the paper's int8 per-layer KV cache alone cuts the memory term 72%
    and the resident cache+weights 14.7 -> 4.6 GiB.
  - deepseek-v3-671b/train_4k: collective wire 4.79 -> 2.59 TB/device
    (-46%), collective term 96s -> 52s (MLA expansion sharding pin,
    shard_map MoE with int8 all-to-all, 3-D routing).
  - xlstm-350m/train_4k: memory term 219s -> 3.3s (66x) — sLSTM scan
    time-dim sharding fix + slice-aware cost accounting.
* The baseline lowering itself absorbed three structural fixes found
  through the same loop (shard_map MoE dispatch replacing GSPMD scatter:
  -16x device memory on deepseek-v3; expanded-H GQA attention; SP residual
  sharding) — see DESIGN.md §7b and §Perf below.
"""


def build() -> str:
    return "\n".join([HEADER, repro_section(), dryrun_section(),
                      roofline_section(), perf_section()])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true")
    ap.add_argument("--serve", action="store_true",
                    help="print the BENCH_serve.json per-workload "
                         "latest-vs-first trajectory summary and exit")
    args = ap.parse_args()
    if args.serve:
        print(serve_section())
        return
    doc = build()
    if args.write:
        with open("EXPERIMENTS.md", "w") as f:
            f.write(doc)
        print("wrote EXPERIMENTS.md")
    else:
        print(doc)


if __name__ == "__main__":
    main()
