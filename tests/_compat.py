"""Hypothesis compatibility shim for the tier-1 environment.

The property tests are written against the real ``hypothesis`` API. When the
package is installed we simply re-export it. When it is absent (the minimal
CPU container), a small seeded example-sampling fallback provides the subset
the tests use — ``@given`` draws ``max_examples`` pseudo-random examples per
strategy and runs the test body once per example, so the properties still
execute instead of dying at import.

The fallback is deliberately deterministic (fixed seed per test name) so
failures reproduce; it does lose shrinking and the database, which is fine
for CI smoke coverage.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect as _inspect
    import zlib

    import numpy as _np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw(rng) callable; mirrors the tiny slice of the API we use."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred, _tries=100):
            def draw(rng):
                for _ in range(_tries):
                    x = self._draw(rng)
                    if pred(x):
                        return x
                raise ValueError("filter predicate too strict in shim")
            return _Strategy(draw)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: float(
                rng.uniform(min_value, max_value)))

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

        @staticmethod
        def lists(elem, min_size=0, max_size=8):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    strategies = _StrategiesModule()

    def settings(**kwargs):
        """Accepts hypothesis settings kwargs; only max_examples matters."""
        max_examples = kwargs.get("max_examples", 20)

        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*pos_strats, **kw_strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # @settings may sit above @given (attr lands on wrapper) or
                # below it (attr lands on fn)
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 20))
                rng = _np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    pos = tuple(s.draw(rng) for s in pos_strats)
                    drawn = {k: s.draw(rng) for k, s in kw_strats.items()}
                    try:
                        fn(*args, *pos, **drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"shim-property failure on example {i}: "
                            f"args={pos!r} kwargs={drawn!r}") from e

            # hide the drawn parameters from pytest's fixture resolution:
            # strategies fill all keyword-named params and the rightmost
            # positional params, exactly like real hypothesis
            sig = _inspect.signature(fn)
            params = [p for p in sig.parameters.values()
                      if p.name not in kw_strats]
            if pos_strats:
                params = params[:len(params) - len(pos_strats)]
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper
        return deco
