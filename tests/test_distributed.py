"""Distribution tests on 8 fake host devices (subprocess — the device count
must be fixed before jax initializes, so each case runs its own python)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout=420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=_ROOT)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_quantized_allreduce_matches_mean():
    run_py("""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import quantized_allreduce
        mesh = jax.make_mesh((8,), ("d",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        f = shard_map(lambda x: quantized_allreduce(x[0], "d")[None],
                      mesh=mesh, in_specs=P("d"), out_specs=P("d"),
                      check_rep=False)
        got = f(x)                       # every row = approx mean
        want = x.mean(axis=0)
        err = float(jnp.abs(got - want[None]).max())
        rel = err / float(jnp.abs(want).max())
        assert rel < 0.05, (err, rel)    # int8 wire, n-1 requant hops
        # int8 wire really appears in the lowered HLO
        txt = jax.jit(f).lower(x).compile().as_text()
        assert "s8[" in txt and "collective-permute" in txt
        print("OK")
    """)


def test_gpipe_pipeline_matches_sequential():
    run_py("""
        from repro.parallel.pipeline import gpipe_apply, pipeline_bubble
        mesh = jax.make_mesh((8,), ("stage",))
        S, M, mb, D = 8, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), S)
        Ws = jnp.stack([jax.random.normal(k, (D, D)) * 0.3 for k in ks])
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
        def stage_fn(W, h):
            return jnp.tanh(h @ W)
        out = gpipe_apply(stage_fn, Ws, x, mesh=mesh, axis="stage")
        # sequential reference
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        assert abs(pipeline_bubble(8, 4) - 7/11) < 1e-9
        print("OK")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    """The REAL train step, jit'd with production sharding rules on a (2,4)
    mesh, must produce the same loss trajectory as the unsharded step."""
    run_py("""
        import dataclasses
        from repro.configs.registry import get_smoke_config
        from repro.launch.steps import TrainHParams, init_train_state, \\
            make_train_step
        from repro.data.lm import LMDataConfig, lm_batch
        from repro.parallel.sharding import (auto_batch_sharding,
                                             plan_for_mesh, state_shardings)
        from repro.parallel.hints import activation_hints

        cfg = get_smoke_config("yi-34b")
        hp = TrainHParams(lr=1e-3)
        dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                            batch_size=8, seed=5)
        state0 = init_train_state(jax.random.PRNGKey(0), cfg, hp)
        step = make_train_step(cfg, hp)

        # single device
        s = state0
        losses1 = []
        for i in range(3):
            s, m = jax.jit(step)(s, lm_batch(dcfg, i))
            losses1.append(float(m["loss"]))

        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = plan_for_mesh(mesh)
        sh = state_shardings(jax.eval_shape(lambda: state0), plan)
        bsh = auto_batch_sharding(jax.eval_shape(lambda: lm_batch(dcfg, 0)),
                                  plan)
        s2 = jax.device_put(state0, sh)
        with activation_hints(plan):
            jstep = jax.jit(step, in_shardings=(sh, bsh),
                            out_shardings=(sh, None))
            losses2 = []
            for i in range(3):
                batch = jax.device_put(lm_batch(dcfg, i), bsh)
                s2, m = jstep(s2, batch)
                losses2.append(float(m["loss"]))
        np.testing.assert_allclose(losses1, losses2, rtol=2e-2)
        print("OK", losses1, losses2)
    """)


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint on a (4,2) mesh, restore onto (2,4) and single device —
    the mesh-agnostic checkpoint contract."""
    d = str(tmp_path / "ck")
    run_py(f"""
        from repro.configs.registry import get_smoke_config
        from repro.launch.steps import TrainHParams, init_train_state
        from repro.parallel.sharding import plan_for_mesh, state_shardings
        from repro.checkpoint.ckpt import save_checkpoint
        cfg = get_smoke_config("deepseek-7b")
        hp = TrainHParams()
        state = init_train_state(jax.random.PRNGKey(3), cfg, hp)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sh = state_shardings(jax.eval_shape(lambda: state),
                             plan_for_mesh(mesh))
        state = jax.device_put(state, sh)
        save_checkpoint({d!r}, 17, state)
        print("saved")
    """)
    run_py(f"""
        from repro.configs.registry import get_smoke_config
        from repro.launch.steps import TrainHParams, init_train_state
        from repro.runtime.elastic import elastic_restore
        cfg = get_smoke_config("deepseek-7b")
        hp = TrainHParams()
        tmpl = jax.eval_shape(
            lambda k: init_train_state(k, cfg, hp), jax.random.PRNGKey(0))
        for shape, axes in [((2, 4), ("data", "model")),
                            ((8,), ("data",))]:
            mesh = jax.make_mesh(shape, axes)
            step, state, _ = elastic_restore({d!r}, tmpl, mesh)
            assert step == 17
            leaf = state["params"]["embed"]["table"]
            assert leaf.shape == tmpl["params"]["embed"]["table"].shape
        print("OK")
    """)


def test_dryrun_cells_compile_on_test_mesh():
    """dryrun.lower_cell on a small mesh for one arch of each family kind."""
    run_py("""
        import dataclasses
        from repro.configs.registry import get_smoke_config
        from repro.configs.shapes import ShapeConfig
        from repro.launch import dryrun
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        tr = ShapeConfig("t", 128, 8, "train")
        dc = ShapeConfig("d", 128, 8, "decode")
        for arch in ["qwen1.5-32b", "deepseek-v3-671b", "xlstm-350m",
                     "jamba-v0.1-52b"]:
            cfg = dataclasses.replace(get_smoke_config(arch), loss_chunk=64)
            for shape in (tr, dc):
                c = dryrun.lower_cell(cfg, shape, mesh,
                                      kv_bits=8 if shape.kind == "decode"
                                      else 0).compile()
                assert c is not None
        print("OK")
    """, timeout=560)


def test_moe_all_to_all_visible_in_hlo():
    run_py("""
        import dataclasses
        from repro.configs.registry import get_smoke_config
        from repro.models.moe import init_moe, moe_apply
        from repro.parallel.sharding import plan_for_mesh
        from repro.parallel.hints import activation_hints
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = plan_for_mesh(mesh)
        cfg = get_smoke_config("deepseek-v3-671b")
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jnp.ones((4, 32, cfg.d_model), jnp.float32)
        with activation_hints(plan):
            txt = jax.jit(lambda p, x: moe_apply(p, x, cfg=cfg,
                                                 mode="scatter")[0]) \\
                .lower(p, x).compile().as_text()
        assert "all-to-all" in txt, "EP dispatch must lower to all-to-all"
        print("OK")
    """)
