"""End-to-end system tests: real training runs, resume-exactness, serving,
and the paper pipeline (train -> calibrate -> search) in miniature."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod

jax.config.update("jax_platform_name", "cpu")


def test_train_loss_decreases(tmp_path):
    log = train_mod.main([
        "--arch", "xlstm-350m", "--smoke", "--steps", "30",
        "--batch-size", "4", "--seq-len", "64", "--log-every", "10",
        "--lr", "3e-3",
        "--metrics-out", str(tmp_path / "m.json")])
    assert len(log) == 3
    assert log[-1]["loss"] < log[0]["loss"]
    assert np.isfinite(log[-1]["loss"])


def test_train_resume_is_exact(tmp_path):
    """20 straight steps == 10 steps + checkpoint + restore + 10 steps."""
    args = ["--arch", "deepseek-7b", "--smoke", "--batch-size", "4",
            "--seq-len", "64", "--log-every", "5", "--lr", "1e-3"]
    log_a = train_mod.main(args + ["--steps", "20"])
    ck = str(tmp_path / "ck")
    train_mod.main(args + ["--steps", "10", "--ckpt-dir", ck,
                           "--ckpt-interval", "10"])
    log_b = train_mod.main(args + ["--steps", "20", "--ckpt-dir", ck,
                                   "--ckpt-interval", "100", "--resume"])
    la = [r for r in log_a if r["step"] == 20][0]["loss"]
    lb = [r for r in log_b if r["step"] == 20][0]["loss"]
    np.testing.assert_allclose(la, lb, rtol=1e-4)


def test_train_with_perlayer_quant_and_compression(tmp_path):
    log = train_mod.main([
        "--arch", "yi-34b", "--smoke", "--steps", "12", "--batch-size", "4",
        "--seq-len", "64", "--log-every", "6", "--lr", "1e-3",
        "--weight-bits", "10", "--data-bits", "12", "--kv-bits", "8",
        "--int8-moments", "--grad-compress"])
    assert np.isfinite(log[-1]["loss"])
    assert log[-1]["loss"] < log[0]["loss"] * 1.5


def test_serve_batched_requests():
    reqs = serve_mod.main([
        "--arch", "qwen2-72b", "--smoke", "--requests", "6",
        "--batch-size", "3", "--prompt-len", "6", "--max-new", "5",
        "--max-len", "64"])
    assert all(len(r.out) == 5 for r in reqs)


def test_serve_quantized_kv_matches_fp_mostly():
    reqs_fp = serve_mod.main([
        "--arch", "deepseek-7b", "--smoke", "--requests", "4",
        "--batch-size", "2", "--prompt-len", "8", "--max-new", "6",
        "--max-len", "64"])
    reqs_q8 = serve_mod.main([
        "--arch", "deepseek-7b", "--smoke", "--requests", "4",
        "--batch-size", "2", "--prompt-len", "8", "--max-new", "6",
        "--max-len", "64", "--kv-bits", "8"])
    # both runs complete with valid token streams; random-init logits are
    # near-uniform so argmax agreement is a weak signal — require it only
    # to be non-trivial
    assert all(len(r.out) == 6 for r in reqs_fp + reqs_q8)
    agree = np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                     for a, b in zip(reqs_fp, reqs_q8)])
    assert agree >= 0.15, agree


def test_paper_pipeline_miniature():
    """The full paper method end-to-end on LeNet at reduced budget:
    train -> uniform baseline -> greedy search -> TR@10% < 0.5."""
    from repro.core.fixedpoint import FixedPointFormat
    from repro.core.policy import PrecisionPolicy
    from repro.core.search import greedy_pareto_search
    from repro.data.synthetic import digits_dataset
    from repro.models.cnn import (LENET, cnn_accuracy, cnn_loss,
                                  cnn_traffic_model, init_cnn)

    spec = LENET
    params = init_cnn(jax.random.PRNGKey(0), spec)
    xs, ys = digits_dataset(1536, seed=0)
    xv, yv = digits_dataset(384, seed=1)
    grad = jax.jit(jax.grad(lambda p, b: cnn_loss(p, b, spec)))
    for i in range(170):
        sl = slice((i * 64) % 1472, (i * 64) % 1472 + 64)
        g = grad(params, {"image": jnp.asarray(xs[sl]),
                          "label": jnp.asarray(ys[sl])})
        params = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params, g)
    base = cnn_accuracy(params, jnp.asarray(xv), jnp.asarray(yv), spec)
    assert base > 0.8

    tm = cnn_traffic_model(spec)
    init = PrecisionPolicy.uniform(spec.layer_names, FixedPointFormat(1, 8),
                                   FixedPointFormat(8, 2))
    res = greedy_pareto_search(
        lambda pol: cnn_accuracy(params, jnp.asarray(xv), jnp.asarray(yv),
                                 spec, pol),
        tm, init, baseline_accuracy=base, batch_size=50, max_steps=25)
    pick = res.select(0.10)
    assert pick is not None
    assert pick.traffic_ratio < 0.5  # >2x traffic cut at 10% tolerance
