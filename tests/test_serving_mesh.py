"""Tensor-parallel serving mesh + sharding-rule tests (launch.mesh
make_serving_mesh, parallel.sharding paged_pool_shardings /
param_shardings inference mode).

Mesh-shape and sharded-vs-single identity cases run in subprocesses with
``--xla_force_host_platform_device_count`` — the device count must be
fixed before jax initializes. Spec rules are pure and test in-process on
the 1-device mesh.
"""
import os
import subprocess
import sys
import textwrap

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_serving_mesh
from repro.parallel.sharding import (paged_pool_shardings, param_shardings,
                                     plan_for_mesh)

jax.config.update("jax_platform_name", "cpu")

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout=420,
           single_thread=False) -> str:
    flags = f"--xla_force_host_platform_device_count={devices}"
    if single_thread:
        flags += (" --xla_cpu_multi_thread_eigen=false "
                  "intra_op_parallelism_threads=1")
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "{flags}"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=_ROOT)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# make_serving_mesh
# ---------------------------------------------------------------------------
def test_serving_mesh_rejects_bad_tp():
    with pytest.raises(ValueError):
        make_serving_mesh(0)
    with pytest.raises(ValueError):
        make_serving_mesh(3)   # 3 does not divide this host's 1 device


def test_serving_mesh_single_device():
    mesh = make_serving_mesh(1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == 1


def test_serving_mesh_shapes_on_8_devices():
    run_py("""
        from repro.launch.mesh import make_serving_mesh
        for tp, want in ((1, (8, 1)), (2, (4, 2)), (8, (1, 8))):
            mesh = make_serving_mesh(tp)
            assert mesh.axis_names == ("data", "model"), mesh.axis_names
            shape = (mesh.shape["data"], mesh.shape["model"])
            assert shape == want, (tp, shape)
        try:
            make_serving_mesh(3)
        except ValueError:
            pass
        else:
            raise AssertionError("tp=3 must not divide 8 devices")
        print("MESH_OK")
    """)


# ---------------------------------------------------------------------------
# Sharding specs (pure rules, 1-device mesh)
# ---------------------------------------------------------------------------
def _plan():
    return plan_for_mesh(make_serving_mesh(1))


def test_paged_pool_specs_shard_head_axis():
    plan = _plan()
    caches = [{"k_pages": jax.ShapeDtypeStruct((10, 8, 2, 16), jax.numpy.int8),
               "v_pages": jax.ShapeDtypeStruct((10, 8, 2, 16), jax.numpy.int8),
               "k_scale": jax.ShapeDtypeStruct((10,), jax.numpy.float32),
               "v_scale": jax.ShapeDtypeStruct((10,), jax.numpy.float32)}]
    sh = paged_pool_shardings(caches, plan)[0]
    # page grids: KV-heads axis (ndim-2) over "model", everything else whole
    assert sh["k_pages"].spec == P(None, None, "model", None)
    assert sh["v_pages"].spec == P(None, None, "model", None)
    # per-page scales replicate (aliased by every shard)
    assert sh["k_scale"].spec == P(None)
    assert sh["v_scale"].spec == P(None)
    # scan-stacked pools: same axis counted from the tail
    stacked = [{"k_pages": jax.ShapeDtypeStruct((3, 10, 8, 2, 16),
                                                jax.numpy.int32)}]
    assert paged_pool_shardings(stacked, plan)[0]["k_pages"].spec \
        == P(None, None, None, "model", None)


def test_paged_pool_specs_divisibility_fallback():
    run_py("""
        from repro.launch.mesh import make_serving_mesh
        from repro.parallel.sharding import paged_pool_shardings, \\
            plan_for_mesh
        from jax.sharding import PartitionSpec as P
        plan = plan_for_mesh(make_serving_mesh(8))   # model axis = 8
        caches = [{"k_pages": jax.ShapeDtypeStruct((10, 8, 2, 16),
                                                   jnp.int8)}]
        # 2 KV heads don't divide tp=8: replicate rather than fail to lower
        assert paged_pool_shardings(caches, plan)[0]["k_pages"].spec \\
            == P(None, None, None, None)
        print("FALLBACK_OK")
    """)


def test_param_specs_inference_strips_fsdp():
    plan = _plan()
    params = {"layers": {"block": {
        "wq": jax.ShapeDtypeStruct((64, 64), jax.numpy.float32)}}}
    train = param_shardings(params, plan)["layers"]["block"]["wq"]
    infer = param_shardings(params, plan,
                            inference=True)["layers"]["block"]["wq"]
    assert train.spec == P("data", "model")
    # serving keeps weights resident: TP-only, no per-token FSDP gathers
    assert infer.spec == P(None, "model")


# ---------------------------------------------------------------------------
# Sharded-vs-single serving identity (the tp=2 replica really is the same
# server — same trace, bitwise-equal token streams)
# ---------------------------------------------------------------------------
def test_tp2_serving_token_identity():
    run_py("""
        jax.config.update("jax_platform_name", "cpu")
        from repro.configs.registry import get_smoke_config
        from repro.launch.mesh import make_serving_mesh
        from repro.launch.serve import BatchedServer, Request
        from repro.models.transformer import init_model

        cfg = get_smoke_config("qwen2-72b")
        params = init_model(jax.random.PRNGKey(0), cfg)

        def mk():
            rng = np.random.default_rng(5)
            return [Request(i, rng.integers(0, cfg.vocab_size,
                                            4 + 3 * i).astype(np.int32),
                            4 + i) for i in range(3)]

        common = dict(batch_size=2, max_len=32, page_size=8, num_pages=10,
                      kv_bits=8)
        single = {r.rid: r for r in
                  BatchedServer(cfg, params, **common).run(mk())}
        mesh = make_serving_mesh(2)
        assert mesh.shape["model"] == 2
        sharded = BatchedServer(cfg, params, mesh=mesh, **common).run(mk())
        for r in sharded:
            assert r.out == single[r.rid].out, (r.rid, r.out,
                                                single[r.rid].out)
            assert r.done
        print("TP_IDENTITY_OK")
    """, devices=2, single_thread=True, timeout=900)
