"""Traffic-at-scale harness tests: arrival-trace determinism, generator
validation, the deadline-miss predictor's decision surface, and the async
pager's byte-identity + token-neutrality contracts.

* generate_trace: equal TraceConfigs produce identical request streams —
  fingerprint-asserted both in-process and across two subprocesses (the
  guarantee the benchmark's replay-both-arms design rests on); structural
  invariants (deadline pricing, shared-prefix pooling, horizon bounds).
* DeadlineMissPredictor: monotone risk in each pressure feature, peak-hold
  hazard decay, the three spec_budget bands, and SGD moving weights toward
  the observed label.
* extract_page_async: resolves to byte-identical PageBlobs vs the sync
  extractor, stays valid after the device page is overwritten, and
  resolve() is idempotent.
* Serving with ``predictor="off"``/``pager_async`` must be token-identical
  to the PR 8 surface (no new kwargs) at kv-bits {0, 8, 4} — subprocess,
  single-threaded XLA, same pattern as the other bitwise-identity suites.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.page_store import extract_page, extract_page_async
from repro.core.traffic import (TenantSpec, TraceConfig, generate_trace,
                                trace_fingerprint)
from repro.launch.scheduler import DeadlineMissPredictor
from repro.runtime.telemetry import MetricsRegistry

jax.config.update("jax_platform_name", "cpu")


def _mix_config(seed=11):
    return TraceConfig(
        seed=seed, horizon=48, rate=0.3, process="bursty", burst_rate=1.8,
        p_enter_burst=0.15, p_exit_burst=0.3, vocab_size=997,
        tenants=(
            TenantSpec("chat", weight=0.7, priority=5, deadline_slack=4,
                       prompt_mean=8.0, prompt_cap=16, max_new_mean=4.0,
                       max_new_cap=6, shared_prefix_len=6, prefix_pool=3),
            TenantSpec("batch", weight=0.3, priority=0, deadline_slack=None,
                       prompt_mean=12.0, prompt_cap=24, max_new_mean=10.0,
                       max_new_cap=16),
        ))


# ---------------------------------------------------------------------------
# Trace generation: determinism + structure
# ---------------------------------------------------------------------------
def test_trace_deterministic_in_process():
    a, b = generate_trace(_mix_config()), generate_trace(_mix_config())
    assert trace_fingerprint(a) == trace_fingerprint(b)
    assert len(a.requests) == len(b.requests) > 0
    for ra, rb in zip(a.requests, b.requests):
        assert (ra.rid, ra.tenant, ra.arrive_step, ra.max_new,
                ra.priority, ra.deadline_step, ra.prefix_id) == \
               (rb.rid, rb.tenant, rb.arrive_step, rb.max_new,
                rb.priority, rb.deadline_step, rb.prefix_id)
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    assert trace_fingerprint(generate_trace(_mix_config(seed=12))) \
        != trace_fingerprint(a)


def test_trace_structural_invariants():
    tr = generate_trace(_mix_config())
    cfg = tr.config
    prefixes = {}
    tenants = {t.name: t for t in cfg.tenants}
    for r in tr.requests:
        t = tenants[r.tenant]
        assert 0 <= r.arrive_step < cfg.horizon
        assert 1 <= r.max_new <= t.max_new_cap
        assert r.prompt.dtype == np.int32
        assert np.all((r.prompt >= 0) & (r.prompt < cfg.vocab_size))
        if t.deadline_slack is None:
            assert r.deadline_step is None
        else:
            assert r.deadline_step == \
                r.arrive_step + r.max_new + t.deadline_slack
        if t.shared_prefix_len > 0:
            assert 0 <= r.prefix_id < t.prefix_pool
            key = (r.tenant, r.prefix_id)
            head = r.prompt[:t.shared_prefix_len]
            if key in prefixes:          # pool entries are shared verbatim
                np.testing.assert_array_equal(head, prefixes[key])
            prefixes[key] = head
        else:
            assert r.prefix_id == -1
    assert {r.tenant for r in tr.requests} == {"chat", "batch"}
    # the bursty mix overloads a small batch on its own numbers
    assert tr.overload_ratio(batch_size=2) > 1.0
    assert tr.burst_steps, "MMPP never entered the burst state"


def test_trace_generator_validation():
    with pytest.raises(ValueError, match="process"):
        generate_trace(TraceConfig(process="fractal"))
    with pytest.raises(ValueError, match="tenant"):
        generate_trace(TraceConfig(tenants=()))
    with pytest.raises(ValueError, match="weights"):
        generate_trace(TraceConfig(tenants=(TenantSpec("a", weight=0.0),)))


_FINGERPRINT_SCRIPT = r"""
from repro.core.traffic import TenantSpec, TraceConfig, generate_trace, \
    trace_fingerprint
cfg = TraceConfig(
    seed=11, horizon=48, rate=0.3, process="bursty", burst_rate=1.8,
    p_enter_burst=0.15, p_exit_burst=0.3, vocab_size=997,
    tenants=(
        TenantSpec("chat", weight=0.7, priority=5, deadline_slack=4,
                   prompt_mean=8.0, prompt_cap=16, max_new_mean=4.0,
                   max_new_cap=6, shared_prefix_len=6, prefix_pool=3),
        TenantSpec("batch", weight=0.3, priority=0, deadline_slack=None,
                   prompt_mean=12.0, prompt_cap=24, max_new_mean=10.0,
                   max_new_cap=16),
    ))
print(trace_fingerprint(generate_trace(cfg)))
"""


def test_trace_fingerprint_across_processes():
    """Same config in two fresh interpreters yields the same sha256 — no
    hidden global RNG or hash-seed dependence in the stream."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    fps = []
    for _ in range(2):
        res = subprocess.run([sys.executable, "-c", _FINGERPRINT_SCRIPT],
                             env=env, capture_output=True, text=True,
                             timeout=300)
        assert res.returncode == 0, res.stdout + res.stderr
        fps.append(res.stdout.strip())
    assert fps[0] == fps[1] and len(fps[0]) == 64
    # and matches the in-process generator on the identical config
    assert fps[0] == trace_fingerprint(generate_trace(_mix_config()))


# ---------------------------------------------------------------------------
# DeadlineMissPredictor decision surface
# ---------------------------------------------------------------------------
def _feat(pred, **kw):
    base = dict(queue_deadlined=0, batch=4, free_frac=1.0, prefill_debt=0,
                debt_cap=32, live_frac=0.0, arrival_ewma=0.0,
                tpot_slowdown=0.0)
    base.update(kw)
    return pred.features(**base)


def test_predictor_risk_monotone_and_bounded():
    p = DeadlineMissPredictor(MetricsRegistry())
    calm = p.risk(_feat(p))
    assert 0.0 < calm < 0.5                 # bias keeps the gate open at rest
    for kw in (dict(queue_deadlined=8), dict(arrival_ewma=2.0),
               dict(free_frac=0.0), dict(prefill_debt=32),
               dict(live_frac=1.0), dict(tpot_slowdown=0.25)):
        assert p.risk(_feat(p, **kw)) > calm, kw
    storm = p.risk(_feat(p, queue_deadlined=8, arrival_ewma=2.0,
                         free_frac=0.0, prefill_debt=32, live_frac=1.0))
    assert storm > p.gate_at                # full pressure crosses the gate
    # features are normalized: saturating the inputs saturates, not explodes
    x = _feat(p, queue_deadlined=10 ** 6, arrival_ewma=10 ** 6,
              prefill_debt=10 ** 6, live_frac=50.0, tpot_slowdown=9.0)
    assert all(-0.25 <= xi <= 1.0 for xi in x)


def test_predictor_hazard_peak_hold_and_budget_bands():
    p = DeadlineMissPredictor(MetricsRegistry())
    storm = _feat(p, queue_deadlined=8, arrival_ewma=2.0, free_frac=0.0,
                  prefill_debt=32, live_frac=1.0)
    r = p.consult(storm)
    assert p.hazard == r > p.gate_at
    assert p.metrics.gauge("sched.miss_risk").value == r
    # calm cycles decay the hazard geometrically but hold the peak memory
    p.consult(_feat(p))
    assert r * p.hazard_decay - 1e-12 <= p.hazard < r
    for _ in range(400):
        p.consult(_feat(p))
    assert p.spec_budget(4) == 4            # decayed back below the gate
    p.hazard = p.gate_at - 0.01
    assert p.spec_budget(4) == 4
    p.hazard = (p.gate_at + (1.0 + p.gate_at) / 2.0) / 2.0   # warning band
    assert p.spec_budget(4) == 1
    p.hazard = 0.99
    assert p.spec_budget(4) == 0


def test_predictor_sgd_moves_toward_label():
    p = DeadlineMissPredictor(MetricsRegistry())
    x = _feat(p, queue_deadlined=4, arrival_ewma=1.0, free_frac=0.4)
    r0 = p.risk(x)
    for _ in range(50):
        p.observe(x, missed=True)
    assert p.risk(x) > r0                   # misses push risk up...
    r1 = p.risk(x)
    for _ in range(50):
        p.observe(x, missed=False)
    assert p.risk(x) < r1                   # ...makes push it back down
    assert p.updates == 100
    assert p.metrics.counter("sched.predictor_updates").value == 100


# ---------------------------------------------------------------------------
# Async page extraction: byte identity with the sync path
# ---------------------------------------------------------------------------
def _filled_pool(container, *, scale_mode="static", seed=0):
    """One layer's pool with pages 1..2 written via the real update path
    (same recipe as test_page_store, so int containers hold genuine
    quantized grids + scales)."""
    import jax.numpy as jnp
    from repro.core.paged_kv import PagedKVLayout, init_paged_pool, \
        paged_update
    rng = np.random.default_rng(seed)
    ps, KV, hd = 4, 2, 16
    layout = PagedKVLayout(num_pages=6, page_size=ps, num_kv_heads=KV,
                           head_dim=hd, container=container)
    pool = init_paged_pool(layout)
    pt = jnp.asarray([[1, 2]], np.int32)
    bits = layout.bits
    for t in range(2 * ps):
        k = jnp.asarray(rng.normal(size=(1, 1, KV, hd)) * (0.1 + 0.2 * t),
                        jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 1, KV, hd)) * 0.4, jnp.float32)
        pool = paged_update(pool, k, v, pt, jnp.asarray([t], np.int32),
                            page_size=ps, container=container,
                            int_bits=2 if bits else None,
                            frac_bits=(bits - 2) if bits else None,
                            scale_mode=scale_mode)
    return pool


@pytest.mark.parametrize("container", ["fp", "int8", "int4"])
def test_extract_page_async_byte_identical(container):
    from repro.core.page_store import inject_page
    caches = [
        (_filled_pool(container, seed=1),),
        ([_filled_pool(container, scale_mode="page" if container != "fp"
                       else "static", seed=2)],),
    ]
    ref = extract_page(caches, 2)
    pending = extract_page_async(caches, 2)
    assert not pending.resolved
    # overwrite the device page BEFORE resolving: the async slices must be
    # functional values, immune to pool reuse
    caches = inject_page(caches, extract_page(caches, 1), 2)
    blob = pending.resolve()
    assert pending.resolved
    assert pending.resolve() is blob        # idempotent
    assert blob.nbytes == ref.nbytes > 0
    for got, want in zip(blob.arrays, ref.arrays):
        for k in want:
            np.testing.assert_array_equal(got[k], want[k])


# ---------------------------------------------------------------------------
# predictor off / pager_async: token-identical to the PR 8 surface
# ---------------------------------------------------------------------------
_PREDICTOR_OFF_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)

def mk():
    rng = np.random.default_rng(13)
    sys_p = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    reqs = []
    for i, L in enumerate([3, 9, 5, 12, 2, 7]):
        p = np.concatenate([sys_p, rng.integers(0, cfg.vocab_size, L)
                            .astype(np.int32)])
        reqs.append(Request(i, p, 4 + (i % 3), priority=i % 2,
                            deadline_step=(None if i % 2 else 30 + 4 * i),
                            arrive_step=2 * i))
    return reqs

for kv_bits in (0, 8, 4):
    base = dict(batch_size=2, max_len=48, kv_bits=kv_bits, page_size=8,
                prefill="bucketed", prefill_bucket=8, prefix_cache="on",
                kv_offload="host", sched="slo", preempt=False)
    seed = BatchedServer(cfg, params, **base)           # PR 8 surface
    out_seed = seed.run(mk())
    off = BatchedServer(cfg, params, metrics="on", predictor="off",
                        pager_async="off", **base)
    out_off = off.run(mk())
    asy = BatchedServer(cfg, params, metrics="on", predictor="off",
                        pager_async="on", **base)
    out_asy = asy.run(mk())
    for a, b, c in zip(out_seed, out_off, out_asy):
        assert a.out == b.out, ("predictor-off", kv_bits, a.rid)
        assert a.out == c.out, ("pager-async", kv_bits, a.rid)
    assert off.predictor is None and asy.predictor is None
    assert asy.pager.async_mode and not off.pager.async_mode
    assert off.tracer.slo_summary()["requests"] == len(out_off)
    print(f"kv_bits={kv_bits} tokens identical across seed/off/async")
print("PREDICTOR_OFF_IDENTITY_OK")
"""


def test_predictor_off_is_token_neutral():
    """``--predictor off --metrics on`` (and the async pager) must be
    token-identical to a PR 8-style server with none of the new kwargs,
    at kv-bits {0, 8, 4} — the telemetry/prediction layer is observe-only
    until the gate is explicitly enabled. Subprocess + single-threaded
    XLA for bitwise-stable logits."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src"),
           os.path.join(os.path.dirname(__file__), "..")])
    res = subprocess.run(
        [sys.executable, "-c", _PREDICTOR_OFF_IDENTITY_SCRIPT],
        env=env, capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "PREDICTOR_OFF_IDENTITY_OK" in res.stdout


def test_predictor_flag_validation():
    from repro.configs.registry import get_smoke_config
    from repro.launch.serve import BatchedServer
    from repro.models.transformer import init_model
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="predictor"):
        BatchedServer(cfg, params, batch_size=2, max_len=32, page_size=8,
                      predictor="on")            # needs sched="slo"
    with pytest.raises(ValueError, match="pager"):
        BatchedServer(cfg, params, batch_size=2, max_len=32, page_size=8,
                      pager_async="on")          # needs kv_offload="host"
    with pytest.raises(ValueError, match="predictor"):
        BatchedServer(cfg, params, batch_size=2, max_len=32, page_size=8,
                      sched="slo", predictor="maybe")
