"""Unit + property tests for the fixed-point core (paper §2.1 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st

from repro.core import (FixedPointFormat, QuantizedTensor, fake_quant,
                        fake_quant_ste, format_params, pack_bits, quantize,
                        dequantize, required_int_bits, unpack_bits)


class TestFormat:
    def test_basic_properties(self):
        f = FixedPointFormat(4, 3)  # Q4.3: 7 bits total
        assert f.total_bits == 7
        assert f.scale == 8.0
        assert f.qmax == 63 and f.qmin == -64
        assert f.max_value == 63 / 8 and f.min_value == -8.0

    def test_validation(self):
        with pytest.raises(ValueError):
            FixedPointFormat(0, 3)
        with pytest.raises(ValueError):
            FixedPointFormat(1, -1)

    def test_parse_roundtrip(self):
        f = FixedPointFormat.parse("Q3.5")
        assert (f.int_bits, f.frac_bits) == (3, 5)
        assert FixedPointFormat.parse(f.short()) == f

    def test_container(self):
        assert FixedPointFormat(4, 4).container_dtype() == jnp.int8
        assert FixedPointFormat(8, 8).container_dtype() == jnp.int16
        assert FixedPointFormat(12, 10).container_dtype() == jnp.int32


class TestFakeQuant:
    def test_exact_grid_values_preserved(self):
        f = FixedPointFormat(4, 3)
        xs = jnp.array([0.0, 0.125, -0.125, 1.0, -8.0, 7.875])
        np.testing.assert_allclose(fake_quant(xs, 4, 3), xs)

    def test_rounding_to_grid(self):
        y = fake_quant(jnp.array([0.06]), 4, 3)  # grid 0.125; 0.06*8=0.48 -> 0
        np.testing.assert_allclose(y, [0.0])
        y = fake_quant(jnp.array([0.07]), 4, 3)  # 0.56 -> 1 -> 0.125
        np.testing.assert_allclose(y, [0.125])

    def test_saturation(self):
        f = FixedPointFormat(3, 2)  # range [-4, 3.75]
        y = fake_quant(jnp.array([100.0, -100.0]), 3, 2)
        np.testing.assert_allclose(y, [f.max_value, f.min_value])

    def test_vectorized_formats(self):
        # per-layer formats as arrays (the lax.scan path)
        x = jnp.full((3,), 0.3)
        i = jnp.array([2.0, 2.0, 2.0])
        fbits = jnp.array([1.0, 3.0, 8.0])
        y = fake_quant(x, i, fbits)
        np.testing.assert_allclose(y, [0.5, 0.25, 0.30078125])

    def test_stochastic_rounding_unbiased(self):
        key = jax.random.PRNGKey(0)
        x = jnp.full((20000,), 0.3)
        y = fake_quant(x, 4, 2, rounding="stochastic", key=key)
        # grid is .25; E[y] should be ~0.3
        assert abs(float(y.mean()) - 0.3) < 5e-3

    def test_ste_gradient(self):
        g = jax.grad(lambda x: fake_quant_ste(x, 4, 3).sum())(jnp.array([0.3, 100.0]))
        np.testing.assert_allclose(g, [1.0, 0.0])  # clipped region has 0 grad

    @given(st.integers(1, 8), st.integers(0, 8),
           st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=1, max_size=32))
    @settings(max_examples=60, deadline=None)
    def test_prop_idempotent_and_bounded(self, i, f, xs):
        fmt = FixedPointFormat(i, f)
        x = jnp.asarray(xs, jnp.float32)
        y = fake_quant(x, i, f)
        # idempotent
        np.testing.assert_allclose(fake_quant(y, i, f), y, rtol=0, atol=0)
        # bounded by format range
        assert float(y.max()) <= fmt.max_value + 1e-6
        assert float(y.min()) >= fmt.min_value - 1e-6
        # error bounded by half resolution inside the range
        inside = (x <= fmt.max_value) & (x >= fmt.min_value)
        err = jnp.abs(jnp.where(inside, x - y, 0.0))
        assert float(err.max()) <= fmt.resolution / 2 + 1e-6

    @given(st.integers(1, 12))
    @settings(max_examples=12, deadline=None)
    def test_prop_monotone(self, i):
        xs = jnp.linspace(-10, 10, 201)
        y = fake_quant(xs, i, 3)
        assert bool(jnp.all(jnp.diff(y) >= 0))


class TestRequiredIntBits:
    def test_values(self):
        assert int(required_int_bits(0.9)) == 1
        assert int(required_int_bits(1.5)) == 2
        assert int(required_int_bits(2.0)) == 2
        assert int(required_int_bits(2.1)) == 3
        assert int(required_int_bits(100.0)) == 8

    def test_covers(self):
        for m in [0.3, 1.0, 3.7, 64.2, 1000.0]:
            i = int(required_int_bits(m))
            assert 2 ** (i - 1) >= m


class TestPacking:
    @given(st.integers(1, 16), st.integers(1, 50), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=60, deadline=None)
    def test_prop_pack_roundtrip(self, bits, n, seed):
        """Round-trip across ALL widths 1..16 (odd widths included) and
        last dims that are not multiples of values_per_word."""
        rng = np.random.default_rng(seed)
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        q = rng.integers(lo, hi + 1, size=(3, n))
        packed, nn = pack_bits(jnp.asarray(q), bits)
        assert packed.dtype == jnp.int32
        vpw = 32 // bits
        assert packed.shape == (3, -(-n // vpw))
        out = unpack_bits(packed, bits, nn)
        assert out.shape == q.shape
        np.testing.assert_array_equal(np.asarray(out), q)

    @pytest.mark.parametrize("bits", list(range(1, 17)))
    def test_sign_extension_at_extremes(self, bits):
        """Both range extremes (and their neighbours) survive the two's-
        complement field round-trip with correct sign extension, on a last
        dim deliberately not a multiple of values_per_word."""
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        vals = sorted({lo, lo + 1, -1, 0, hi - 1, hi})
        vpw = 32 // bits
        n = len(vals) * 3 + (1 if (len(vals) * 3) % vpw == 0 else 0)
        q = np.resize(np.asarray(vals, np.int32), (2, n))
        packed, nn = pack_bits(jnp.asarray(q), bits)
        out = np.asarray(unpack_bits(packed, bits, nn))
        np.testing.assert_array_equal(out, q)
        assert out.min() >= lo and out.max() <= hi

    def test_packed_sizes(self):
        q = jnp.zeros((4, 128))
        packed, _ = pack_bits(q, 4)  # 8 vals/word
        assert packed.shape == (4, 16)
        packed, _ = pack_bits(q, 3)  # 10 vals/word, padded to 130
        assert packed.shape == (4, 13)
        for bits in (1, 5, 7, 9, 11, 13, 15):
            packed, _ = pack_bits(q, bits)
            assert packed.shape == (4, -(-128 // (32 // bits)))


class TestQuantizedTensor:
    def test_roundtrip_unpacked(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)), jnp.float32)
        qt = QuantizedTensor.from_float(x, 2, 6)
        assert qt.data.dtype == jnp.int8
        y = qt.dequantize()
        np.testing.assert_allclose(y, fake_quant(x, 2, 6), atol=1e-7)

    def test_roundtrip_packed(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 40)), jnp.float32)
        qt = QuantizedTensor.from_float(x, 1, 3, pack=True)
        y = qt.dequantize()
        np.testing.assert_allclose(y, fake_quant(x, 1, 3), atol=1e-7)

    def test_footprint(self):
        x = jnp.zeros((128, 128))
        qt4 = QuantizedTensor.from_float(x, 1, 3, pack=True)   # 4 bits
        qt8 = QuantizedTensor.from_float(x, 2, 6)              # int8
        assert abs(qt4.footprint_ratio - 4 / 32) < 1e-6
        assert abs(qt8.footprint_ratio - 8 / 32) < 1e-6

    def test_pytree(self):
        x = jnp.ones((4, 8))
        qt = QuantizedTensor.from_float(x, 2, 5)
        leaves, treedef = jax.tree_util.tree_flatten(qt)
        qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_allclose(qt2.dequantize(), qt.dequantize())
        # jit through it
        f = jax.jit(lambda t: t.dequantize().sum())
        assert float(f(qt)) == float(qt.dequantize().sum())
