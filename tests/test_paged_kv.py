"""Paged quantized KV-cache subsystem tests: allocator invariants, pool
scatter/gather round-trips, and end-to-end serving equivalence (paged server
== dense server, token for token, at kv-bits 0/8/4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.paged_kv import (SCRATCH_PAGE, PageAllocator, PagedCacheSpec,
                                 PagedKVLayout, init_paged_pool,
                                 max_pages_per_seq, paged_gather,
                                 paged_update, pool_bytes)
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------
class TestPageAllocator:
    def test_never_hands_out_scratch(self):
        al = PageAllocator(8)
        got = [al.alloc() for _ in range(al.num_free)]
        assert SCRATCH_PAGE not in got
        assert sorted(got) == list(range(1, 8))

    def test_alloc_free_cycle(self):
        al = PageAllocator(5)
        a, b = al.alloc(), al.alloc()
        assert a != b
        al.free([a])
        assert al.num_free == 3
        c = al.alloc()
        assert c not in (b,)

    def test_exhaustion_raises(self):
        al = PageAllocator(3)
        al.alloc(), al.alloc()
        with pytest.raises(RuntimeError):
            al.alloc()

    def test_double_free_rejected(self):
        al = PageAllocator(4)
        p = al.alloc()
        al.free([p])
        with pytest.raises(ValueError):
            al.free([p])
        with pytest.raises(ValueError):
            al.free([SCRATCH_PAGE])

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            PagedCacheSpec(page_size=0, num_pages=4)
        with pytest.raises(ValueError):
            PagedCacheSpec(page_size=8, num_pages=1)
        assert max_pages_per_seq(33, 8) == 5


# ---------------------------------------------------------------------------
# Pool scatter/gather round-trip
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("container,bits", [("int8", 8), ("int4", 4),
                                            ("fp", 0)])
def test_paged_update_gather_roundtrip(container, bits):
    """Tokens appended through the page table come back (dequantized) in
    logical order, regardless of page-id order."""
    rng = np.random.default_rng(0)
    B, KV, hd, ps, NP = 2, 2, 16, 4, 3
    layout = PagedKVLayout(num_pages=1 + B * NP, page_size=ps,
                           num_kv_heads=KV, head_dim=hd, container=container)
    pool = init_paged_pool(layout)
    ids = np.arange(1, 1 + B * NP)
    rng.shuffle(ids)
    pt = jnp.asarray(ids.reshape(B, NP).astype(np.int32))
    T = NP * ps
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    # append one token at a time at per-row positions (the decode pattern)
    for t in range(T):
        pool = paged_update(pool, k[:, t:t + 1], v[:, t:t + 1], pt,
                            jnp.full((B,), t, jnp.int32), page_size=ps,
                            container=container, int_bits=2, frac_bits=bits - 2
                            if bits else None)
    kg, vg = paged_gather(pool, pt, container=container, head_dim=hd)
    if container == "fp":
        np.testing.assert_allclose(kg, k, atol=1e-6)
        np.testing.assert_allclose(vg, v, atol=1e-6)
    else:
        # values come back on the Q(2, bits-2) grid: error <= half a step
        # after clipping to the representable range [-2, 2 - step]
        step = 2.0 ** -(bits - 2)
        err = np.abs(np.asarray(kg)
                     - np.clip(np.asarray(k), -2.0, 2.0 - step))
        assert err.max() <= step / 2 + 1e-6


@pytest.mark.parametrize("container,bits", [("int8", 8), ("int4", 4)])
def test_page_scale_calibration_tighter_than_static(container, bits):
    """``scale_mode="page"`` (dynamic per-page max-abs calibration) must
    dequantize small-magnitude values with materially lower error than the
    layer's static Q(2, bits-2) grid, including under decode-style
    token-at-a-time appends (which trigger in-place page requantization
    whenever a later token raises the page's scale)."""
    rng = np.random.default_rng(0)
    B, KV, hd, ps, NP = 2, 2, 16, 4, 3
    layout = PagedKVLayout(num_pages=1 + B * NP, page_size=ps,
                           num_kv_heads=KV, head_dim=hd, container=container)
    ids = np.arange(1, 1 + B * NP)
    rng.shuffle(ids)
    pt = jnp.asarray(ids.reshape(B, NP).astype(np.int32))
    T = NP * ps
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)) * 0.12, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)) * 0.12, jnp.float32)
    err = {}
    for mode in ("static", "page"):
        pool = init_paged_pool(layout)
        for t in range(T):
            pool = paged_update(pool, k[:, t:t + 1], v[:, t:t + 1], pt,
                                jnp.full((B,), t, jnp.int32), page_size=ps,
                                container=container, int_bits=2,
                                frac_bits=bits - 2, scale_mode=mode)
        kg, vg = paged_gather(pool, pt, container=container, head_dim=hd)
        err[mode] = max(float(jnp.abs(kg - k).max()),
                        float(jnp.abs(vg - v).max()))
    # static grid: step 2^-(bits-2); page scales track the ~0.5 abs-max
    assert err["page"] < 0.7 * err["static"], err


def test_page_scale_respects_valid_len_masking():
    """Padded chunk tails (bucketed prefill) must neither write pages nor
    inflate any live page's calibrated scale."""
    B, KV, hd, ps = 1, 2, 8, 4
    layout = PagedKVLayout(num_pages=4, page_size=ps, num_kv_heads=KV,
                           head_dim=hd, container="int8")
    pool = init_paged_pool(layout)
    pt = jnp.asarray([[1, 2]], np.int32)
    rng = np.random.default_rng(1)
    small = jnp.asarray(rng.normal(size=(B, 8, KV, hd)) * 0.05, jnp.float32)
    # huge values in the padded tail must not touch the scale
    chunk = small.at[:, 3:].set(100.0)
    pool = paged_update(pool, chunk, chunk, pt, 0, page_size=ps,
                        container="int8", int_bits=2, frac_bits=6,
                        valid_len=3, scale_mode="page")
    kg, _ = paged_gather(pool, pt, container="int8", head_dim=hd)
    np.testing.assert_allclose(np.asarray(kg[:, :3]),
                               np.asarray(small[:, :3]), atol=1e-3)


def test_page_scale_out_of_span_tokens_cannot_corrupt_last_page():
    """In static mode a token past the page-table span harmlessly rewrites
    the clamped last page (uniform scale); under per-page scales that write
    must redirect to scratch instead — the last real page's bytes and scale
    stay intact."""
    B, KV, hd, ps = 1, 2, 8, 4
    layout = PagedKVLayout(num_pages=4, page_size=ps, num_kv_heads=KV,
                           head_dim=hd, container="int8")
    pool = init_paged_pool(layout)
    pt = jnp.asarray([[1, 2]], np.int32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(B, 2 * ps, KV, hd)) * 0.1, jnp.float32)
    pool = paged_update(pool, x, x, pt, 0, page_size=ps, container="int8",
                        int_bits=2, frac_bits=6, scale_mode="page")
    before = {k: np.asarray(v) for k, v in pool.items()}
    huge = jnp.full((B, 1, KV, hd), 50.0, jnp.float32)
    pool = paged_update(pool, huge, huge, pt,
                        jnp.asarray([2 * ps], jnp.int32),  # past the span
                        page_size=ps, container="int8", int_bits=2,
                        frac_bits=6, scale_mode="page")
    for key in ("k_pages", "v_pages", "k_scale", "v_scale"):
        np.testing.assert_array_equal(np.asarray(pool[key])[1:3],
                                      before[key][1:3])


def test_paged_pool_footprint_ratios():
    """Stored pool bytes shrink ~4x (int8) / ~8x (int4) vs fp32 pages."""
    mk = lambda c: pool_bytes(init_paged_pool(PagedKVLayout(
        num_pages=64, page_size=16, num_kv_heads=4, head_dim=64,
        container=c)))
    fp, i8, i4 = mk("fp"), mk("int8"), mk("int4")
    assert 3.5 < fp / i8 < 4.5
    assert 7.0 < fp / i4 < 9.0


# ---------------------------------------------------------------------------
# Serving integration: paged == dense, token for token
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)

def mk():
    rng = np.random.default_rng(7)
    lens = [3, 9, 5, 12, 7, 4]
    return [Request(i, rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    5 + (i % 3)) for i, L in enumerate(lens)]

for kv_bits in (0, 8, 4):
    dense = BatchedServer(cfg, params, batch_size=3, max_len=32,
                          kv_bits=kv_bits)
    out_d = dense.run(mk())
    # prefill="stepwise" isolates the LAYOUT variable: dense == paged must
    # hold bitwise under the same prefill algorithm. Bucketed == stepwise
    # is covered separately in tests/test_serve_fast.py.
    paged = BatchedServer(cfg, params, batch_size=3, max_len=32,
                          kv_bits=kv_bits, page_size=8, prefill="stepwise")
    out_p = paged.run(mk())
    for a, b in zip(out_d, out_p):
        assert a.out == b.out, (kv_bits, a.rid, a.out, b.out)
    assert all(r.done for r in out_p)
    assert paged.allocator.num_free == paged.allocator.num_pages - 1
    print(f"kv_bits={kv_bits} identical ok")
print("PAGED_IDENTITY_OK")
"""


def test_paged_server_matches_dense():
    """BatchedServer with the paged cache produces token-for-token identical
    output to the dense-cache server on a mixed-length request batch, at
    kv-bits 0 / 8 / 4.

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c", _IDENTITY_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "PAGED_IDENTITY_OK" in res.stdout


def test_paged_server_small_pool_frees_per_request(smoke_model):
    """A pool far smaller than batch*max_len worth of pages suffices when
    requests are short — pages recycle as requests complete."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=64,
                        kv_bits=8, page_size=8, num_pages=7)
    # dense equivalent would need 2 * 64 = 128 token-slots; the pool holds
    # 6 usable pages = 48 token-slots, enough for 2 concurrent short reqs
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 4).astype(np.int32), 4)
            for i in range(5)]
    srv.run(reqs)
    assert all(r.done and len(r.out) == 4 for r in reqs)
    assert srv.allocator.num_free == 6
