"""Multi-replica admission front tests (launch.frontend).

* THE identity contract: a 1-replica ``ReplicaFrontend`` must produce
  bitwise-identical token streams / done flags / finish steps to the plain
  ``BatchedServer.run`` surface, at kv-bits {0, 8, 4}, with the full
  serving feature set on (prefix cache, host offload, async pager, SLO
  scheduler). Subprocess with single-threaded XLA — exact token identity
  needs bitwise-equal logits.
* Routing: sticky prefix affinity, rebalance only past the load margin,
  least-loaded for key-less traffic.
* SharedPrefixStore: publish/install round-trip lands one replica's
  cached chains in another's host tier (geometry-namespaced, orphans and
  duplicates skipped without leaking handles).
* aggregate_goodput accounting and make_replicas registry namespacing.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.configs.registry import get_smoke_config
from repro.core.traffic import TenantSpec, TraceConfig, generate_trace
from repro.launch.frontend import (ReplicaFrontend, SharedPrefixStore,
                                   aggregate_goodput, make_replicas,
                                   merged_snapshot, requests_from_trace)
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

jax.config.update("jax_platform_name", "cpu")

_COMMON = dict(batch_size=2, max_len=48, page_size=8, num_pages=12,
               prefix_cache="on", kv_offload="host", sched="slo",
               metrics="on", pager_async="on")


def _trace_cfg(vocab):
    return TraceConfig(
        seed=11, horizon=24, rate=0.3, process="bursty", burst_rate=1.2,
        p_enter_burst=0.2, p_exit_burst=0.3, vocab_size=vocab,
        tenants=(TenantSpec("chat", weight=0.7, priority=5,
                            deadline_slack=6, prompt_mean=8.0,
                            prompt_sigma=0.4, prompt_cap=12,
                            max_new_mean=2.5, max_new_sigma=0.4,
                            max_new_cap=4, shared_prefix_len=6,
                            prefix_pool=2),
                 TenantSpec("bulk", weight=0.3, priority=0,
                            deadline_slack=None, prompt_mean=9.0,
                            prompt_sigma=0.4, prompt_cap=14,
                            max_new_mean=6.0, max_new_sigma=0.3,
                            max_new_cap=8)))


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Identity: 1-replica frontend == the plain server (subprocess, kv-bits
# sweep — the PR's acceptance criterion)
# ---------------------------------------------------------------------------
_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.core.traffic import TenantSpec, TraceConfig, generate_trace
from repro.launch.frontend import ReplicaFrontend, requests_from_trace
from repro.launch.serve import BatchedServer, Request

cfg = get_smoke_config("qwen2-72b")
from repro.models.transformer import init_model
params = init_model(jax.random.PRNGKey(0), cfg)
trace = generate_trace(TraceConfig(
    seed=11, horizon=24, rate=0.3, process="bursty", burst_rate=1.2,
    p_enter_burst=0.2, p_exit_burst=0.3, vocab_size=cfg.vocab_size,
    tenants=(TenantSpec("chat", weight=0.7, priority=5, deadline_slack=6,
                        prompt_mean=8.0, prompt_sigma=0.4, prompt_cap=12,
                        max_new_mean=2.5, max_new_sigma=0.4, max_new_cap=4,
                        shared_prefix_len=6, prefix_pool=2),
             TenantSpec("bulk", weight=0.3, priority=0, deadline_slack=None,
                        prompt_mean=9.0, prompt_sigma=0.4, prompt_cap=14,
                        max_new_mean=6.0, max_new_sigma=0.3,
                        max_new_cap=8))))
assert trace.requests, "empty trace"

for kv_bits in (0, 8, 4):
    common = dict(batch_size=2, max_len=48, page_size=8, num_pages=12,
                  kv_bits=kv_bits, prefix_cache="on", kv_offload="host",
                  sched="slo", metrics="on", pager_async="on")
    plain = BatchedServer(cfg, params, **common)
    pr = {r.rid: r for r in plain.run(
        [Request(t.rid, np.array(t.prompt), t.max_new, priority=t.priority,
                 deadline_step=t.deadline_step, arrive_step=t.arrive_step)
         for t in trace.requests])}
    fe = ReplicaFrontend([BatchedServer(cfg, params, **common)])
    reqs, keys = requests_from_trace(trace)
    fe.run(reqs, keys)
    for r in reqs:
        p = pr[r.rid]
        assert list(r.out) == list(p.out), (kv_bits, r.rid, r.out, p.out)
        assert r.done == p.done and r.finish_step == p.finish_step, \
            (kv_bits, r.rid)
    assert fe.store is None   # inert at one replica
print("FRONTEND_IDENTITY_OK")
"""


def test_one_replica_frontend_is_the_plain_server():
    """Run the kv-bits {0,8,4} identity sweep single-threaded: threaded CPU
    GEMMs are not bitwise deterministic under contention, and exact argmax
    token identity needs bitwise-equal logits."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c", _IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "FRONTEND_IDENTITY_OK" in res.stdout


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------
def _req(rid, arrive=0, n=4):
    rng = np.random.default_rng(100 + rid)
    return Request(rid, rng.integers(0, 50, n).astype(np.int32), 3,
                   arrive_step=arrive)


def test_route_sticky_affinity_and_rebalance(smoke_model):
    cfg, params = smoke_model
    fe = ReplicaFrontend(make_replicas(2, cfg, params, **_COMMON),
                         rebalance_margin=2.0)
    key = ("chat", 0)
    first = fe.route(_req(0), key)
    assert fe.affinity[key] == first
    # sticky while the favored replica stays within the margin
    assert fe.route(_req(1), key) == first
    assert fe.metrics.counter("frontend.affinity_hits").value == 1
    # pile undelivered work onto the sticky replica: past the margin the
    # affinity yields and the key is re-pinned to the other replica
    for i in range(4):
        fe.loops[first].add(_req(10 + i))
    moved = fe.route(_req(2), key)
    assert moved != first and fe.affinity[key] == moved
    assert fe.metrics.counter("frontend.rebalanced").value == 1


def test_route_keyless_prefers_least_loaded(smoke_model):
    cfg, params = smoke_model
    fe = ReplicaFrontend(make_replicas(2, cfg, params, **_COMMON))
    fe.loops[0].add(_req(0))
    fe.loops[0].add(_req(1))
    assert fe.route(_req(2), None) == 1
    assert not fe.affinity          # keyless traffic never pins


# ---------------------------------------------------------------------------
# Shared prefix store
# ---------------------------------------------------------------------------
def test_shared_prefix_store_roundtrip(smoke_model):
    cfg, params = smoke_model
    trace = generate_trace(_trace_cfg(cfg.vocab_size))
    a, b = make_replicas(2, cfg, params, **_COMMON, kv_bits=8)
    # warm replica a's prefix cache alone with the shared-prefix traffic
    reqs, _ = requests_from_trace(trace)
    a.run(reqs)
    chains_a = sum(1 for _ in a.prefix_cache.iter_chain_nodes())
    assert chains_a > 0, "trace produced no cached prefix chains"
    store = SharedPrefixStore()
    assert store.publish(a) == chains_a and len(store) == chains_a
    assert store.publish(a) == 0        # idempotent
    installed = store.install(b)
    assert installed > 0
    chains_b = {tuple(t) for _, t, _ in b.prefix_cache.iter_chain_nodes()}
    assert chains_b == {tuple(t) for _, t, _
                        in a.prefix_cache.iter_chain_nodes()}
    assert store.install(b) == 0        # already cached: no handle churn


def test_shared_store_namespaces_by_geometry(smoke_model):
    cfg, params = smoke_model
    a = make_replicas(1, cfg, params, **_COMMON, kv_bits=8)[0]
    b = make_replicas(1, cfg, params, **_COMMON, kv_bits=4)[0]
    a.run([_req(0, n=9)])
    store = SharedPrefixStore()
    store.publish(a)
    # int4 pool geometry differs: nothing may cross the namespace
    assert store.install(b) == 0


# ---------------------------------------------------------------------------
# Accounting + construction
# ---------------------------------------------------------------------------
def test_aggregate_goodput_accounting():
    def done(rid, finish, deadline=None):
        r = Request(rid, np.array([1, 2]), 1, deadline_step=deadline)
        r.done, r.finish_step = True, finish
        return r
    missed = done(2, finish=9, deadline=5)
    unfinished = Request(3, np.array([1]), 1)
    errored = done(4, finish=2)
    errored.error = "rejected"
    reqs = [done(0, finish=3, deadline=5), done(1, finish=7), missed,
            unfinished, errored]
    assert aggregate_goodput(reqs) == pytest.approx(2 / 5)
    assert aggregate_goodput([]) is None


def test_make_replicas_namespaced_registries(smoke_model):
    cfg, params = smoke_model
    servers = make_replicas(2, cfg, params, **_COMMON)
    for i, srv in enumerate(servers):
        assert any(k.startswith(f"replica{i}.")
                   for k in srv.metrics.snapshot()["gauges"])
    fe = ReplicaFrontend(servers)
    fe.metrics.counter("frontend.routed").inc()
    snap = merged_snapshot(fe)
    assert "frontend.routed" in snap["counters"]
    assert any(k.startswith("replica0.") for k in snap["gauges"])
    assert any(k.startswith("replica1.") for k in snap["gauges"])
    with pytest.raises(ValueError):
        make_replicas(2, cfg, params, registry=object())
    with pytest.raises(ValueError):
        make_replicas(0, cfg, params)
