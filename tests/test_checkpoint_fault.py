"""Checkpointing (atomic/async/quantized) + fault-tolerance supervisor."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.core.fixedpoint import FixedPointFormat
from repro.core.policy import LayerPolicy, PrecisionPolicy
from repro.runtime.fault import (FaultInjection, StragglerMonitor,
                                 TrainSupervisor)

jax.config.update("jax_platform_name", "cpu")


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"layer_000": jax.random.normal(k, (8, 16)),
                       "norm": jnp.ones(16)},
            "opt": {"step": jnp.int32(7), "m": jnp.zeros((8, 16))}}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    st = _state()
    save_checkpoint(d, 42, st, extra={"foo": 1})
    assert latest_step(d) == 42
    step, restored, extra = restore_checkpoint(d, jax.eval_shape(lambda: st))
    assert step == 42 and extra == {"foo": 1}
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(a, b)


def test_async_save_and_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    st = _state()
    threads = [save_checkpoint(d, s, st, async_=True, keep=2)
               for s in (1, 2, 3, 4)]
    for t in threads:
        t.join()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d))
    assert steps == [3, 4]  # keep=2 GC'd the rest


def test_incomplete_checkpoint_is_skipped(tmp_path):
    d = str(tmp_path / "ckpt")
    st = _state()
    save_checkpoint(d, 10, st)
    # simulate a crash mid-save: dir without COMMIT
    os.makedirs(os.path.join(d, "step_000000011"))
    assert latest_step(d) == 10


def test_quantized_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    st = _state()
    pol = PrecisionPolicy(("layer_000",),
                          (LayerPolicy(FixedPointFormat(3, 5), None),))
    save_checkpoint(d, 1, st, policy=pol)
    # container on disk is int8
    npz = np.load(os.path.join(d, "step_000000001", "arrays.npz"))
    assert npz["params::layer_000"].dtype == np.int8
    _, restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: st))
    # dequantized values within the Q(3,5) grid resolution
    np.testing.assert_allclose(restored["params"]["layer_000"],
                               st["params"]["layer_000"], atol=2 ** -5)
    # non-policy leaves exact
    np.testing.assert_array_equal(restored["params"]["norm"],
                                  st["params"]["norm"])


def test_supervisor_restarts_from_checkpoint(tmp_path):
    """Inject failures at chosen steps; training must complete with the
    correct final counter, replaying from the last checkpoint."""
    d = str(tmp_path / "ckpt")
    ckpt = CheckpointManager(d, interval=2)
    fail_at = {5: True, 9: True}
    executed = []

    def step_fn(state, step):
        if fail_at.pop(step, None):
            raise FaultInjection(f"node lost at step {step}")
        executed.append(step)
        return {"x": state["x"] + 1}, {"step": step}

    def save_hook(step, state):
        ckpt.maybe_save(step, state, extra={})

    def restore_fn():
        ckpt.wait()
        step, state, _ = ckpt.restore_latest(
            jax.eval_shape(lambda: {"x": jnp.int32(0)}))
        return step, state

    sup = TrainSupervisor(step_fn=step_fn, save_hook=save_hook,
                          restore_fn=restore_fn, max_restarts=5)
    state, metrics = sup.run({"x": jnp.int32(0)}, 0, 12)
    assert sup.restarts == 2
    assert int(state["x"]) == 12  # every step counted exactly once
    assert len(metrics) >= 12


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def step_fn(state, step):
        raise FaultInjection("always down")

    sup = TrainSupervisor(step_fn=step_fn, save_hook=lambda *a: None,
                          restore_fn=lambda: (0, {}), max_restarts=2)
    with pytest.raises(RuntimeError, match="exceeded"):
        sup.run({}, 0, 5)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, window=50)
    for i in range(20):
        mon.observe(i, 0.1)
    rec = mon.observe(20, 0.5)   # 5x median
    assert rec.flagged
    assert mon.flagged_steps == [20]
    s = mon.summary()
    assert s["steps"] == 21 and s["flagged"] == 1
