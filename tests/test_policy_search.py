"""Tests for PrecisionPolicy, TrafficModel and the two search algorithms.

The search tests use a synthetic differentiable 'network' whose accuracy
response to per-layer precision is known analytically, so we can assert the
paper's qualitative claims (mixed beats uniform at equal accuracy) exactly.
"""
import json

import numpy as np
import pytest
from _compat import given, settings, strategies as st

from repro.core import (FIELDS, FixedPointFormat, LayerPolicy, LayerTraffic,
                        PrecisionPolicy, TrafficModel, greedy_pareto_search,
                        sensitivity_search)


def mk_policy(names=("l1", "l2", "l3")):
    return PrecisionPolicy.uniform(names, FixedPointFormat(2, 8),
                                   FixedPointFormat(8, 2))


def mk_traffic(names=("l1", "l2", "l3")):
    layers = tuple(
        LayerTraffic(n, weight_elems=1000 * (i + 1), data_in_elems=500,
                     data_out_elems=500) for i, n in enumerate(names))
    return TrafficModel(layers)


class TestPolicy:
    def test_uniform_and_access(self):
        p = mk_policy()
        assert len(p) == 3
        assert p["l2"].weight.total_bits == 10

    def test_decrement_and_floor(self):
        p = mk_policy()
        p2 = p.decrement(0, "weight_frac")
        assert p2["l1"].weight.frac_bits == 7
        assert p["l1"].weight.frac_bits == 8  # immutability
        # drive to the floor
        cur = p
        for _ in range(8):
            cur = cur.decrement(0, "weight_frac")
        assert cur["l1"].weight.frac_bits == 0
        assert cur.decrement(0, "weight_frac") is None
        # int floor is 1 (sign bit)
        cur = p
        for _ in range(1):
            cur = cur.decrement(0, "weight_int")
        assert cur.decrement(0, "weight_int") is None

    def test_candidate_moves_count(self):
        p = mk_policy()
        # 3 layers x 4 fields, all above floor
        assert len(p.candidate_moves()) == 12
        base = PrecisionPolicy.fp32_baseline(("a",))
        assert base.candidate_moves() == []

    def test_json_roundtrip(self):
        p = mk_policy().with_field(1, "data_int", 3)
        q = PrecisionPolicy.from_json(p.to_json())
        assert q == p

    def test_stacked_arrays(self):
        p = PrecisionPolicy(
            ("a", "b"),
            (LayerPolicy(FixedPointFormat(2, 6), FixedPointFormat(9, 1)),
             LayerPolicy(None, FixedPointFormat(4, 4))))
        ib, fb, en = p.stacked_arrays("weight")
        assert list(en) == [True, False]
        assert list(ib) == [2.0, 16.0]
        ib, fb, en = p.stacked_arrays("data")
        assert list(fb) == [1.0, 4.0]


class TestTraffic:
    def test_baseline_and_ratio(self):
        t = mk_traffic()
        p = PrecisionPolicy.fp32_baseline(t.names)
        assert t.traffic_ratio(p) == pytest.approx(1.0)
        # uniform 16-bit everywhere => TR 0.5
        p16 = PrecisionPolicy.uniform(t.names, FixedPointFormat(8, 8),
                                      FixedPointFormat(8, 8))
        assert t.traffic_ratio(p16) == pytest.approx(0.5)

    def test_batch_vs_single(self):
        t = mk_traffic()
        w1, d1 = t.accesses(batch_size=10, mode="single")
        w2, d2 = t.accesses(batch_size=10, mode="batch")
        assert d1 == d2 and w1 == 10 * w2  # weights amortized by batching

    def test_mixed_prices_correctly(self):
        names = ("a", "b")
        t = TrafficModel((LayerTraffic("a", 100, 0, 0),
                          LayerTraffic("b", 0, 50, 50)))
        p = PrecisionPolicy(
            names,
            (LayerPolicy(FixedPointFormat(1, 7), None),       # W 8 bits
             LayerPolicy(None, FixedPointFormat(2, 2))))      # D 4 bits
        bits = t.traffic_bits(p)
        assert bits == 100 * 8 + 0 * 32 + 100 * 4


# ---------------------------------------------------------------------------
# Synthetic search target: accuracy = 1 - sum_l sens_l * err_l(policy), where
# err grows as bits shrink. Layer sensitivities differ by 16x so the optimal
# mixed config is very non-uniform — exactly the paper's Fig. 3 situation.
# ---------------------------------------------------------------------------
def synthetic_eval(sens):
    def eval_fn(policy: PrecisionPolicy) -> float:
        loss = 0.0
        for s, lp in zip(sens, policy.layers):
            for fmt, need_i in ((lp.weight, 2), (lp.data, 6)):
                if fmt is None:
                    continue
                # range error if I too small; resolution error from F
                loss += s * (4.0 * max(0, need_i - fmt.int_bits)
                             + 2.0 ** (-fmt.frac_bits))
        return max(0.0, 1.0 - 0.05 * loss)
    return eval_fn


class TestGreedySearch:
    def test_reduces_traffic_within_tolerance(self):
        names = ("l1", "l2", "l3", "l4")
        sens = [2.0, 0.125, 0.5, 0.125]
        ev = synthetic_eval(sens)
        t = TrafficModel(tuple(LayerTraffic(n, 4000, 1000, 1000) for n in names))
        init = PrecisionPolicy.uniform(names, FixedPointFormat(2, 10),
                                       FixedPointFormat(6, 6))
        res = greedy_pareto_search(ev, t, init, max_steps=60)
        sel = res.select(0.01)
        assert sel is not None
        assert sel.traffic_ratio < 0.45  # big reduction at 1% tolerance
        assert sel.accuracy >= res.baseline_accuracy * 0.99

    def test_mixed_beats_uniform(self):
        """The paper's headline: per-layer beats one-size-fits-all."""
        names = ("a", "b", "c")
        sens = [4.0, 0.1, 0.1]
        ev = synthetic_eval(sens)
        t = TrafficModel(tuple(LayerTraffic(n, 10000, 2000, 2000) for n in names))
        init = PrecisionPolicy.uniform(names, FixedPointFormat(2, 12),
                                       FixedPointFormat(6, 6))
        res = greedy_pareto_search(ev, t, init, max_steps=80)
        sel = res.select(0.02)
        # find best *uniform* config meeting the same tolerance
        best_uniform = None
        for wb in range(1, 13):
            for db in range(0, 7):
                p = PrecisionPolicy.uniform(names, FixedPointFormat(2, wb),
                                            FixedPointFormat(6, db))
                if ev(p) >= res.baseline_accuracy * 0.98:
                    tr = t.traffic_ratio(p)
                    if best_uniform is None or tr < best_uniform:
                        best_uniform = tr
        assert sel.traffic_ratio < best_uniform  # mixed strictly better

    def test_pareto_is_nondominated(self):
        names = ("a", "b")
        ev = synthetic_eval([1.0, 0.2])
        t = mk_traffic(names[:2]) if False else TrafficModel(
            (LayerTraffic("a", 100, 10, 10), LayerTraffic("b", 100, 10, 10)))
        init = PrecisionPolicy.uniform(names, FixedPointFormat(2, 8),
                                       FixedPointFormat(6, 4))
        res = greedy_pareto_search(ev, t, init, max_steps=40)
        front = res.pareto()
        for i in range(1, len(front)):
            assert front[i].accuracy > front[i - 1].accuracy
            assert front[i].traffic_ratio > front[i - 1].traffic_ratio


class TestSensitivitySearch:
    def test_matches_greedy_quality_fewer_evals(self):
        names = tuple(f"l{i}" for i in range(6))
        sens = [2.0, 1.0, 0.5, 0.25, 0.125, 0.125]
        ev = synthetic_eval(sens)
        t = TrafficModel(tuple(LayerTraffic(n, 5000, 1000, 1000) for n in names))
        init = PrecisionPolicy.uniform(names, FixedPointFormat(2, 10),
                                       FixedPointFormat(6, 6))
        g = greedy_pareto_search(ev, t, init, max_steps=100)
        s = sensitivity_search(ev, t, init, tolerance=0.01, max_steps=200)
        gs, ss = g.select(0.01), s.select(0.01)
        assert ss is not None and gs is not None
        assert ss.traffic_ratio <= gs.traffic_ratio * 1.15  # within 15%
        assert s.evaluations < g.evaluations  # and much cheaper

    def test_respects_tolerance(self):
        names = ("a", "b", "c")
        ev = synthetic_eval([1.0, 0.3, 0.1])
        t = TrafficModel(tuple(LayerTraffic(n, 1000, 100, 100) for n in names))
        init = PrecisionPolicy.uniform(names, FixedPointFormat(2, 10),
                                       FixedPointFormat(6, 6))
        res = sensitivity_search(ev, t, init, tolerance=0.05)
        final = res.trajectory[-1]
        assert final.accuracy >= res.baseline_accuracy * 0.95
