"""Tiered page store tests: demote/promote byte-identity for every
container (fp / int8 / lane-packed int4, static and per-page scales), host
tier accounting + capacity, allocator pressure callbacks, and the snapshot
format round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.page_store import (HostPageStore, PageBlob, TieredPager,
                                   cache_geometry, extract_page, inject_page,
                                   load_prefix_snapshot,
                                   save_prefix_snapshot)
from repro.core.paged_kv import (OutOfPagesError, PageAllocator,
                                 PagedKVLayout, caches_kv_bytes,
                                 init_paged_pool, iter_kv_pools,
                                 paged_update)

jax.config.update("jax_platform_name", "cpu")


def _filled_pool(container, *, scale_mode="static", seed=0, num_pages=6,
                 ps=4, KV=2, hd=16, pages=(1, 2), tokens=None):
    """One layer's pool with ``pages`` written via the real update path
    (so int containers hold genuine quantized grids + scales). ``pages``
    may be non-monotonic (a fragmented table); ``tokens`` < len(pages)*ps
    leaves the last page partially written."""
    rng = np.random.default_rng(seed)
    layout = PagedKVLayout(num_pages=num_pages, page_size=ps,
                           num_kv_heads=KV, head_dim=hd, container=container)
    pool = init_paged_pool(layout)
    pt = jnp.asarray([list(pages)], np.int32)
    bits = layout.bits
    for t in range(len(pages) * ps if tokens is None else tokens):
        k = jnp.asarray(rng.normal(size=(1, 1, KV, hd)) * (0.1 + 0.2 * t),
                        jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 1, KV, hd)) * 0.4, jnp.float32)
        pool = paged_update(pool, k, v, pt, jnp.asarray([t], jnp.int32),
                            page_size=ps, container=container,
                            int_bits=2 if bits else None,
                            frac_bits=(bits - 2) if bits else None,
                            scale_mode=scale_mode)
    return pool


def _stacked(pool, n=3):
    """Broadcast a pool to the stacked (layers, NP, ...) layout."""
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape) + 0, pool)


def _page_bytes(caches, page):
    out = []
    for pool, axis in iter_kv_pools(caches):
        idx = (slice(None), page) if axis == 1 else (page,)
        out.append({k: np.asarray(pool[k][idx]) for k in pool})
    return out


# ---------------------------------------------------------------------------
# extract -> inject round trip is byte-identical, every container/layout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("container", ["fp", "int8", "int4"])
@pytest.mark.parametrize("scale_mode", ["static", "page"])
def test_swap_round_trip_byte_identical(container, scale_mode):
    """demote->promote preserves every stored byte AND the per-page dequant
    scales, for packed int containers and dynamic per-page calibration —
    the bitwise foundation of preemption resume and prefix persistence."""
    if container == "fp" and scale_mode == "page":
        pytest.skip("page-scale calibration applies to int containers")
    # mixed structure: one stacked multi-layer entry + one per-period list
    caches = [
        (_stacked(_filled_pool(container, scale_mode=scale_mode, seed=1)),),
        ([_filled_pool(container, scale_mode=scale_mode, seed=2)],),
    ]
    src, dst = 2, 4
    before_src = _page_bytes(caches, src)
    blob = extract_page(caches, src)
    assert blob.nbytes > 0
    # inject into a DIFFERENT page of the same pools (the promote path
    # never gets the same physical page back)
    caches2 = inject_page(caches, blob, dst)
    after_dst = _page_bytes(caches2, dst)
    for b, a in zip(before_src, after_dst):
        for k in ("k_pages", "v_pages", "k_scale", "v_scale"):
            np.testing.assert_array_equal(b[k], a[k])
    # extraction was non-destructive and inject didn't disturb the source
    for b, a in zip(before_src, _page_bytes(caches2, src)):
        for k in b:
            np.testing.assert_array_equal(b[k], a[k])


def test_extract_through_host_store_survives_page_reuse():
    """The blob is a HOST copy: freeing + rewriting the device page must not
    corrupt a parked blob (preempted pages outlive their page ids)."""
    caches = [(_filled_pool("int8", seed=3),)]
    blob = extract_page(caches, 1)
    snap = [{k: a.copy() for k, a in rec.items()} for rec in blob.arrays]
    host = HostPageStore()
    h = host.put(blob)
    # overwrite the device page (simulates reuse by another request)
    caches = inject_page(caches, extract_page(caches, 2), 1)
    got = host.pop(h)
    for rec, ref in zip(got.arrays, snap):
        for k in rec:
            np.testing.assert_array_equal(rec[k], ref[k])
    assert host.num_pages == 0 and host.nbytes == 0


# ---------------------------------------------------------------------------
# Host tier accounting + capacity
# ---------------------------------------------------------------------------
def test_host_store_accounting_and_capacity():
    host = HostPageStore(max_pages=2)
    blob = extract_page([(_filled_pool("int4"),)], 1)
    h1 = host.put(blob)
    h2 = host.put(blob)
    assert host.num_pages == 2 and host.nbytes == 2 * blob.nbytes
    assert not host.has_room(1)
    with pytest.raises(RuntimeError, match="host page tier full"):
        host.put(blob)
    host.drop(h1)
    assert host.has_room(1) and host.drops == 1
    host.pop(h2)
    assert host.num_pages == 0 and host.nbytes == 0
    assert host.peak_pages == 2 and host.peak_bytes == 2 * blob.nbytes
    # int4 blobs report their packed container
    assert set(PageBlob(blob.arrays).bytes_by_container()) == {"int4"}


def test_caches_kv_bytes_per_container_split():
    caches = [
        (_stacked(_filled_pool("int8")),),
        ([_filled_pool("int4"), _filled_pool("fp")],),
    ]
    split = caches_kv_bytes(caches)
    assert set(split) == {"int8", "int4", "fp"}
    assert all(v > 0 for v in split.values())
    # packed int4 stores 8 values per int32 word: strictly below the int8
    # pool of the same logical shape, even with 3 stacked int8 layers
    assert split["int4"] < split["int8"]


# ---------------------------------------------------------------------------
# TieredPager demote/promote against a live allocator
# ---------------------------------------------------------------------------
def test_pager_demote_promote_round_trip():
    state = {"caches": [(_filled_pool("int8", num_pages=8),)]}
    al = PageAllocator(8)
    host = HostPageStore()
    pager = TieredPager(al, host, lambda: state["caches"],
                        lambda c: state.update(caches=c))
    page = al.alloc()
    # write something recognizable into the page we own
    state["caches"] = inject_page(state["caches"],
                                  extract_page(state["caches"], 2), page)
    ref = _page_bytes(state["caches"], page)
    h = pager.demote(page)
    assert al.refcount(page) == 0          # device reference released
    assert host.num_pages == 1
    new_page = pager.promote(h)
    assert al.refcount(new_page) == 1      # caller owns the promoted page
    assert host.num_pages == 0
    for b, a in zip(ref, _page_bytes(state["caches"], new_page)):
        for k in b:
            np.testing.assert_array_equal(b[k], a[k])
    assert pager.demotions == 1 and pager.promotions == 1


# ---------------------------------------------------------------------------
# Allocator pressure callbacks + host inventory reporting
# ---------------------------------------------------------------------------
def test_allocator_pressure_callbacks_fire_in_order_after_reclaim():
    al = PageAllocator(3)                  # 2 usable
    calls = []
    freed_pages = []

    def reclaim(n):
        calls.append("reclaim")
        return 0

    def cb(n):
        calls.append("pressure")
        if freed_pages:
            al.free([freed_pages.pop()])
        return 1

    al.reclaim = reclaim
    al.add_pressure(cb)
    p1, p2 = al.alloc(), al.alloc()
    freed_pages.append(p1)
    p3 = al.alloc()                        # empty free list -> hooks fire
    assert calls == ["reclaim", "pressure"]
    assert p3 == p1
    with pytest.raises(OutOfPagesError):
        al.alloc()                         # hooks can't help: raises
    assert calls == ["reclaim", "pressure", "reclaim", "pressure"]
    al.free([p2, p3])


def test_out_of_pages_reports_host_inventory():
    al = PageAllocator(2)                  # 1 usable
    al.host_inventory = lambda: 7
    al.alloc()
    with pytest.raises(OutOfPagesError) as ei:
        al.alloc()
    assert ei.value.host_pages == 7
    assert "7 host-tier" in str(ei.value)


# ---------------------------------------------------------------------------
# Snapshot format round trip
# ---------------------------------------------------------------------------
def test_snapshot_save_load_round_trip(tmp_path):
    caches = [(_filled_pool("int4"),), ([_filled_pool("int8")],)]
    geo = cache_geometry(caches)
    entries = [
        ("int8|scale=static", [1, 2, 3, 4], extract_page(caches, 1)),
        ("int8|scale=static", [1, 2, 3, 4, 9], extract_page(caches, 2)),
        ("uniform4|scale=page", [5], extract_page(caches, 2)),
    ]
    path = str(tmp_path / "snap.npz")
    assert save_prefix_snapshot(path, entries, page_size=4,
                                geometry=geo) == 3
    meta, loaded = load_prefix_snapshot(path)
    assert meta["page_size"] == 4 and meta["geometry"] == geo
    assert [(k, t) for k, t, _ in loaded] == [(k, t) for k, t, _ in entries]
    for (_, _, a), (_, _, b) in zip(entries, loaded):
        assert len(a.arrays) == len(b.arrays)
        for ra, rb in zip(a.arrays, b.arrays):
            for f in ("k", "v", "ks", "vs"):
                np.testing.assert_array_equal(ra[f], rb[f])
                assert ra[f].dtype == rb[f].dtype


def test_snapshot_path_without_npz_extension_round_trips(tmp_path):
    """np.savez appends '.npz' to bare filenames; save/load normalize
    through snapshot_path so a bare --prefix-snapshot path still restores
    on the next run instead of silently never matching."""
    from repro.core.page_store import snapshot_path
    caches = [(_filled_pool("int8"),)]
    bare = str(tmp_path / "kvsnap")       # no extension
    save_prefix_snapshot(bare, [("k", [1, 2], extract_page(caches, 1))],
                         page_size=4, geometry=cache_geometry(caches))
    import os
    assert os.path.exists(snapshot_path(bare))
    meta, loaded = load_prefix_snapshot(bare)   # bare path loads too
    assert len(loaded) == 1 and meta["page_size"] == 4


# ---------------------------------------------------------------------------
# Online requantization (fp -> int8 -> int4) + the quant tier store
# ---------------------------------------------------------------------------
from repro.core.page_store import (QuantTierStore, narrower_container,
                                   requantize_blob, requantize_page,
                                   widen_blob)
from repro.core.page_store import _dequant_plane, _rec_container, \
    _rec_head_dim


def _deq(rec):
    """Dequantized (k, v) float planes of one blob record."""
    c, hd = _rec_container(rec), _rec_head_dim(rec)
    return (_dequant_plane(rec["k"], rec["ks"], c, hd),
            _dequant_plane(rec["v"], rec["vs"], c, hd))


def test_narrower_container_ladder_and_floors():
    assert narrower_container("fp", head_dim=16) == "int8"
    assert narrower_container("int8", head_dim=16) == "int4"
    assert narrower_container("int4", head_dim=16) == "int4"   # floor
    # floor_bits=8 stops the descent at int8
    assert narrower_container("int8", head_dim=16, floor_bits=8) == "int8"
    assert narrower_container("fp", head_dim=16, floor_bits=8) == "int8"
    # a head dim int4 lane-packing cannot express floors at int8
    assert narrower_container("int8", head_dim=12) == "int8"
    assert narrower_container("fp", head_dim=12) == "int8"


@pytest.mark.parametrize("container", ["fp", "int8"])
@pytest.mark.parametrize("scale_mode", ["static", "page"])
def test_requantize_one_step_error_bounded(container, scale_mode):
    """One ladder step loses at most half an LSB of the freshly calibrated
    max-abs grid, for every source container and scale mode — including a
    FRAGMENTED page table (extraction is page-id addressed)."""
    if container == "fp" and scale_mode == "page":
        pytest.skip("page-scale calibration applies to int containers")
    caches = [(_filled_pool(container, scale_mode=scale_mode, seed=5,
                            pages=(4, 2)),)]          # fragmented table
    blob, narrowed = requantize_page(caches, 2, steps=1)
    assert narrowed == len(blob.arrays)
    tgt = "int8" if container == "fp" else "int4"
    qmax = {"int8": 127.0, "int4": 7.0}[tgt]
    ref = extract_page(caches, 2)
    for before, after in zip(ref.arrays, blob.arrays):
        assert _rec_container(after) == tgt
        for want, got in zip(_deq(before), _deq(after)):
            amax = np.max(np.abs(want))
            assert np.max(np.abs(want - got)) <= amax / (2 * qmax) * 1.001


def test_requantize_int4_already_at_floor_passes_through():
    caches = [(_filled_pool("int4", seed=6),)]
    blob, narrowed = requantize_page(caches, 1, steps=1)
    assert narrowed == 0
    ref = extract_page(caches, 1)
    for a, b in zip(ref.arrays, blob.arrays):
        for f in ("k", "v", "ks", "vs"):
            np.testing.assert_array_equal(a[f], b[f])


def test_requantize_steps_none_reaches_floor_and_shrinks():
    caches = [(_filled_pool("fp", seed=7),)]
    one, n1 = requantize_page(caches, 1, steps=1)      # fp -> int8
    full, n2 = requantize_page(caches, 1, steps=None)  # fp -> int4
    assert n1 == n2 == len(one.arrays)
    assert all(_rec_container(r) == "int8" for r in one.arrays)
    assert all(_rec_container(r) == "int4" for r in full.arrays)
    assert full.nbytes < one.nbytes < extract_page(caches, 1).nbytes
    # floor_bits=8 floors the full descent at int8
    floored, _ = requantize_page(caches, 1, steps=None, floor_bits=8)
    assert all(_rec_container(r) == "int8" for r in floored.arrays)


def test_requantize_partial_page_masks_stale_slots():
    """valid_len zeroes token slots past the written count BEFORE
    calibration: a partial last page must not let stale garbage inflate
    the fresh max-abs scale (nor survive into the narrowed grid)."""
    caches = [(_filled_pool("fp", seed=8, pages=(1, 2)),)]   # 2 full pages
    # page 2 fully written; pretend only 2 of 4 tokens are valid
    blob, _ = requantize_page(caches, 2, steps=1, valid_len=2)
    masked, _ = requantize_page(
        [(_filled_pool("fp", seed=8, pages=(1, 2), tokens=4 + 2),)],
        2, steps=1)
    for rec, ref in zip(blob.arrays, masked.arrays):
        k, v = _deq(rec)
        assert np.all(k[..., 2:, :, :] == 0) and np.all(v[..., 2:, :, :]
                                                        == 0)
        # scale calibrated over the valid slots only: identical to a pool
        # where those slots were never written
        np.testing.assert_allclose(rec["ks"], ref["ks"], rtol=1e-6)


def test_widen_blob_recalibrates_scales():
    """Widening is exact on the grid AND recalibrates the page scale to
    the target container's granularity: int4 -> int8 rescales the grid by
    16 and the scale by 1/16 (bit-identical dequant, int8-step scale for
    later page-scale extensions); any grid -> fp keeps the grid as floats
    with its scale CARRIED (dequant stays a float32 gather-time multiply,
    never folded at rest)."""
    int8_caches = [(_filled_pool("int8", seed=9),)]
    narrowed, _ = requantize_page(int8_caches, 1, steps=1)   # int4 blob
    wide = widen_blob(narrowed, int8_caches)
    for nrec, wrec in zip(narrowed.arrays, wide.arrays):
        assert _rec_container(wrec) == "int8"
        np.testing.assert_array_equal(wrec["ks"],
                                      np.asarray(nrec["ks"]) / 16)
        assert np.max(np.abs(wrec["k"])) <= 112    # 7 * 16 fits int8
        for a, b in zip(_deq(nrec), _deq(wrec)):
            np.testing.assert_array_equal(a, b)    # power-of-2: bitwise
    fp_caches = [(_filled_pool("fp", seed=9),)]
    narrowed_fp, _ = requantize_page(fp_caches, 1, steps=1)
    wide_fp = widen_blob(narrowed_fp, fp_caches)
    for nrec, wrec in zip(narrowed_fp.arrays, wide_fp.arrays):
        assert _rec_container(wrec) == "fp"
        np.testing.assert_array_equal(wrec["ks"], nrec["ks"])
        np.testing.assert_array_equal(wrec["vs"], nrec["vs"])
        for a, b in zip(_deq(nrec), _deq(wrec)):
            np.testing.assert_allclose(a, b, atol=1e-6)
    # injecting the widened blob round-trips through the real pool
    caches2 = inject_page(fp_caches, wide_fp, 4)
    got = extract_page(caches2, 4)
    for a, b in zip(wide_fp.arrays, got.arrays):
        for f in ("k", "v", "ks", "vs"):
            np.testing.assert_array_equal(a[f], b[f])


def test_fp_restore_scale_roundtrip_recycle_and_cow():
    """A quant-tier restore into an fp pool carries a NON-unit page scale;
    the read path dequantizes it correctly, a CoW copy folds it into unit
    scale for the extender, and recycling the page with fresh fp writes
    resets the stale scale at the page's first write."""
    from repro.core.paged_kv import copy_pool_pages, paged_gather
    ps, KV, hd = 4, 2, 16
    pool = _filled_pool("fp", seed=13, num_pages=8, ps=ps, KV=KV, hd=hd)
    caches = [(pool,)]
    narrowed, _ = requantize_page(caches, 1, steps=1)        # int8 blob
    want = [_deq(r) for r in narrowed.arrays]
    caches = inject_page(caches, widen_blob(narrowed, caches), 5)
    rec = extract_page(caches, 5).arrays[0]
    assert not np.allclose(rec["ks"], 1.0)                   # scale carried
    pool = caches[0][0]
    pt = jnp.asarray([[5]], np.int32)
    k, v = paged_gather(pool, pt, container="fp")            # read path
    np.testing.assert_allclose(np.asarray(k)[0], want[0][0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(v)[0], want[0][1], atol=1e-6)
    # CoW: the copy folds to unit scale, values preserved
    pool2 = copy_pool_pages(pool, 5, 6)
    np.testing.assert_array_equal(np.asarray(pool2["k_scale"][6]), 1.0)
    k2, _ = paged_gather(pool2, jnp.asarray([[6]], np.int32), container="fp")
    np.testing.assert_allclose(np.asarray(k2), np.asarray(k), atol=1e-6)
    # recycle: a fresh fp write at offset 0 resets the stale scale
    rng = np.random.default_rng(14)
    knew = jnp.asarray(rng.normal(size=(1, 1, KV, hd)), jnp.float32)
    pool3 = paged_update(pool, knew, knew, pt,
                         jnp.asarray([0], jnp.int32), page_size=ps,
                         container="fp")
    np.testing.assert_array_equal(np.asarray(pool3["k_scale"][5]), 1.0)
    k3, _ = paged_gather(pool3, pt, container="fp")
    np.testing.assert_array_equal(np.asarray(k3)[0, 0], np.asarray(knew)[0, 0])


def test_quant_tier_park_deepen_restore_accounting():
    state = {"caches": [(_filled_pool("fp", seed=10, num_pages=8),)]}
    tier = QuantTierStore(lambda: state["caches"],
                          lambda c: state.update(caches=c), pages=2)
    # capacity quoted in FLOOR (int4) page equivalents; an int8-parked
    # page costs roughly two of them
    assert tier.page_bytes_floor < tier.page_bytes_step
    blob = tier.requantize(1)
    assert blob is not None and tier.has_room(blob)
    h1 = tier.put(blob)
    assert tier.num_pages == 1 and tier.nbytes == blob.nbytes
    assert set(tier.bytes_by_container()) == {"int8"}
    # deepen frees bytes (int8 -> int4)
    nb0 = tier.nbytes
    freed = tier.deepen(h1)
    assert freed > 0 and tier.nbytes == nb0 - freed
    assert tier.deepen(h1) == 0                      # already at the floor
    assert set(tier.bytes_by_container()) == {"int4"}
    # restore widens into a fresh page; the dequant values survive
    want = [_deq(r) for r in tier.export(h1).arrays]
    tier.restore(h1, 5)
    assert tier.num_pages == 0 and tier.nbytes == 0
    got = extract_page(state["caches"], 5)
    for (wk, wv), rec in zip(want, got.arrays):
        k, v = _deq(rec)
        np.testing.assert_allclose(wk, k, atol=1e-6)
        np.testing.assert_allclose(wv, v, atol=1e-6)
    assert tier.puts == 1 and tier.pops == 1 and tier.deepens == 1


def test_quant_tier_byte_budget_enforced():
    state = {"caches": [(_filled_pool("fp", seed=11, num_pages=8),)]}
    tier = QuantTierStore(lambda: state["caches"],
                          lambda c: state.update(caches=c), pages=2)
    b1 = tier.requantize(1)
    h1 = tier.put(b1)                    # one int8 page ~ 2 int4 equivalents
    b2 = tier.requantize(2)
    assert not tier.has_room(b2)
    with pytest.raises(RuntimeError, match="byte budget"):
        tier.put(b2)
    # deepening the parked page makes exactly enough room for an int4
    tier.deepen(h1)
    b2d, _ = requantize_blob(b2, steps=None)
    assert tier.has_room(b2d)
    h2 = tier.put(b2d)
    tier.drop(h1)
    tier.drop(h2)
    assert tier.num_pages == 0 and tier.nbytes == 0 and tier.drops == 2


def test_quant_tier_rejects_pools_with_nothing_to_narrow():
    state = {"caches": [(_filled_pool("int4", seed=12),)]}
    with pytest.raises(ValueError, match="nothing to narrow"):
        QuantTierStore(lambda: state["caches"], lambda c: None, pages=2)
    state8 = {"caches": [(_filled_pool("int8", seed=12),)]}
    with pytest.raises(ValueError, match="nothing to narrow"):
        QuantTierStore(lambda: state8["caches"], lambda c: None, pages=2,
                       floor_bits=8)


def test_cache_geometry_detects_mismatch():
    a = cache_geometry([(_filled_pool("int8"),)])
    b = cache_geometry([(_filled_pool("int4"),)])
    c = cache_geometry([(_filled_pool("int8", hd=8),)])
    # page-count differences do NOT change the geometry (pools may be
    # sized differently across restarts)...
    d = cache_geometry([(_filled_pool("int8", num_pages=9),)])
    assert a == d
    # ...but container and shape differences do
    assert a != b and a != c
