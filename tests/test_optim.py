"""Optimizer + schedules + gradient-compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import (CompressionConfig, compress_gradients,
                                  error_feedback_init)
from repro.optim.schedule import constant_lr, cosine_warmup, linear_warmup

jax.config.update("jax_platform_name", "cpu")


def _quadratic_problem():
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3), "m": jnp.ones((2, 3))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2) * 0.1

    return params, loss, target


@pytest.mark.parametrize("quantized", [False, True])
def test_adamw_converges_on_quadratic(quantized):
    cfg = AdamWConfig(weight_decay=0.0, quantize_moments=quantized)
    params, loss, target = _quadratic_problem()
    state = adamw_init(params, cfg)
    step = jax.jit(lambda p, s: adamw_update(p, jax.grad(loss)(p), s, 0.05,
                                             cfg))
    for _ in range(400):
        params, state, _ = step(params, state)
    np.testing.assert_allclose(params["w"], target, atol=0.05)
    assert float(jnp.abs(params["m"]).max()) < 0.05


def test_quantized_moments_are_int8():
    cfg = AdamWConfig(quantize_moments=True)
    params = {"w": jnp.ones((4, 8))}
    state = adamw_init(params, cfg)
    assert state["m"]["w"]["q"].dtype == jnp.int8
    assert state["v"]["w"]["q"].dtype == jnp.int8
    # footprint: int8 q + one fp32 scale per row
    assert state["m"]["w"]["q"].size == 32
    assert state["m"]["w"]["scale"].size == 4


def test_grad_clipping():
    cfg = AdamWConfig(grad_clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    big = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(params, big, state, 0.1, cfg)
    assert float(metrics["grad_norm"]) > 100
    assert float(metrics["clip"]) < 0.01


def test_schedules():
    f = linear_warmup(1.0, 10)
    assert float(f(jnp.int32(0))) == pytest.approx(0.1)
    assert float(f(jnp.int32(9))) == pytest.approx(1.0)
    g = cosine_warmup(1.0, 10, 110, final_frac=0.1)
    assert float(g(jnp.int32(109))) == pytest.approx(0.1, abs=0.02)
    assert float(constant_lr(0.3)(jnp.int32(5))) == pytest.approx(0.3)


def test_compress_gradients_error_feedback():
    cfg = CompressionConfig(bits=8, error_feedback=True)
    grads = {"w": jnp.asarray([0.001, 1.0, -0.5, 0.3])}
    res = error_feedback_init(grads)
    # single step: small value may vanish under int8 quantization...
    c1, res1 = compress_gradients(grads, res, cfg)
    # ...but error feedback must recover it in accumulation over steps
    acc = jnp.zeros(4)
    res_t = error_feedback_init(grads)
    for _ in range(64):
        c, res_t = compress_gradients(grads, res_t, cfg)
        acc = acc + c["w"]
    np.testing.assert_allclose(acc / 64, grads["w"], atol=2e-3)


def test_compress_bits_reduce_error_monotonically():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,))}
    errs = []
    for bits in (4, 8, 16):
        c, _ = compress_gradients(
            g, error_feedback_init(g), CompressionConfig(bits=bits,
                                                         error_feedback=False))
        errs.append(float(jnp.abs(c["w"] - g["w"]).max()))
    assert errs[0] > errs[1] > errs[2]
