"""SLO scheduler + preemption tests.

* ordering/victim-selection policy units (``launch.scheduler``),
* the legacy-FIFO head-skip regression (a permanently-too-large head no
  longer starves the queue behind it),
* bounded out-of-order admission past a deferred head,
* end-to-end preemption: a preempted request's pages demote to the host
  tier, resume promotes them back, and the token stream is BITWISE
  identical to an uninterrupted run at kv-bits {0, 8, 4} (gather mode,
  single-threaded-XLA subprocess like the other identity tests).
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.paged_kv import OutOfPagesError
from repro.launch.scheduler import (SchedPolicy, SLOScheduler, request_key)
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _req(rid, *, priority=0, deadline=None, arrive=0, prompt_len=4,
         max_new=4):
    return Request(rid, (np.arange(prompt_len) % 7).astype(np.int32),
                   max_new, priority=priority, deadline_step=deadline,
                   arrive_step=arrive)


# ---------------------------------------------------------------------------
# Ordering + victim policy units
# ---------------------------------------------------------------------------
def test_request_key_priority_then_deadline_then_arrival():
    hi = _req(0, priority=5)
    edf_soon = _req(1, priority=0, deadline=10)
    edf_late = _req(2, priority=0, deadline=99)
    no_dl = _req(3, priority=0)
    later = _req(4, priority=0, arrive=7)
    order = sorted([later, no_dl, edf_late, edf_soon, hi], key=request_key)
    assert [r.rid for r in order] == [0, 1, 2, 3, 4]


def test_sort_queue_is_stable_for_ties():
    sched = SLOScheduler()
    a, b = _req(1), _req(2)
    q = [a, b]
    sched.sort_queue(q)
    assert [r.rid for r in q] == [1, 2]


def test_choose_victims_strictly_less_urgent_least_first():
    sched = SLOScheduler(SchedPolicy(max_preempt_per_admit=2))
    urgent = _req(0, priority=5)
    low1, low2, mid = _req(1, priority=0), _req(2, priority=0,
                                                arrive=3), _req(3, priority=3)
    running = [(0, low1, 0), (1, mid, 0), (2, low2, 0)]
    gains = {0: 2, 1: 2, 2: 2}
    victims = sched.choose_victims(urgent, running, 2, gains.get)
    assert victims == [2]          # least urgent (latest arrival) first
    victims = sched.choose_victims(urgent, running, 4, gains.get)
    assert victims == [2, 0]       # accumulates until the shortfall is met
    # equally/more urgent peers are never victims
    peer = _req(9, priority=5)
    assert sched.choose_victims(peer, [(0, urgent, 0)], 1,
                                gains.get) == []
    # insufficient total gain -> no pointless churn
    assert sched.choose_victims(urgent, running, 99, gains.get) == []
    # preemption disabled
    off = SLOScheduler(SchedPolicy(preempt=False))
    assert off.choose_victims(urgent, running, 1, gains.get) == []


# ---------------------------------------------------------------------------
# Legacy FIFO: too-large head is skipped, not starving the tail
# ---------------------------------------------------------------------------
def test_fifo_skips_permanently_too_large_head(smoke_model):
    """Regression: the old admission raised on the spot for a never-fit
    head, killing every serviceable request queued behind it. Now the head
    is recorded+skipped, the tail is served, and the error surfaces at the
    end of the run."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=64, kv_bits=8,
                        page_size=8, num_pages=5)        # 4 usable
    rng = np.random.default_rng(0)
    huge = Request(0, rng.integers(0, cfg.vocab_size, 30).astype(np.int32),
                   30)              # needs 8 pages > 4 usable: never fits
    ok = [Request(i, rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 6)
          for i in (1, 2)]
    with pytest.raises(OutOfPagesError) as ei:
        srv.run([huge] + ok)
    # the too-large head was rejected with full counts...
    assert ei.value.rid == 0 and ei.value.needed > ei.value.total
    assert isinstance(huge.error, OutOfPagesError) and huge.done
    assert huge.out == []
    # ...but the tail behind it was served to completion first
    assert all(r.done and len(r.out) == 6 and r.error is None for r in ok)
    assert srv.allocator.num_free == srv.allocator.num_usable


def test_slo_records_reject_instead_of_raising(smoke_model):
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=64, kv_bits=8,
                        page_size=8, num_pages=5, sched="slo")
    rng = np.random.default_rng(0)
    huge = Request(0, rng.integers(0, cfg.vocab_size, 30).astype(np.int32),
                   30)
    ok = Request(1, rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 6)
    out = srv.run([huge, ok])      # no raise in slo mode
    assert out is not None
    assert isinstance(huge.error, OutOfPagesError)
    assert ok.done and len(ok.out) == 6 and ok.error is None
    assert srv.rejected == [huge]


# ---------------------------------------------------------------------------
# Out-of-order admission past a deferred head
# ---------------------------------------------------------------------------
def test_slo_admits_small_request_past_deferred_head(smoke_model):
    """A head that must WAIT for pages no longer blocks a small request
    behind it: the scheduler admits within the window, and the head admits
    later once pages free up."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=64, kv_bits=8,
                        page_size=8, num_pages=7, sched="slo")  # 6 usable
    rng = np.random.default_rng(1)
    # big needs ceil((11+20)/8)=4 pages; blocker holds 3 -> big defers
    blocker = Request(0, rng.integers(0, cfg.vocab_size, 8)
                      .astype(np.int32), 16)               # 3 pages
    big = Request(1, rng.integers(0, cfg.vocab_size, 12)
                  .astype(np.int32), 20)                   # 4 pages
    small = Request(2, rng.integers(0, cfg.vocab_size, 4)
                    .astype(np.int32), 4, arrive_step=2)   # 1 page
    srv.run([blocker, big, small])
    assert all(r.done and r.error is None for r in (blocker, big, small))
    assert srv.scheduler.ooo_admissions >= 1
    assert srv.allocator.num_free == srv.allocator.num_usable

    # window=0 restores strict (priority-sorted) FIFO: no OOO admissions
    srv2 = BatchedServer(cfg, params, batch_size=2, max_len=64, kv_bits=8,
                         page_size=8, num_pages=7, sched="slo",
                         admit_window=0)
    rng = np.random.default_rng(1)
    srv2.run([Request(0, rng.integers(0, cfg.vocab_size, 8)
                      .astype(np.int32), 16),
              Request(1, rng.integers(0, cfg.vocab_size, 12)
                      .astype(np.int32), 20),
              Request(2, rng.integers(0, cfg.vocab_size, 4)
                      .astype(np.int32), 4, arrive_step=2)])
    assert srv2.scheduler.ooo_admissions == 0


# ---------------------------------------------------------------------------
# End-to-end preemption wiring (single-process; bitwise test below)
# ---------------------------------------------------------------------------
def test_preemption_demotes_resumes_and_completes(smoke_model):
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=1, max_len=48, kv_bits=4,
                        page_size=8, num_pages=4, kv_offload="host",
                        sched="slo")
    rng = np.random.default_rng(2)
    low = Request(0, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                  16, priority=0)
    hi = Request(1, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                 6, priority=5, arrive_step=4, deadline_step=20)
    srv.run([low, hi])
    assert low.done and hi.done and low.preemptions >= 1
    assert len(low.out) == 16 and len(hi.out) == 6
    assert srv.preempt_count == srv.resume_count >= 1
    assert srv.host_store.num_pages == 0      # resume drained the handles
    assert srv.allocator.num_free == srv.allocator.num_usable
    # the preempted request kept ONE contiguous output stream
    assert low._paused is None and low.error is None


def test_preempt_realias_skips_host_copies(smoke_model):
    """With the prefix cache on, a victim's prompt pages are cache nodes
    (refcount > 1) — preempting it must RE-ALIAS them (pin the node, drop
    the slot ref, no host copy) instead of paying offload for pages that
    free nothing, and resume must re-incref them with the token stream
    intact and zero leaks."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=1, max_len=48, kv_bits=8,
                        page_size=8, num_pages=5, kv_offload="host",
                        sched="slo", prefix_cache="on")
    rng = np.random.default_rng(2)
    low = Request(0, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                  16, priority=0)
    hi = Request(1, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                 6, priority=5, arrive_step=4, deadline_step=20)
    srv.run([low, hi])
    assert low.done and hi.done and low.preemptions >= 1
    assert srv.preempt_count == srv.resume_count >= 1
    # at least the victim's full prompt page skipped the host round trip
    assert srv.realias_skipped >= 1
    assert srv.host_store.num_pages == 0
    assert srv.release_prefix_cache() == 0
    assert srv.allocator.num_free == srv.allocator.num_usable


def test_reject_before_resume_releases_pins_and_host_pages(smoke_model):
    """Regression: a preempted request that is REJECTED (or rolled back)
    before it ever resumes must release its parked resume state — unpin
    every re-aliased prefix node and drop its host-tier blobs. The old
    ``_reject`` path only recorded the error, leaving the nodes pinned
    forever (phantom retained pages in the leak gate) and the host blobs
    counting against --host-pages until process exit."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=1, max_len=48, kv_bits=8,
                        page_size=8, num_pages=8, kv_offload="host",
                        sched="slo", prefix_cache="on", prefill_batch=1,
                        kv_scale="page")
    rng = np.random.default_rng(3)
    req = Request(0, rng.integers(0, cfg.vocab_size, 12).astype(np.int32), 8)
    jobs = []
    srv._admit_slo([req], jobs)
    assert srv.slots[0] is req and not jobs   # prefill ran inline
    # page-scale mode: the full page is cached (-> alias-pinned on
    # preempt), the partial tail is private (-> host blob), so BOTH parked
    # resource flavors are exercised
    victim = srv._preempt_slot(0)
    kinds = {k for k, _ in victim._paused.entries}
    assert kinds == {"alias", "host"}, victim._paused.entries
    assert srv.host_store.num_pages >= 1
    queue = [victim]
    srv._reject(queue, 0, RuntimeError("cancelled before resume"))
    assert victim.done and victim._paused is None
    assert srv.host_store.num_pages == 0          # parked blobs dropped
    assert srv.release_prefix_cache() == 0        # pins released, no leak
    assert srv.allocator.num_free == srv.allocator.num_usable


def test_preempt_requires_host_offload(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="host"):
        BatchedServer(cfg, params, batch_size=1, max_len=32, kv_bits=8,
                      page_size=8, sched="slo", preempt=True)
    with pytest.raises(ValueError, match="slo"):
        BatchedServer(cfg, params, batch_size=1, max_len=32, kv_bits=8,
                      page_size=8, kv_offload="host", preempt=True)


# ---------------------------------------------------------------------------
# Preempt/resume is BITWISE identical to an uninterrupted run
# ---------------------------------------------------------------------------
_PREEMPT_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)

def mk():
    rng = np.random.default_rng(11)
    low = Request(0, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                  14, priority=0)
    hi = Request(1, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                 5, priority=5, arrive_step=4)
    mid = Request(2, rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                  6, priority=1, arrive_step=8)
    return [low, hi, mid]

for kv_bits in (0, 8, 4):
    for prefix in ("off", "on"):
        # tight pool + slots: the high-priority latecomer must preempt.
        # prefix="on" additionally routes the victim's prompt pages through
        # PREEMPTION RE-ALIASING (pinned cache nodes, no host copy), which
        # must be just as bitwise-invisible as the host round trip.
        srv = BatchedServer(cfg, params, batch_size=1, max_len=48,
                            kv_bits=kv_bits, page_size=8, num_pages=4,
                            kv_offload="host", sched="slo",
                            prefix_cache=prefix)
        reqs = srv.run(mk())
        assert srv.preempt_count >= 1, "trace failed to trigger preemption"
        assert srv.resume_count == srv.preempt_count
        assert all(r.done and r.error is None for r in reqs)
        if prefix == "on":
            assert srv.realias_skipped >= 1, "re-aliasing never fired"
            assert srv.release_prefix_cache() == 0
        assert srv.host_store.num_pages == 0
        # uninterrupted reference: same requests, roomy pool, no preemption
        ref = BatchedServer(cfg, params, batch_size=3, max_len=48,
                            kv_bits=kv_bits, page_size=8)
        ref_reqs = ref.run(mk())
        assert ref.preempt_count == 0
        by_rid = {r.rid: r for r in ref_reqs}
        for r in reqs:
            assert r.out == by_rid[r.rid].out, (kv_bits, prefix, r.rid,
                                                r.out, by_rid[r.rid].out)
        n_pre = sum(r.preemptions for r in reqs)
        print(f"kv_bits={kv_bits} prefix={prefix} bitwise-identical "
              f"after {n_pre} preemption(s), "
              f"{srv.realias_skipped} demotions skipped")
print("PREEMPT_IDENTITY_OK")
"""


def test_preempt_resume_bitwise_identical():
    """A preempted-then-resumed request emits bitwise-identical tokens to an
    unpreempted run at kv-bits {0, 8, 4}: demote->promote restores the
    packed page bytes exactly and decode continues from the restored state
    (no re-prefill).

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c", _PREEMPT_IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "PREEMPT_IDENTITY_OK" in res.stdout
