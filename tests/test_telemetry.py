"""Telemetry-layer tests: registry semantics, the metric_attr bridge,
kv-inventory/gauge reconciliation, trace integrity over a preempt/resume
run, and the metrics on/off bitwise-identity contract.

* MetricsRegistry: exact nearest-rank percentiles over raw observations,
  reset()/checkpoint()/since() warmup-boundary semantics, gauge callbacks.
* metric_attr: legacy instance-attribute reads/writes (``srv.x += 1``,
  hand-zeroing) land on the owning registry's counters.
* kv_inventory() scalars must reconcile byte-for-byte with the registered
  ``kv.*`` gauges AND with caches_kv_bytes over the live pools — one
  schema shared by the dict, the snapshot stream, and direct gauge reads.
* A preempt/resume trace must export valid Chrome trace-event JSON:
  non-negative monotonic-clock timestamps, spans on one track disjoint or
  properly nested, lifecycle instants ordered arrive <= admit <=
  first_token <= finish, and the victim's track showing offload + resume
  spans that do not overlap.
* ``--metrics off`` must be token-identical to a server built without the
  flag, and ``--metrics on`` must change tokens nowhere, at kv-bits
  {0, 8, 4} (subprocess, single-threaded XLA — same pattern as the other
  bitwise-identity suites); fused mode must keep program_launches ==
  cycles as read through the registry.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model
from repro.runtime.telemetry import (Ewma, MetricsRegistry,
                                     MetricsSnapshotter, NullTracer,
                                     SLOMonitor, Tracer, make_tracer,
                                     metric_attr, percentile)

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Registry primitives
# ---------------------------------------------------------------------------
def test_percentile_exact_nearest_rank():
    xs = list(range(1, 101))        # 1..100
    assert percentile(xs, 50) == 50
    assert percentile(xs, 99) == 99
    assert percentile(xs, 100) == 100
    assert percentile(xs, 0) == 1   # nearest-rank floors at the minimum
    assert percentile([7.5], 50) == 7.5
    assert percentile([], 50) is None
    # unsorted input is sorted internally
    assert percentile([3, 1, 2], 50) == 2


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert reg.counter("c") is c            # stable object per name
    assert c.value == 3.5
    c.value = 4                             # hand-assignment (bench idiom)
    assert c.value == 4 and isinstance(c.value, int)

    reg.gauge("g").set(7)
    assert reg.gauge("g").value == 7
    backing = {"v": 11}
    reg.register_gauge("live", lambda: backing["v"])
    assert reg.gauge("live").value == 11
    backing["v"] = 13                       # callback gauges read live state
    assert reg.gauge("live").value == 13

    h = reg.histogram("h")
    for v in range(1, 101):
        h.observe(v)
    assert h.count == 100
    assert h.percentile(50) == 50 and h.percentile(99) == 99
    s = h.summary()
    assert s["min"] == 1 and s["max"] == 100 and s["p50"] == 50

    assert reg.value("c") == 4
    assert reg.value("live") == 13
    assert reg.value("h") == 100
    with pytest.raises(KeyError):
        reg.value("nope")


def test_registry_reset_checkpoint_since():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc(5)
    reg.histogram("h").observe(1.0)
    reg.gauge("g").set(9)

    mark = reg.checkpoint()
    c.inc(3)
    reg.counter("new_after_mark").inc(2)
    delta = reg.since(mark)
    assert delta["n"] == 3 and delta["new_after_mark"] == 2

    reg.reset()
    assert c.value == 0                     # the held object was zeroed...
    assert reg.counter("n") is c            # ...not replaced
    assert reg.histogram("h").count == 0
    assert reg.gauge("g").value == 9        # gauges are state, not counts


def test_metric_attr_routes_through_registry():
    class Thing:
        hits = metric_attr("thing.hits")

        def __init__(self):
            self.metrics = MetricsRegistry()

    a, b = Thing(), Thing()
    a.hits += 1
    a.hits += 1
    b.hits = 40
    assert a.hits == 2 and b.hits == 40     # per-instance registries
    assert a.metrics.counter("thing.hits").value == 2
    a.metrics.reset()
    assert a.hits == 0 and b.hits == 40


def test_make_tracer_and_null_surface(tmp_path, caplog):
    assert isinstance(make_tracer("on"), Tracer)
    null = make_tracer("off")
    assert isinstance(null, NullTracer) and not null.enabled
    with pytest.raises(ValueError, match="metrics"):
        make_tracer("maybe")
    # the disabled surface: spans are reusable null contexts, reductions
    # are empty, exporting is a warned no-op (returns None, writes no
    # file) instead of the PR 8 RuntimeError footgun
    with null.span("x"):
        with null.req_span(0, "y"):
            null.req_arrive(0, 0)
            null.req_finish(0, 1, 1)
    null.pager_span("pager.demote", 0.0, 1.0)
    assert null.request_stats() == [] and null.slo_summary() == {}
    assert null.chrome_trace()["traceEvents"] == []
    path = tmp_path / "t.json"
    import logging
    with caplog.at_level(logging.WARNING, "repro.runtime.telemetry"):
        assert null.export_chrome(str(path)) is None
    assert not path.exists(), "NullTracer export must not write a file"
    assert any("disabled" in r.getMessage() for r in caplog.records)


def test_snapshotter_jsonl_stream(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(4)
    path = str(tmp_path / "metrics.jsonl")
    snap = MetricsSnapshotter(reg, path, every=10)
    assert snap.maybe_emit(0) is True       # first window
    assert snap.maybe_emit(5) is False      # same window
    reg.counter("c").inc(1)
    assert snap.maybe_emit(10) is True      # next window
    lines = [json.loads(ln) for ln in open(path)]
    assert [ln["cycle"] for ln in lines] == [0, 10]
    assert lines[0]["counters"]["c"] == 4
    assert lines[1]["counters"]["c"] == 5
    assert all(ln["elapsed_s"] >= 0 for ln in lines)
    with pytest.raises(ValueError, match="interval"):
        MetricsSnapshotter(reg, path, every=0)


# ---------------------------------------------------------------------------
# kv_inventory == registry gauges == live pool bytes
# ---------------------------------------------------------------------------
def test_kv_inventory_reconciles_with_gauges(smoke_model):
    from repro.core.paged_kv import caches_kv_bytes
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=32, kv_bits=8,
                        page_size=8, prefix_cache="on", kv_offload="host",
                        sched="slo", metrics="on")
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 7 + i)
                    .astype(np.int32), 4) for i in range(3)]
    srv.run(reqs)
    inv = srv.kv_inventory()
    # one schema: the dict's scalars ARE the registered kv.* gauges
    g = srv.metrics.gauge
    assert inv["device_bytes"] == g("kv.device_bytes").value
    assert inv["device_pages_free"] == g("kv.device_pages_free").value
    assert inv["device_pages_usable"] == g("kv.device_pages_usable").value
    assert inv["host_bytes"] == g("kv.host_bytes").value
    assert inv["host_pages"] == g("kv.host_pages").value
    assert inv["tier_bytes"] == g("kv.tier_bytes").value
    assert inv["tier_pages"] == g("kv.tier_pages").value
    # ...and the gauges reconcile with the live pools
    assert inv["device_bytes"] == sum(caches_kv_bytes(srv.caches).values())
    assert inv["device_bytes"] == sum(inv["device_by_container"].values())
    assert inv["device_pages_free"] == srv.allocator.num_free
    assert inv["device_pages_usable"] == srv.allocator.num_usable
    assert inv["host_bytes"] == srv.host_store.nbytes
    assert inv["host_pages"] == srv.host_store.num_pages
    # the registry path is live, not a construction-time copy: park a page
    # on the host tier and re-read
    before = inv["host_pages"]
    from repro.core.page_store import extract_page
    blob = extract_page(srv.caches, 1)
    h = srv.host_store.put(blob)
    inv2 = srv.kv_inventory()
    assert inv2["host_pages"] == before + 1
    assert inv2["host_bytes"] == srv.host_store.nbytes > 0
    srv.host_store.pop(h)


def test_kv_inventory_unpaged_is_zero(smoke_model):
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=32)
    inv = srv.kv_inventory()
    assert inv["device_bytes"] == 0 and inv["device_by_container"] == {}


# ---------------------------------------------------------------------------
# Trace integrity over a preempt/resume run
# ---------------------------------------------------------------------------
def _spans_disjoint_or_nested(spans, eps=0.5):
    """Every pair of X intervals on one track must be disjoint or properly
    nested (eps in us absorbs float jitter at shared boundaries)."""
    ivs = [(e["ts"], e["ts"] + e["dur"], e["name"]) for e in spans]
    for i in range(len(ivs)):
        for j in range(i + 1, len(ivs)):
            a0, a1, an = ivs[i]
            b0, b1, bn = ivs[j]
            disjoint = a1 <= b0 + eps or b1 <= a0 + eps
            a_in_b = b0 <= a0 + eps and a1 <= b1 + eps
            b_in_a = a0 <= b0 + eps and b1 <= a1 + eps
            assert disjoint or a_in_b or b_in_a, (
                f"overlapping spans on one track: {an} [{a0},{a1}] vs "
                f"{bn} [{b0},{b1}]")


def test_trace_integrity_preempt_resume(smoke_model):
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=1, max_len=48, kv_bits=4,
                        page_size=8, num_pages=4, kv_offload="host",
                        sched="slo", metrics="on")
    rng = np.random.default_rng(2)
    low = Request(0, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                  16, priority=0)
    hi = Request(1, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                 6, priority=5, arrive_step=4, deadline_step=20)
    srv.run([low, hi])
    assert low.done and hi.done and srv.preempt_count >= 1

    trace = srv.tracer.chrome_trace()
    # Chrome trace-event JSON: round-trips, only known phases, sane fields
    trace = json.loads(json.dumps(trace))
    events = trace["traceEvents"]
    assert trace["displayTimeUnit"] == "ms"
    assert {e["ph"] for e in events} <= {"X", "i", "M"}
    xs = [e for e in events if e["ph"] == "X"]
    assert xs, "no complete spans recorded"
    for e in events:
        assert e["pid"] == 0
        if e["ph"] == "M":
            continue
        assert e["ts"] >= 0, e
        if e["ph"] == "X":
            assert e["dur"] >= 0, e
    # the engine track saw decode spans and admission waves
    engine_names = {e["name"] for e in xs if e["tid"] == 0}
    assert "decode_span" in engine_names and "admission" in engine_names

    # spans on any one track are disjoint or properly nested
    for tid in {e["tid"] for e in xs}:
        _spans_disjoint_or_nested([e for e in xs if e["tid"] == tid])

    # per-request lifecycle instants are causally ordered
    for rid in (0, 1):
        tid = 1 + rid
        inst = {e["name"]: e["ts"] for e in events
                if e["ph"] == "i" and e["tid"] == tid}
        assert inst["arrive"] <= inst["admit"] \
            <= inst["first_token"] <= inst["finish"]
    # track names were emitted for both request tracks
    names = {e["args"]["name"] for e in events if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert {"engine", "req 0", "req 1"} <= names

    # the victim's track shows the offload and resume spans, not overlapping
    victim = [e for e in xs if e["tid"] == 1 + low.rid]
    offloads = [e for e in victim if e["name"] == "offload"]
    resumes = [e for e in victim if e["name"] == "resume"]
    assert offloads and resumes, "victim track missing offload/resume spans"
    for o in offloads:
        for r in resumes:
            assert (o["ts"] + o["dur"] <= r["ts"]
                    or r["ts"] + r["dur"] <= o["ts"]), \
                "offload and resume spans overlap"
    # preempt instant precedes the resume span
    pre = [e["ts"] for e in events if e["ph"] == "i"
           and e["tid"] == 1 + low.rid and e["name"] == "preempt"]
    assert pre and min(pre) <= resumes[0]["ts"]

    # the lifecycle records reduce correctly
    stats = {s["rid"]: s for s in srv.tracer.request_stats()}
    assert stats[0]["preemptions"] >= 1 and stats[0]["resumed"] >= 1
    assert stats[0]["finished"] and stats[1]["finished"]
    assert stats[1]["met_deadline"], stats[1]
    assert stats[0]["tokens"] == 16 and stats[1]["tokens"] == 6
    slo = srv.tracer.slo_summary()
    assert slo["requests"] == 2 and slo["finished"] == 2
    assert slo["preemptions"] == srv.preempt_count
    assert slo["deadlined"] == 1
    assert slo["ttft_p50_s"] is not None and slo["ttft_p50_s"] >= 0
    assert slo["tpot_p50_s"] is not None and slo["tpot_p50_s"] >= 0

    # export writes loadable JSON
    import tempfile, os
    path = os.path.join(tempfile.mkdtemp(prefix="trace_"), "t.json")
    srv.tracer.export_chrome(path)
    with open(path) as f:
        assert json.load(f)["traceEvents"] == events


def test_rid_reuse_opens_fresh_incarnation(smoke_model):
    """Warm bench passes re-offer the same rids; each arrival must open a
    fresh lifecycle record instead of merging into (or corrupting) the
    finished one."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=1, max_len=32, kv_bits=8,
                        page_size=8, metrics="on")
    rng = np.random.default_rng(4)
    mk = lambda: [Request(0, rng.integers(0, cfg.vocab_size, 5)
                          .astype(np.int32), 3)]
    srv.run(mk())
    srv.run(mk())
    stats = srv.tracer.request_stats()
    assert len(stats) == 2
    assert all(s["rid"] == 0 and s["finished"] for s in stats)
    assert srv.tracer.slo_summary()["requests"] == 2


# ---------------------------------------------------------------------------
# --metrics off == seed, --metrics on changes tokens nowhere (subprocess)
# ---------------------------------------------------------------------------
_METRICS_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)

def mk():
    rng = np.random.default_rng(7)
    lens = [1, 7, 9, 3, 21]
    return [Request(i, rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    5 + (i % 3)) for i, L in enumerate(lens)]

for kv_bits in (0, 8, 4):
    base = dict(batch_size=3, max_len=32, kv_bits=kv_bits, page_size=8,
                prefill="bucketed", prefill_bucket=8)
    seed = BatchedServer(cfg, params, **base)          # no metrics kwarg
    out_seed = seed.run(mk())
    off = BatchedServer(cfg, params, metrics="off", **base)
    out_off = off.run(mk())
    on = BatchedServer(cfg, params, metrics="on", **base)
    out_on = on.run(mk())
    for a, b, c in zip(out_seed, out_off, out_on):
        assert a.out == b.out, ("off", kv_bits, a.rid, a.out, b.out)
        assert a.out == c.out, ("on", kv_bits, a.rid, a.out, c.out)
    assert all(r.done for r in out_on)
    assert len(on.tracer.events) > 0 and len(off.tracer.events) == 0
    assert on.tracer.slo_summary()["finished"] == len(out_on)
    print(f"kv_bits={kv_bits} tokens identical across seed/off/on")

# fused mode with metrics on: the one-launch-per-cycle contract holds as
# read THROUGH the registry (the gate the ragged bench re-asserts)
fus = BatchedServer(cfg, params, batch_size=3, max_len=32, kv_bits=8,
                    page_size=8, prefill="bucketed", prefill_bucket=8,
                    fused="on", metrics="on")
out_fus = fus.run(mk())
assert all(r.done for r in out_fus)
assert (fus.metrics.counter("serve.program_launches").value
        == fus.metrics.counter("serve.cycles").value > 0)
sep = BatchedServer(cfg, params, batch_size=3, max_len=32, kv_bits=8,
                    page_size=8, prefill="bucketed", prefill_bucket=8,
                    fused="off")
out_sep = sep.run(mk())
for a, b in zip(out_sep, out_fus):
    assert a.out == b.out, ("fused", a.rid, a.out, b.out)
print("METRICS_IDENTITY_OK")
"""


def test_metrics_modes_are_token_neutral():
    """--metrics off is token-identical to a server built without the flag,
    and --metrics on changes tokens nowhere, at kv-bits {0, 8, 4}; fused
    mode keeps program_launches == cycles as read through the registry.

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c", _METRICS_IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "METRICS_IDENTITY_OK" in res.stdout


def test_scattered_counters_share_one_registry(smoke_model):
    """The server threads ONE registry through allocator, scheduler, prefix
    cache and tiers: the migrated legacy attributes and the registry read
    the same storage."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=32, kv_bits=8,
                        page_size=8, prefix_cache="on", sched="slo")
    rng = np.random.default_rng(9)
    sys_p = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [sys_p, rng.integers(0, cfg.vocab_size, 2 + i)
                 .astype(np.int32)]), 3) for i in range(3)]
    srv.run(reqs)
    m = srv.metrics
    assert srv.prefill_forwards == m.counter("serve.prefill_forwards").value
    assert srv.decode_steps == m.counter("serve.decode_steps").value > 0
    assert srv.prefix_cache.lookups == m.counter("prefix.lookups").value > 0
    assert srv.prefix_cache.hits == m.counter("prefix.hits").value
    assert (srv.scheduler.ooo_admissions
            == m.counter("sched.ooo_admissions").value)
    assert m.counter("alloc.allocs").value > 0
    assert m.gauge("alloc.free_pages").value == srv.allocator.num_free
    # two servers never share counters (per-server registries)
    other = BatchedServer(cfg, params, batch_size=2, max_len=32, kv_bits=8,
                          page_size=8)
    assert other.metrics is not m
    assert other.metrics.counter("serve.decode_steps").value == 0


# ---------------------------------------------------------------------------
# slo_summary edge cases + SLOMonitor rolling windows
# ---------------------------------------------------------------------------
def test_slo_summary_zero_requests():
    """An untouched tracer summarises to zeros/None, never NaN or a raise."""
    tr = Tracer()
    s = tr.slo_summary()
    assert s["requests"] == 0 and s["finished"] == 0
    assert s["goodput"] is None
    for k in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s"):
        assert s[k] is None


def test_slo_summary_all_deferred():
    """Requests that arrive but never admit (gate closed all run) count as
    offered-but-not-good: goodput 0.0 with None percentiles."""
    tr = Tracer()
    for rid in range(3):
        tr.req_arrive(rid, step=0, deadline_step=10)
        tr.req_defer(rid, step=1)
        tr.req_defer(rid, step=2)
    s = tr.slo_summary()
    assert s["requests"] == 3 and s["finished"] == 0
    assert s["goodput"] == 0.0
    assert s["deadline_misses"] == 3
    assert s["ttft_p50_s"] is None and s["tpot_p50_s"] is None
    assert all(st["defers"] == 2 for st in tr.request_stats())


def test_slo_summary_never_first_token():
    """A request that finishes without ever emitting a token (e.g. rejected
    after admit, or zero-token cap) has None TTFT/TPOT; percentiles are
    drawn only from requests that actually emitted tokens."""
    tr = Tracer()
    tr.req_arrive(0, step=0)
    tr.req_admit(0, step=0)
    tr.req_finish(0, step=3, tokens=0)       # no req_first_token ever
    tr.req_arrive(1, step=0)
    tr.req_admit(1, step=0)
    tr.req_first_token(1)
    tr.req_finish(1, step=4, tokens=5)
    stats = {s["rid"]: s for s in tr.request_stats()}
    assert stats[0]["ttft_s"] is None and stats[0]["tpot_s"] is None
    assert stats[0]["finished"] and stats[0]["met_deadline"]
    assert stats[1]["ttft_s"] is not None and stats[1]["tpot_s"] is not None
    s = tr.slo_summary()
    assert s["goodput"] == 1.0               # both no-deadline + finished
    assert s["ttft_p50_s"] == stats[1]["ttft_s"]
    assert s["tpot_p50_s"] == stats[1]["tpot_s"]


def test_slo_monitor_window_reductions_and_gauges():
    reg = MetricsRegistry()
    mon = SLOMonitor(reg, window=4)
    g = reg.snapshot()["gauges"]
    # empty window: gauges read 0.0, window_requests disambiguates
    assert g["slo.window_requests"] == 0
    assert g["slo.window_goodput"] == 0.0
    assert mon.window_goodput() is None      # the method keeps the None
    assert g["slo.window_ttft_p50_s"] == 0.0
    # feed 6 finishes through a window of 4: only the last 4 count
    for rid in range(6):
        mon.note_arrive(rid)
        mon.note_first_token(rid)
        mon.note_finish(rid, met=(rid >= 2), tokens=8)
    g = reg.snapshot()["gauges"]
    assert g["slo.window_requests"] == 4
    assert mon.window_goodput() == 1.0       # rids 2..5 all met
    assert g["slo.window_goodput"] == 1.0
    assert mon.window_ttft(50) is not None and mon.window_ttft(50) >= 0.0
    assert mon.window_tpot(99) is not None and mon.window_tpot(99) >= 0.0
    # a rejection is one window sample with met=False, no TPOT
    mon.note_arrive(99)
    mon.note_finish(99, met=False, tokens=0)
    assert mon.window_goodput() == 0.75


def test_slo_monitor_advance_and_slowdown_clipping():
    reg = MetricsRegistry()
    mon = SLOMonitor(reg, window=4)
    # advance folds pending arrivals into a per-step rate EWMA
    for rid in range(6):
        mon.note_arrive(rid)
    mon.advance(steps=3)
    assert mon.arrival_rate.get() == pytest.approx(2.0)
    mon.advance(steps=5)                     # no new arrivals -> decays
    assert 0.0 < mon.arrival_rate.get() < 2.0
    mon.note_queue_depth(10)
    assert mon.queue_depth.get() == 10.0
    # slowdown: 0 before any TPOT sample, then clipped to +/-0.25
    assert mon.tpot_slowdown() == 0.0
    mon.tpot.value, mon.tpot_ref.value = 10.0, 1.0
    assert mon.tpot_slowdown() == 0.25
    mon.tpot.value = 0.1
    assert mon.tpot_slowdown() == -0.25
    mon.tpot.value = 1.05
    assert mon.tpot_slowdown() == pytest.approx(0.05)
    with pytest.raises(ValueError):
        SLOMonitor(MetricsRegistry(), window=0)
    with pytest.raises(ValueError):
        Ewma(alpha=0.0)
