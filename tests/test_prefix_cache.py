"""Shared-prefix page cache tests: radix trie lookup vs a brute-force
oracle, allocator refcount invariants, LRU eviction semantics, CoW byte
preservation, and end-to-end serving under pool pressure.

Property tests run through the ``tests/_compat`` hypothesis shim, so they
execute (seeded example sampling) even in the minimal container."""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _compat import given, settings, strategies as st  # noqa: E402

from repro.configs.registry import get_smoke_config
from repro.core.page_store import HostPageStore, TieredPager
from repro.core.paged_kv import (OutOfPagesError, PageAllocator, PagedKVLayout,
                                 copy_pool_pages, init_paged_pool,
                                 paged_update)
from repro.core.prefix_cache import PrefixCache
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

jax.config.update("jax_platform_name", "cpu")


def _mk_tiered(num_pages=64, ps=2, host_pages=None):
    """A PrefixCache wired to a real pager over a tiny single-layer pool."""
    al = PageAllocator(num_pages)
    layout = PagedKVLayout(num_pages=num_pages, page_size=ps, num_kv_heads=1,
                           head_dim=8, container="int8")
    state = {"caches": [(init_paged_pool(layout),)]}
    host = HostPageStore(max_pages=host_pages)
    pager = TieredPager(al, host, lambda: state["caches"],
                        lambda c: state.update(caches=c))
    cache = PrefixCache(al, ps, pager=pager)
    al.reclaim = cache.evict
    return cache, al, host


# ---------------------------------------------------------------------------
# Allocator refcount invariants
# ---------------------------------------------------------------------------
class TestRefcounts:
    def test_alloc_starts_at_one_and_free_recycles(self):
        al = PageAllocator(4)
        p = al.alloc()
        assert al.refcount(p) == 1
        al.free([p])
        assert al.refcount(p) == 0
        assert al.num_free == 3

    def test_no_free_while_referenced(self):
        """A page with live references NEVER returns to the free list."""
        al = PageAllocator(4)
        p = al.alloc()
        al.incref(p)                      # a sharer aliases the page
        al.free([p])                      # owner releases
        assert al.refcount(p) == 1
        assert p not in al._free          # still referenced -> not recycled
        al.free([p])                      # sharer releases
        assert p in al._free

    def test_double_free_still_rejected(self):
        al = PageAllocator(4)
        p = al.alloc()
        al.free([p])
        with pytest.raises(ValueError, match="double free"):
            al.free([p])

    def test_incref_of_free_page_rejected(self):
        al = PageAllocator(4)
        with pytest.raises(ValueError):
            al.incref(2)

    @settings(max_examples=25)
    @given(seed=st.integers(0, 10_000))
    def test_random_ops_match_shadow_model(self, seed):
        """Random alloc/incref/free sequences agree with a pure-python
        shadow refcount model; the free list only ever holds refcount-0
        pages and every page is in exactly one of {free, allocated}."""
        rng = np.random.default_rng(seed)
        al = PageAllocator(9)
        shadow = {}                       # page -> refcount
        for _ in range(120):
            op = rng.integers(3)
            if op == 0 and al.num_free:
                p = al.alloc()
                assert shadow.get(p, 0) == 0
                shadow[p] = 1
            elif op == 1 and any(c > 0 for c in shadow.values()):
                live = [p for p, c in shadow.items() if c > 0]
                p = int(live[rng.integers(len(live))])
                al.incref(p)
                shadow[p] += 1
            elif op == 2 and any(c > 0 for c in shadow.values()):
                live = [p for p, c in shadow.items() if c > 0]
                p = int(live[rng.integers(len(live))])
                al.free([p])
                shadow[p] -= 1
            for p, c in shadow.items():
                assert al.refcount(p) == c
                assert (p in al._free) == (c == 0)
            assert al.num_free + sum(1 for c in shadow.values() if c > 0) \
                == al.num_usable


# ---------------------------------------------------------------------------
# Radix trie: lookup == brute-force longest-common-prefix oracle
# ---------------------------------------------------------------------------
def _cp_len(a, b):
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def _insert_seq(cache, al, tokens):
    """Allocate backing pages for ``tokens`` and insert; returns the pages
    (the caller's slot-owned references)."""
    ps = cache.page_size
    pages = [al.alloc() for _ in range(-(-len(tokens) // ps))]
    cache.insert(tokens, pages)
    return pages


@settings(max_examples=30)
@given(seed=st.integers(0, 10_000), ps=st.sampled_from([2, 3, 4]),
       vocab=st.sampled_from([2, 3]))
def test_lookup_matches_common_prefix_oracle(seed, ps, vocab):
    """matched == max over inserted sequences of the common-prefix length
    with the query (full pages aliased, the divergence page as CoW)."""
    rng = np.random.default_rng(seed)
    al = PageAllocator(256)
    cache = PrefixCache(al, ps)
    seqs = [list(rng.integers(0, vocab, rng.integers(1, 17)))
            for _ in range(rng.integers(1, 6))]
    for s in seqs:
        _insert_seq(cache, al, s)
    for _ in range(8):
        q = list(rng.integers(0, vocab, rng.integers(0, 17)))
        hit = cache.lookup(q)
        expect = max((_cp_len(s, q) for s in seqs), default=0)
        # a cached chain can also serve a PREFIX of itself that the oracle
        # sees via any longer sequence — matched is exactly the oracle value
        assert hit.matched == expect, (q, seqs, hit)
        # chain structure: whole pages aliased, the remainder via CoW
        assert len(hit.full_pages) == hit.matched // ps
        assert hit.cow_valid == hit.matched % ps
        assert (hit.cow_page is None) == (hit.cow_valid == 0)


@settings(max_examples=20)
@given(seed=st.integers(0, 10_000))
def test_insert_dedupes_and_refcounts_balance(seed):
    """Re-inserting shared chunks retains each cached page exactly once
    (one cache reference per node); releasing the inserters' own refs
    leaves every cached page at refcount 1 and clear() frees everything."""
    rng = np.random.default_rng(seed)
    al = PageAllocator(128)
    cache = PrefixCache(al, 2)
    owned = []
    common = list(rng.integers(0, 2, 6))
    for _ in range(4):
        s = common + list(rng.integers(0, 2, rng.integers(0, 5)))
        owned.append(_insert_seq(cache, al, s))
    for pages in owned:                  # all "requests" complete
        al.free(pages)
    assert cache.num_pages == cache.evictable_pages()
    assert cache.clear() == 0            # no refcount leak
    assert al.num_free == al.num_usable


def test_evict_lru_leaf_first_and_respects_references():
    al = PageAllocator(64)
    cache = PrefixCache(al, 2)
    pages_a = _insert_seq(cache, al, [0, 0, 0, 0])   # chain of 2 pages
    pages_b = _insert_seq(cache, al, [1, 1])         # 1 page, older stamp?
    # touch chain A so B is LRU
    cache.lookup([0, 0, 0, 0])
    al.free(pages_a)
    # B's page stays referenced by its "slot" -> not evictable
    assert cache.evictable_pages() == 2
    assert cache.evict(10) == 2                      # only A's chain goes
    assert cache.num_pages == 1
    hit = cache.lookup([1, 1])
    assert hit.matched == 2                          # B still served
    assert cache.lookup([0, 0, 0, 0]).matched == 0   # A gone
    al.free(pages_b)
    assert cache.clear() == 0


def test_heat_aware_victim_hot_old_outlives_cold_young():
    """Victim picking is age+hit-count scored, not pure LRU: a node that is
    OLDER by stamp but frequently hit outlives a younger never-hit node
    under demotion pressure."""
    cache, al, host = _mk_tiered()
    pages_hot = _insert_seq(cache, al, [0, 0])       # inserted FIRST
    for _ in range(8):                               # ...but hot: 8 hits
        assert cache.lookup([0, 0]).matched == 2
    pages_cold = _insert_seq(cache, al, [1, 1])      # younger stamp, 0 hits
    hot = cache.lookup([0, 0], record=False).nodes[0]
    cold = cache.lookup([1, 1], record=False).nodes[0]
    assert cold.stamp > hot.stamp                    # cold is LRU-younger
    assert cache._heat(hot) > cache._heat(cold)      # ...but heat-colder
    al.free(pages_hot)
    al.free(pages_cold)
    assert cache.evict(1) == 1
    assert cache.demotions == 1
    assert hot.resident and not cold.resident        # cold young one spilled
    # under pure LRU the hot (stamp-older) node would have been the victim
    assert cache.clear() == 0


def test_evict_keeps_ancestors_of_referenced_pages():
    """A referenced child pins its ancestors: evicting them would leave a
    chain with a hole while a reader still aliases the child."""
    al = PageAllocator(64)
    cache = PrefixCache(al, 2)
    pages = _insert_seq(cache, al, [0, 1, 2, 3, 4, 5])   # 3-page chain
    al.free(pages[:2])               # slot keeps a ref only on the LAST page
    assert cache.evictable_pages() == 0
    assert cache.evict(10) == 0
    assert cache.lookup([0, 1, 2, 3, 4, 5]).matched == 6
    al.free(pages[2:])
    assert cache.evictable_pages() == 3
    assert cache.clear() == 0


def test_profile_key_namespacing():
    """Pages are only shared between identically-quantized configs."""
    al = PageAllocator(64)
    cache = PrefixCache(al, 2, profile_key="int8")
    pages = _insert_seq(cache, al, [0, 1, 2, 3])     # default namespace
    assert cache.lookup([0, 1, 2, 3]).matched == 4
    assert cache.lookup([0, 1, 2, 3], profile_key="int4").matched == 0
    cache.insert([0, 1], [pages[0]], profile_key="int4")
    assert cache.lookup([0, 1], profile_key="int4").matched == 2
    al.free(pages)


# ---------------------------------------------------------------------------
# Tiered eviction: demote-instead-of-drop, host hits, host LRU drops
# ---------------------------------------------------------------------------
class TestHostTier:
    def test_evict_demotes_instead_of_dropping(self):
        cache, al, host = _mk_tiered()
        pages = _insert_seq(cache, al, [0, 1, 2, 3])     # 2-page chain
        al.free(pages)
        assert cache.evict(2) == 2
        # nothing destroyed: both pages live on the host tier
        assert cache.num_pages == 0 and cache.host_pages == 2
        assert cache.evictions == 0 and cache.demotions == 2
        assert host.num_pages == 2
        assert al.num_free == al.num_usable
        # the chain still MATCHES through host-state nodes
        hit = cache.lookup([0, 1, 2, 3])
        assert hit.matched == 4
        assert [n.resident for n in hit.nodes] == [False, False]
        # admission's promote path brings a node back as a cache-owned page
        page = cache.ensure_resident(hit.nodes[0])
        assert al.refcount(page) == 1 and cache.host_pages == 1
        assert cache.lookup([0, 1, 2, 3]).matched == 4
        assert cache.clear() == 0 and host.num_pages == 0

    def test_mid_chain_demotion_leaves_no_hole(self):
        """Demotion is NOT leaf-first (demoted bytes survive): a chain may
        interleave host and resident nodes and still serve full hits."""
        cache, al, host = _mk_tiered()
        pages = _insert_seq(cache, al, [0, 1, 2, 3, 4, 5])  # 3-page chain
        al.free(pages[:1])               # only the FIRST page is demotable
        assert cache.evict(1) == 1
        nodes = [n for _, _, n in cache.iter_chain_nodes()]
        assert sorted(n.resident for n in nodes) == [False, True, True]
        hit = cache.lookup([0, 1, 2, 3, 4, 5])
        assert hit.matched == 6          # no hole
        al.free(pages[1:])
        assert cache.clear() == 0 and host.num_pages == 0

    def test_pinned_nodes_survive_eviction_pressure(self):
        cache, al, host = _mk_tiered()
        pages = _insert_seq(cache, al, [0, 1, 2, 3])
        al.free(pages)
        hit = cache.lookup([0, 1, 2, 3])
        cache.pin(hit)
        assert cache.evictable_pages() == 0
        assert cache.evict(10) == 0      # pinned: neither demote nor drop
        cache.unpin(hit)
        assert cache.evictable_pages() == 2
        assert cache.evict(10) == 2
        assert cache.clear() == 0

    def test_host_capacity_falls_back_to_destructive_drop(self):
        cache, al, host = _mk_tiered(host_pages=1)
        pages = _insert_seq(cache, al, [0, 0, 1, 1])     # 2-page chain
        al.free(pages)
        assert cache.evict(2) == 2
        # one page demoted (host full), the leaf dropped destructively
        assert cache.demotions + cache.evictions == 2
        assert host.num_pages <= 1
        cache.clear()
        assert host.num_pages == 0

    def test_drop_host_lru_is_leaf_only(self):
        cache, al, host = _mk_tiered()
        pages = _insert_seq(cache, al, [0, 1, 2, 3])
        al.free(pages)
        cache.evict(2)                   # both nodes now host-state
        assert cache.drop_host_lru()     # drops the LEAF (deepest) first
        nodes = [n for _, _, n in cache.iter_chain_nodes()]
        assert len(nodes) == 1 and nodes[0].tokens == (0, 1)
        assert cache.drop_host_lru()
        assert not cache.drop_host_lru()
        assert host.num_pages == 0

    def test_insert_host_rebuilds_chains_parent_first(self):
        cache, al, host = _mk_tiered()
        # insert_host consumes caller-provided handles; restore order is
        # parents-first (the snapshot serialization order)
        assert cache.insert_host([0, 1], 10)
        assert cache.insert_host([0, 1, 2, 3], 11)
        assert cache.insert_host([0, 1, 2, 3, 9], 12)        # partial leaf
        assert not cache.insert_host([0, 1], 13)             # duplicate
        assert not cache.insert_host([5, 5, 5, 5], 14)       # orphan chain
        hit = cache.lookup([0, 1, 2, 3, 9, 9])
        assert hit.matched == 5 and hit.cow_valid == 1
        assert cache.host_pages == 3 and cache.restored_pages == 3


# ---------------------------------------------------------------------------
# Quant-tier eviction: requant BEFORE demote BEFORE drop
# ---------------------------------------------------------------------------
def _mk_adaptive(num_pages=64, ps=2, tier_pages=8, host_pages=None):
    """A PrefixCache wired to a pager AND a quant tier (int8 pool, so one
    requant step parks int4 directly at the byte floor)."""
    from repro.core.page_store import QuantTierStore
    al = PageAllocator(num_pages)
    layout = PagedKVLayout(num_pages=num_pages, page_size=ps, num_kv_heads=1,
                           head_dim=8, container="int8")
    state = {"caches": [(init_paged_pool(layout),)]}
    host = HostPageStore(max_pages=host_pages)
    pager = TieredPager(al, host, lambda: state["caches"],
                        lambda c: state.update(caches=c))
    tier = QuantTierStore(lambda: state["caches"],
                          lambda c: state.update(caches=c), pages=tier_pages)
    cache = PrefixCache(al, ps, pager=pager, tier=tier)
    al.reclaim = cache.evict
    return cache, al, host, tier


class TestQuantTier:
    def test_evict_requants_before_any_host_demotion(self):
        cache, al, host, tier = _mk_adaptive()
        pages = _insert_seq(cache, al, [0, 1, 2, 3])     # 2-page chain
        al.free(pages)
        assert cache.requantizable_pages() == 2
        assert cache.evict(2) == 2
        # relief came from requantization alone: nothing left the device
        assert cache.requants == 2 and cache.demotions == 0
        assert cache.evictions == 0 and host.num_pages == 0
        assert tier.num_pages == 2 and cache.tier_pages == 2
        assert al.num_free == al.num_usable
        # the chain still MATCHES through tier-state nodes
        hit = cache.lookup([0, 1, 2, 3])
        assert hit.matched == 4
        assert [n.resident for n in hit.nodes] == [False, False]
        assert cache.host_nodes_in(hit) == 2   # each costs a promotion page
        # a hit promotes the parked page back (lossy widen, fresh page)
        page = cache.ensure_resident(hit.nodes[0])
        assert al.refcount(page) == 1 and hit.nodes[0].resident
        assert cache.tier_promotions == 1 and tier.num_pages == 1
        assert cache.clear() == 0
        assert tier.num_pages == 0 and tier.nbytes == 0

    def test_tier_full_falls_back_to_host_demotion(self):
        # tier holds exactly ONE parked int4 page; the second eviction must
        # take the host round trip — and the requant counter at first
        # demotion records that requantization fired first
        cache, al, host, tier = _mk_adaptive(tier_pages=1)
        pages = _insert_seq(cache, al, [0, 1, 2, 3])
        al.free(pages)
        assert cache.evict(2) == 2
        assert cache.requants == 1 and cache.demotions == 1
        assert tier.num_pages == 1 and host.num_pages == 1
        assert cache.requants_at_first_demotion == 1
        assert cache.lookup([0, 1, 2, 3]).matched == 4   # no hole
        assert cache.clear() == 0
        assert tier.num_pages == 0 and host.num_pages == 0

    def test_requantizable_pages_tracks_tier_room(self):
        cache, al, host, tier = _mk_adaptive(tier_pages=1)
        pages = _insert_seq(cache, al, [0, 1, 2, 3, 4, 5])   # 3-page chain
        assert cache.requantizable_pages() == 0   # slot refs pin the chain
        al.free(pages)
        # three demotable pages but tier room for one
        assert cache.requantizable_pages() == 1
        cache.evict(1)
        assert cache.requantizable_pages() == 0   # tier full
        assert cache.clear() == 0

    def test_pinned_nodes_survive_requant_pressure(self):
        cache, al, host, tier = _mk_adaptive()
        pages = _insert_seq(cache, al, [0, 1, 2, 3])
        al.free(pages)
        hit = cache.lookup([0, 1, 2, 3])
        cache.pin(hit)
        assert cache.requantizable_pages() == 0
        assert cache.evict(10) == 0
        assert cache.requants == 0 and tier.num_pages == 0
        cache.unpin(hit)
        assert cache.evict(10) == 2 and cache.requants == 2
        assert cache.clear() == 0

    def test_partial_leaf_round_trips_through_tier(self):
        """A partially filled leaf page requants with valid_len masking and
        promotes back still serving its tokens."""
        cache, al, host, tier = _mk_adaptive()
        pages = _insert_seq(cache, al, [0, 1, 2])    # 2 pages, leaf half-full
        al.free(pages)
        assert cache.evict(2) == 2 and cache.requants == 2
        hit = cache.lookup([0, 1, 2, 3])
        assert hit.matched == 3 and hit.cow_valid == 1
        assert cache.ensure_resident(hit.cow_node) >= 0
        assert cache.clear() == 0


# ---------------------------------------------------------------------------
# CoW preserves source page bytes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("container", ["int8", "int4", "fp"])
def test_cow_preserves_source_page_bytes(container):
    """After copy_pool_pages(src, dst) and the sharer overwriting DST, the
    SOURCE page's stored bytes and scales are bit-identical to before."""
    rng = np.random.default_rng(0)
    ps, KV, hd = 4, 2, 16
    layout = PagedKVLayout(num_pages=6, page_size=ps, num_kv_heads=KV,
                           head_dim=hd, container=container)
    pool = init_paged_pool(layout)
    pt = jnp.asarray([[1, 2]], np.int32)
    bits = layout.bits
    for t in range(2 * ps):      # fill pages 1..2 of a fake sequence
        k = jnp.asarray(rng.normal(size=(1, 1, KV, hd)), jnp.float32)
        pool = paged_update(pool, k, k, pt, jnp.asarray([t], jnp.int32),
                            page_size=ps, container=container, int_bits=2,
                            frac_bits=(bits - 2) if bits else None)
    src, dst = 2, 3
    before = {k: np.asarray(v) for k, v in pool.items()}
    pool = copy_pool_pages(pool, src, dst)
    # copied page is byte-identical to the source
    for key in pool:
        np.testing.assert_array_equal(np.asarray(pool[key])[dst],
                                      before[key][src])
    # the sharer extends DST (its private copy) at the divergence offset
    pt2 = jnp.asarray([[1, 3]], np.int32)
    knew = jnp.asarray(rng.normal(size=(1, 1, KV, hd)), jnp.float32)
    pool = paged_update(pool, knew, knew, pt2,
                        jnp.asarray([ps + 2], jnp.int32), page_size=ps,
                        container=container, int_bits=2,
                        frac_bits=(bits - 2) if bits else None)
    # ... and the source page never moved
    for key in pool:
        np.testing.assert_array_equal(np.asarray(pool[key])[src],
                                      before[key][src])


# ---------------------------------------------------------------------------
# End-to-end: eviction under pool pressure + reserved/written error counts
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_serving_evicts_cached_prefixes_under_pressure(smoke_model):
    """A pool too small to RETAIN every request's prompt pages still serves
    the whole trace: unreferenced cached prefixes are LRU-evicted when
    admission / mid-decode allocation needs pages."""
    cfg, params = smoke_model
    rng = np.random.default_rng(2)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 9).astype(np.int32), 6)
            for i in range(5)]           # distinct prompts: nothing shareable
    srv = BatchedServer(cfg, params, batch_size=1, max_len=32, kv_bits=8,
                        page_size=8, num_pages=5,  # 4 usable ~ one request
                        prefix_cache="on")
    srv.run(reqs)
    assert all(r.done and len(r.out) == 6 for r in reqs)
    assert srv.prefix_cache.evictions > 0
    assert srv.release_prefix_cache() == 0
    assert srv.allocator.num_free == srv.allocator.num_usable


def test_out_of_pages_reports_reserved_vs_written(smoke_model):
    """With a live request holding reservations, an impossible admission
    reports written pages and reserved-but-unwritten pages separately."""
    err = OutOfPagesError(needed=9, free=1, total=4, rid=3, reserved=2,
                          written=1, evictable=1)
    assert err.reserved == 2 and err.written == 1 and err.evictable == 1
    assert "reserved-unwritten" in str(err)

    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=64, kv_bits=8,
                        page_size=8, num_pages=4, prefix_cache="on")
    rng = np.random.default_rng(0)
    with pytest.raises(OutOfPagesError) as ei:
        srv.run([Request(0, rng.integers(0, cfg.vocab_size, 50)
                         .astype(np.int32), 40)])
    assert ei.value.needed > ei.value.total
    assert ei.value.written == 0 and ei.value.reserved == 0
    assert srv.allocator.num_free == srv.allocator.num_usable  # no pin leak


def test_prefix_cache_requires_paged(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="page-size"):
        BatchedServer(cfg, params, batch_size=2, max_len=32,
                      prefix_cache="on")
