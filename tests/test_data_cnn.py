"""Data pipelines + the paper's CNNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.lm import LMDataConfig, lm_batch
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import digits_dataset, shapes32_dataset
from repro.models.cnn import (ALEXNET_SMALL, CONVNET, LENET, cnn_accuracy,
                              cnn_forward, cnn_loss, cnn_traffic_model,
                              init_cnn)
from repro.core.fixedpoint import FixedPointFormat
from repro.core.policy import LayerPolicy, PrecisionPolicy

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_digits_deterministic_and_shaped():
    x1, y1 = digits_dataset(32, seed=7)
    x2, y2 = digits_dataset(32, seed=7)
    np.testing.assert_array_equal(x1, x2)
    assert x1.shape == (32, 28, 28, 1) and x1.min() >= 0 and x1.max() <= 1
    assert set(np.unique(y1)).issubset(set(range(10)))


def test_shapes32_all_classes():
    x, y = shapes32_dataset(200, seed=0)
    assert x.shape == (200, 32, 32, 3)
    assert len(np.unique(y)) == 10


def test_lm_batch_deterministic_and_learnable():
    cfg = LMDataConfig(vocab_size=64, seq_len=128, batch_size=4, seed=3)
    b1, b2 = lm_batch(cfg, 5), lm_batch(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 128)
    # labels shift
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # Markov structure: the same (prev, cur) state recurs with few successors
    toks = np.asarray(lm_batch(cfg, 0)["tokens"]).reshape(-1)
    from collections import defaultdict
    succ = defaultdict(set)
    for a, b, c in zip(toks[:-2], toks[1:-1], toks[2:]):
        succ[(a, b)].add(c)
    multi = [s for s in succ.values() if len(s) > 0]
    avg_branch = np.mean([len(s) for s in multi])
    assert avg_branch < cfg.vocab_size / 4  # far from uniform


def test_pipeline_prefetch_and_restore():
    produced = []

    def batch_fn(step):
        produced.append(step)
        return {"step": np.asarray(step)}

    p = DataPipeline(batch_fn, cfg=None)
    b0 = next(p)
    b1 = next(p)
    assert int(b0["step"]) == 0 and int(b1["step"]) == 1
    st = p.state
    p2 = DataPipeline(batch_fn, start_step=0)
    p2.restore(st)
    assert int(next(p2)["step"]) == st["step"]


# ---------------------------------------------------------------------------
# CNNs (paper networks)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", [LENET, CONVNET, ALEXNET_SMALL])
def test_cnn_forward_shapes(spec):
    params = init_cnn(jax.random.PRNGKey(0), spec)
    x = jnp.zeros((2,) + spec.input_shape)
    logits = cnn_forward(params, x, spec)
    assert logits.shape == (2, spec.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cnn_learns_digits_quickly():
    """A few hundred LeNet steps reach >80% on synthetic digits — the
    accuracy signal the paper's experiments need."""
    spec = LENET
    params = init_cnn(jax.random.PRNGKey(0), spec)
    xs, ys = digits_dataset(2048, seed=0)
    xv, yv = digits_dataset(512, seed=1)
    lr = 0.05
    grad = jax.jit(jax.grad(lambda p, b: cnn_loss(p, b, spec)))
    for i in range(170):
        sl = slice((i * 64) % 1984, (i * 64) % 1984 + 64)
        g = grad(params, {"image": jnp.asarray(xs[sl]),
                          "label": jnp.asarray(ys[sl])})
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, g)
    acc = cnn_accuracy(params, jnp.asarray(xv), jnp.asarray(yv), spec)
    assert acc > 0.85, acc


def test_cnn_policy_quantization_changes_output():
    spec = LENET
    params = init_cnn(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4,) + spec.input_shape)
    full = cnn_forward(params, x, spec)
    pol = PrecisionPolicy.uniform(
        spec.layer_names, FixedPointFormat(1, 2), FixedPointFormat(2, 0))
    quant = cnn_forward(params, x, spec, pol)
    assert not np.allclose(np.asarray(full), np.asarray(quant))
    # generous precision ~= full precision
    pol_hi = PrecisionPolicy.uniform(
        spec.layer_names, FixedPointFormat(2, 12), FixedPointFormat(8, 8))
    hi = cnn_forward(params, x, spec, pol_hi)
    np.testing.assert_allclose(np.asarray(full), np.asarray(hi),
                               rtol=0.02, atol=0.02)


def test_cnn_traffic_model_matches_paper_structure():
    tm = cnn_traffic_model(LENET)
    assert tm.names == ("layer1", "layer2", "layer3", "layer4")
    # LeNet weights ~= 431k params
    w, d = tm.accesses(batch_size=1, mode="single")
    total_params = sum(l.weight_elems for l in tm.layers)
    assert 400_000 < total_params < 450_000
    # batch mode amortizes weights
    w_b, d_b = tm.accesses(batch_size=100, mode="batch")
    w_s, d_s = tm.accesses(batch_size=100, mode="single")
    assert w_s == 100 * w_b and d_b == d_s
    # TR for a uniform 8-bit policy = 0.25 exactly
    pol = PrecisionPolicy.uniform(tm.names, FixedPointFormat(1, 7),
                                  FixedPointFormat(4, 4))
    assert tm.traffic_ratio(pol, batch_size=50) == pytest.approx(0.25)
