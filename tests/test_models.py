"""Per-arch smoke tests (assignment requirement) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, applicable
from repro.models.attention import attend_chunked, attend_full
from repro.models.moe import init_moe, moe_apply
from repro.models.transformer import (decode_step, forward, init_model,
                                      prefill, train_loss)

jax.config.update("jax_platform_name", "cpu")


def _batch(cfg, B=2, S=64):
    if cfg.frontend:
        b = {"embeds": jax.random.normal(jax.random.PRNGKey(9),
                                         (B, S, cfg.d_model), jnp.float32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    else:
        b = {"tokens": jax.random.randint(jax.random.PRNGKey(9), (B, S), 0,
                                          cfg.vocab_size, jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.mrope:
        b["mrope_positions"] = jnp.zeros((B, S, 3), jnp.int32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """REDUCED same-family config: one forward + one grad step on CPU,
    asserting output shapes and no NaNs (assignment smoke contract)."""
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    B, S = 2, 64
    _, logits, _, _ = forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = jax.jit(lambda p, b: train_loss(p, b, cfg))(params, batch)
    assert jnp.isfinite(loss)
    grads = jax.jit(jax.grad(lambda p, b: train_loss(p, b, cfg)[0]))(
        params, batch)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 16384, 202048),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    # MoE extras
    if arch == "deepseek-v3-671b":
        assert (cfg.num_experts, cfg.experts_per_token,
                cfg.moe_d_ff, cfg.num_shared_experts) == (256, 8, 2048, 1)
        assert cfg.attention_type == "mla" and cfg.mtp_depth == 1
    if arch == "llama4-maverick-400b-a17b":
        assert (cfg.num_experts, cfg.experts_per_token) == (128, 1)
    if arch == "jamba-v0.1-52b":
        assert (cfg.num_experts, cfg.experts_per_token) == (16, 2)
        kinds = cfg.layer_kinds
        assert kinds.count("attn") == 4 and kinds.count("mamba") == 28
    if arch == "xlstm-350m":
        kinds = cfg.layer_kinds
        assert kinds.count("slstm") == 3 and kinds.count("mlstm") == 21


@pytest.mark.parametrize("arch",
                         [a for a in ARCH_IDS
                          if get_smoke_config(a).family != "encoder"])
def test_arch_decode_consistency(arch):
    """Prefill+decode must agree with teacher-forced full forward."""
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, P = 2, 16
    if cfg.frontend:
        pytest.skip("frontend archs decode from embeds; covered in serve")
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                              cfg.vocab_size, jnp.int32)
    # full forward logits at last position
    _, full_logits, _, _ = forward(params, {"tokens": toks}, cfg)
    logits_last, caches, pos = prefill(params, {"tokens": toks}, cfg,
                                       max_len=32)
    np.testing.assert_allclose(
        np.asarray(logits_last, np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=0.08, atol=0.05)
    # one decode step continues consistently (shape + finite)
    nxt = jnp.argmax(logits_last, -1).astype(jnp.int32)
    step_logits, caches = decode_step(params, nxt, pos, caches, cfg)
    assert step_logits.shape == (B, cfg.vocab_size)
    # teacher-forced check: decode at pos P for token nxt == forward on
    # the extended sequence
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    _, full2, _, _ = forward(params, {"tokens": toks2}, cfg)
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(full2[:, -1], np.float32),
                               rtol=0.12, atol=0.08)


def test_attention_chunked_matches_full():
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 96, 8, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_full = attend_full(q, k, v, pos, jnp.arange(S), causal=True)
    o_chunk = attend_chunked(q, k, v, pos, 0, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_chunk),
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_matches_expanded():
    cfg = get_smoke_config("deepseek-v3-671b")
    from repro.models.attention import init_mla, mla_apply
    params = init_mla(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y0, _ = mla_apply(params, x, pos, cfg=cfg, absorbed=False)
    y1, _ = mla_apply(params, x, pos, cfg=cfg, absorbed=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-3, atol=2e-3)


def test_moe_scatter_matches_eval_all_without_drops():
    cfg = dataclasses.replace(get_smoke_config("jamba-v0.1-52b"),
                              moe_capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_ref, _ = moe_apply(p, x, cfg=cfg, mode="eval_all")
    y_sc, _ = moe_apply(p, x, cfg=cfg, mode="scatter")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sc),
                               rtol=2e-4, atol=2e-4)


def test_quantized_kv_cache_close_to_fp():
    """int8 Q(2,6) KV cache decode stays close to the fp cache path."""
    from repro.quant.apply import build_model_quant, transformer_layer_names
    from repro.core.policy import PrecisionPolicy
    from repro.core.fixedpoint import FixedPointFormat

    cfg = get_smoke_config("yi-34b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size, jnp.int32)
    logits_fp, caches_fp, pos = prefill(params, {"tokens": toks}, cfg,
                                        max_len=16)
    pol = PrecisionPolicy.uniform(transformer_layer_names(cfg), None,
                                  FixedPointFormat(2, 6))
    quant = build_model_quant(pol, cfg, quantize_kv=True,
                              quantize_activations=False)
    logits_q, caches_q, _ = prefill(params, {"tokens": toks}, cfg,
                                    max_len=16, quant=quant)
    # int8 cache container really is int8
    leaf = jax.tree_util.tree_leaves(caches_q)[0]
    assert leaf.dtype == jnp.int8
    # logits of a random-init model are near-uniform, so argmax is not a
    # stable metric; assert the LOGIT perturbation is small instead
    d = np.abs(np.asarray(logits_fp, np.float32)
               - np.asarray(logits_q, np.float32))
    spread = float(np.std(np.asarray(logits_fp, np.float32)))
    assert d.max() <= 0.5 * spread, (d.max(), spread)


def test_shape_applicability_matrix():
    """31 applicable cells out of the nominal 40 (DESIGN.md skip rules)."""
    cells = [(a, s) for a in ARCH_IDS for s in SHAPES
             if applicable(get_config(a), SHAPES[s])]
    assert len(cells) == 31
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert ("xlstm-350m", "long_500k") in cells
    assert ("jamba-v0.1-52b", "long_500k") in cells
    assert ("qwen2-72b", "long_500k") not in cells
