"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU). Hypothesis drives the shape space; tolerances are exact for
grid ops (quantization is deterministic) and ~1e-4 for float accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

_SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# quant_cast
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(m=st.integers(1, 300), n=st.integers(1, 700),
       i=st.integers(1, 8), f=st.integers(0, 8),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_quant_cast_matches_ref(m, n, i, f, dtype):
    key = jax.random.PRNGKey(m * 1000 + n)
    x = (jax.random.normal(key, (m, n), jnp.float32) * 5).astype(dtype)
    y = ops.quant_cast(x, i, f)
    yr = ref.quant_cast_ref(x, i, f)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(yr, np.float32))
    assert y.dtype == x.dtype


def test_quant_cast_3d_and_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 37, 129)) * 3
    y = ops.quant_cast(x, 3, 5)
    y2 = ops.quant_cast(y, 3, 5)
    np.testing.assert_array_equal(y, y2)  # grid projection is idempotent
    # values are on the grid
    scaled = np.asarray(y) * 2**5
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-5)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(m=st.integers(1, 64), words=st.integers(1, 16),
       bits=st.sampled_from([2, 4, 8, 16]))
def test_pack_unpack_roundtrip(m, words, bits):
    vpw = 32 // bits
    n = words * vpw
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = jax.random.randint(jax.random.PRNGKey(m * 31 + words), (m, n),
                           lo, hi + 1, jnp.int32)
    w = ops.pack(q, bits)
    assert w.shape == (m, words)
    np.testing.assert_array_equal(w, ref.pack_ref(q, bits))
    q2 = ops.unpack(w, bits)
    np.testing.assert_array_equal(q2, q)
    np.testing.assert_array_equal(ref.unpack_ref(w, bits), q)


def test_pack_footprint():
    q = jnp.zeros((8, 128), jnp.int32)
    for bits in (2, 4, 8, 16):
        w = ops.pack(q, bits)
        assert w.size * 32 == q.size * bits  # true N-bit footprint


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(m=st.integers(1, 130), k=st.integers(1, 300), n=st.integers(1, 130),
       adt=st.sampled_from(["float32", "bfloat16"]))
def test_quant_matmul_matches_ref(m, k, n, adt):
    key = jax.random.PRNGKey(m + k * 7 + n * 11)
    a = (jax.random.normal(key, (m, k), jnp.float32)).astype(adt)
    wq = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -128, 128,
                            jnp.int32).astype(jnp.int8)
    s = jax.random.uniform(jax.random.fold_in(key, 2), (n,),
                           minval=0.001, maxval=0.05)
    out = ops.qmatmul(a, wq, s)
    expect = ref.quant_matmul_ref(a, wq, s)
    tol = 2e-2 if adt == "bfloat16" else 1e-4
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * 10)


# ---------------------------------------------------------------------------
# kv_attention
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(b=st.integers(1, 3), kv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]), hd=st.sampled_from([16, 32, 64]),
       t=st.integers(8, 200), frac=st.integers(4, 7))
def test_kv_attention_matches_ref(b, kv, g, hd, t, frac):
    key = jax.random.PRNGKey(b * 97 + t)
    h = kv * g
    q = jax.random.normal(key, (b, h, hd), jnp.float32)
    k_q = jax.random.randint(jax.random.fold_in(key, 1), (b, t, kv, hd),
                             -128, 128, jnp.int32).astype(jnp.int8)
    v_q = jax.random.randint(jax.random.fold_in(key, 2), (b, t, kv, hd),
                             -128, 128, jnp.int32).astype(jnp.int8)
    kv_len = max(1, t - 3)
    out = ops.kv_attention(q, k_q, v_q, kv_len, int_bits=2, frac_bits=frac,
                           block_t=64)
    expect = ref.kv_attention_ref(q, k_q, v_q, 2, frac, kv_len)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# paged_kv_attention
# ---------------------------------------------------------------------------
# shared with benchmarks/kernel_bench.py — one fixture, one pool layout
_mk_fragmented_pool = ref.make_fragmented_pool


@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 3), kv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 4]), hd=st.sampled_from([16, 32]),
       np_pages=st.integers(1, 5), ps=st.sampled_from([8, 16]),
       bits=st.sampled_from([0, 4, 8]), tail=st.integers(0, 7))
def test_paged_kv_attention_matches_ref(b, kv, g, hd, np_pages, ps, bits,
                                        tail):
    """Paged kernel vs dense-gather oracle on randomized fragmented page
    layouts, including a partially filled last page (``tail``)."""
    rng = np.random.default_rng(b * 1000 + np_pages * 17 + ps + bits + tail)
    h = kv * g
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    kq, vq, ks, vs, pt = _mk_fragmented_pool(rng, b, np_pages, ps, kv, hd,
                                             bits)
    # per-row lengths; at least 1, last page partially filled by `tail`
    full = np_pages * ps
    lens = np.maximum(1, full - tail - rng.integers(0, ps, b)).astype(np.int32)
    out = ops.paged_kv_attention(q, kq, vq, ks, vs, jnp.asarray(pt),
                                 jnp.asarray(lens), bits=bits)
    expect = ref.paged_kv_attention_ref(q, kq, vq, ks, vs, pt, lens,
                                        bits=bits)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_paged_kv_attention_fragmented_vs_contiguous():
    """The same logical cache must give the same output regardless of WHICH
    pool pages back it (fragmentation invariance)."""
    rng = np.random.default_rng(0)
    B, KV, G, hd, ps, NP = 2, 2, 2, 32, 8, 3
    q = jnp.asarray(rng.normal(size=(B, KV * G, hd)), jnp.float32)
    logical_k = rng.integers(-128, 128, (B, NP, ps, KV, hd))
    logical_v = rng.integers(-128, 128, (B, NP, ps, KV, hd))
    lens = jnp.asarray([20, 17], jnp.int32)
    outs = []
    for perm_seed in (1, 2):
        prng = np.random.default_rng(perm_seed)
        ids = np.arange(1, 1 + B * NP)
        prng.shuffle(ids)
        pt = ids.reshape(B, NP).astype(np.int32)
        P = 1 + B * NP
        kq = np.zeros((P, ps, KV, hd), np.int8)
        vq = np.zeros((P, ps, KV, hd), np.int8)
        kq[pt] = logical_k
        vq[pt] = logical_v
        sc = np.full(P, 2.0 ** -5, np.float32)
        outs.append(ops.paged_kv_attention(
            q, jnp.asarray(kq), jnp.asarray(vq), jnp.asarray(sc),
            jnp.asarray(sc), jnp.asarray(pt), lens, bits=8))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


def test_paged_int4_matches_int8_on_same_grid():
    """A 4-bit grid stored packed (bits=4) and widened to int8 (bits=8) must
    produce identical attention outputs — packing is lossless."""
    from repro.core.qtensor import pack_bits
    rng = np.random.default_rng(3)
    B, KV, G, hd, ps, NP = 1, 2, 2, 16, 8, 2
    P = 1 + B * NP
    q = jnp.asarray(rng.normal(size=(B, KV * G, hd)), jnp.float32)
    grid_k = rng.integers(-8, 8, (P, ps, KV, hd))
    grid_v = rng.integers(-8, 8, (P, ps, KV, hd))
    sc = jnp.full((P,), 0.25, jnp.float32)
    pt = jnp.asarray([[2, 1]], jnp.int32)
    lens = jnp.asarray([13], jnp.int32)
    o8 = ops.paged_kv_attention(q, jnp.asarray(grid_k, jnp.int8),
                                jnp.asarray(grid_v, jnp.int8), sc, sc, pt,
                                lens, bits=8)
    k4, _ = pack_bits(jnp.asarray(grid_k, jnp.int32), 4)
    v4, _ = pack_bits(jnp.asarray(grid_v, jnp.int32), 4)
    o4 = ops.paged_kv_attention(q, k4, v4, sc, sc, pt, lens, bits=4)
    np.testing.assert_array_equal(np.asarray(o8), np.asarray(o4))


# ---------------------------------------------------------------------------
# paged_kv_attention_chunk (variable-length prefill-chunk kernel)
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 2), kv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2]), hd=st.sampled_from([16, 32]),
       ps=st.sampled_from([8, 16]), s=st.sampled_from([2, 5, 8, 13]),
       start=st.integers(0, 19), bits=st.sampled_from([0, 4, 8]))
def test_paged_kv_attention_chunk_matches_ref(b, kv, g, hd, ps, s, start,
                                              bits):
    """Chunk kernel vs dense-gather oracle on fragmented page tables:
    per-row start positions straddle page boundaries (``start`` is
    arbitrary, so chunks begin/end mid-page → partial last pages), history
    lengths differ per row, and every container is swept."""
    rng = np.random.default_rng(b * 1000 + ps * 31 + s * 7 + start + bits)
    h = kv * g
    # per-row starts: row r begins a little earlier than `start`
    starts = np.maximum(0, start - rng.integers(0, 4, b)).astype(np.int32)
    np_pages = max(1, -(-int(starts.max() + s) // ps))
    kq, vq, ks, vs, pt = _mk_fragmented_pool(rng, b, np_pages, ps, kv, hd,
                                             bits)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    lens = starts + s
    out = ops.paged_kv_attention_chunk(q, kq, vq, ks, vs, jnp.asarray(pt),
                                       jnp.asarray(starts),
                                       jnp.asarray(lens), bits=bits,
                                       block_q=4)
    expect = ref.paged_kv_attention_chunk_ref(q, kq, vq, ks, vs, pt, starts,
                                              lens, bits=bits)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_paged_chunk_block_q_invariance():
    """The query-block size is a tiling knob, not a numerics knob: the same
    chunk attended at block_q 1/4/8 gives the same output (page-order
    accumulation is identical, only the grid changes)."""
    rng = np.random.default_rng(9)
    B, KV, G, hd, ps, NP, S = 2, 2, 2, 16, 8, 3, 7
    kq, vq, ks, vs, pt = _mk_fragmented_pool(rng, B, NP, ps, KV, hd, 8)
    q = jnp.asarray(rng.normal(size=(B, S, KV * G, hd)), jnp.float32)
    starts = jnp.asarray([2, 9], jnp.int32)
    lens = starts + S
    outs = [np.asarray(ops.paged_kv_attention_chunk(
        q, kq, vq, ks, vs, jnp.asarray(pt), starts, lens, bits=8,
        block_q=bq)) for bq in (1, 4, 8)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 2), kv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2]), s=st.sampled_from([1, 5, 8]),
       start=st.integers(0, 19), bits=st.sampled_from([0, 4, 8]))
def test_paged_chunk_block_kv_matches_default(b, kv, g, s, start, bits):
    """``block_kv=True`` is a DMA-tiling knob, not a numerics knob: the
    KV-head-blocked grid must agree with the per-head default (and the
    oracle) on the same fragmented tables, every container, decode (S=1)
    and prefill shapes. Agreement is float-ULP, not bitwise — the blocked
    kernel's dot operands are strided head-slices (see the kernel
    docstring)."""
    rng = np.random.default_rng(b * 577 + s * 13 + start * 3 + bits)
    hd, ps = 32, 16
    starts = np.maximum(0, start - rng.integers(0, 4, b)).astype(np.int32)
    np_pages = max(1, -(-int(starts.max() + s) // ps))
    kq, vq, ks, vs, pt = _mk_fragmented_pool(rng, b, np_pages, ps, kv, hd,
                                             bits)
    q = jnp.asarray(rng.normal(size=(b, s, kv * g, hd)), jnp.float32)
    lens = starts + s
    args = (q, kq, vq, ks, vs, jnp.asarray(pt), jnp.asarray(starts),
            jnp.asarray(lens))
    blocked = ops.paged_kv_attention_chunk(*args, bits=bits, block_q=4,
                                           block_kv=True)
    default = ops.paged_kv_attention_chunk(*args, bits=bits, block_q=4)
    np.testing.assert_allclose(blocked, default, rtol=1e-5, atol=1e-5)
    expect = ref.paged_kv_attention_chunk_ref(q, kq, vq, ks, vs, pt, starts,
                                              lens, bits=bits)
    np.testing.assert_allclose(blocked, expect, rtol=1e-4, atol=1e-4)


def test_paged_decode_is_chunk_special_case():
    """The decode entry point == the chunk kernel at S=1 with the causal
    bound collapsed into the length mask (exact: same kernel, same grid
    accumulation)."""
    rng = np.random.default_rng(4)
    B, KV, G, hd, ps, NP = 2, 2, 2, 16, 8, 3
    kq, vq, ks, vs, pt = _mk_fragmented_pool(rng, B, NP, ps, KV, hd, 8)
    q = jnp.asarray(rng.normal(size=(B, KV * G, hd)), jnp.float32)
    lens = jnp.asarray([13, 20], jnp.int32)
    d = ops.paged_kv_attention(q, kq, vq, ks, vs, jnp.asarray(pt), lens,
                               bits=8)
    c = ops.paged_kv_attention_chunk(q[:, None], kq, vq, ks, vs,
                                     jnp.asarray(pt), lens - 1, lens,
                                     bits=8, block_q=1)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(c[:, 0]))


def test_kv_attention_masks_tail():
    """Entries beyond kv_len must not affect the output."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 4, 32))
    k_q = jax.random.randint(key, (1, 64, 2, 32), -128, 128,
                             jnp.int32).astype(jnp.int8)
    v_q = jax.random.randint(jax.random.fold_in(key, 1), (1, 64, 2, 32),
                             -128, 128, jnp.int32).astype(jnp.int8)
    out1 = ops.kv_attention(q, k_q, v_q, 10, int_bits=2, frac_bits=5,
                            block_t=16)
    k_q2 = k_q.at[:, 10:].set(127)
    v_q2 = v_q.at[:, 10:].set(-128)
    out2 = ops.kv_attention(q, k_q2, v_q2, 10, int_bits=2, frac_bits=5,
                            block_t=16)
    np.testing.assert_allclose(out1, out2, rtol=1e-6)
