"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU). Hypothesis drives the shape space; tolerances are exact for
grid ops (quantization is deterministic) and ~1e-4 for float accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

_SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# quant_cast
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(m=st.integers(1, 300), n=st.integers(1, 700),
       i=st.integers(1, 8), f=st.integers(0, 8),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_quant_cast_matches_ref(m, n, i, f, dtype):
    key = jax.random.PRNGKey(m * 1000 + n)
    x = (jax.random.normal(key, (m, n), jnp.float32) * 5).astype(dtype)
    y = ops.quant_cast(x, i, f)
    yr = ref.quant_cast_ref(x, i, f)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(yr, np.float32))
    assert y.dtype == x.dtype


def test_quant_cast_3d_and_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 37, 129)) * 3
    y = ops.quant_cast(x, 3, 5)
    y2 = ops.quant_cast(y, 3, 5)
    np.testing.assert_array_equal(y, y2)  # grid projection is idempotent
    # values are on the grid
    scaled = np.asarray(y) * 2**5
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-5)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(m=st.integers(1, 64), words=st.integers(1, 16),
       bits=st.sampled_from([2, 4, 8, 16]))
def test_pack_unpack_roundtrip(m, words, bits):
    vpw = 32 // bits
    n = words * vpw
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = jax.random.randint(jax.random.PRNGKey(m * 31 + words), (m, n),
                           lo, hi + 1, jnp.int32)
    w = ops.pack(q, bits)
    assert w.shape == (m, words)
    np.testing.assert_array_equal(w, ref.pack_ref(q, bits))
    q2 = ops.unpack(w, bits)
    np.testing.assert_array_equal(q2, q)
    np.testing.assert_array_equal(ref.unpack_ref(w, bits), q)


def test_pack_footprint():
    q = jnp.zeros((8, 128), jnp.int32)
    for bits in (2, 4, 8, 16):
        w = ops.pack(q, bits)
        assert w.size * 32 == q.size * bits  # true N-bit footprint


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(m=st.integers(1, 130), k=st.integers(1, 300), n=st.integers(1, 130),
       adt=st.sampled_from(["float32", "bfloat16"]))
def test_quant_matmul_matches_ref(m, k, n, adt):
    key = jax.random.PRNGKey(m + k * 7 + n * 11)
    a = (jax.random.normal(key, (m, k), jnp.float32)).astype(adt)
    wq = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -128, 128,
                            jnp.int32).astype(jnp.int8)
    s = jax.random.uniform(jax.random.fold_in(key, 2), (n,),
                           minval=0.001, maxval=0.05)
    out = ops.qmatmul(a, wq, s)
    expect = ref.quant_matmul_ref(a, wq, s)
    tol = 2e-2 if adt == "bfloat16" else 1e-4
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * 10)


# ---------------------------------------------------------------------------
# kv_attention
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(b=st.integers(1, 3), kv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]), hd=st.sampled_from([16, 32, 64]),
       t=st.integers(8, 200), frac=st.integers(4, 7))
def test_kv_attention_matches_ref(b, kv, g, hd, t, frac):
    key = jax.random.PRNGKey(b * 97 + t)
    h = kv * g
    q = jax.random.normal(key, (b, h, hd), jnp.float32)
    k_q = jax.random.randint(jax.random.fold_in(key, 1), (b, t, kv, hd),
                             -128, 128, jnp.int32).astype(jnp.int8)
    v_q = jax.random.randint(jax.random.fold_in(key, 2), (b, t, kv, hd),
                             -128, 128, jnp.int32).astype(jnp.int8)
    kv_len = max(1, t - 3)
    out = ops.kv_attention(q, k_q, v_q, kv_len, int_bits=2, frac_bits=frac,
                           block_t=64)
    expect = ref.kv_attention_ref(q, k_q, v_q, 2, frac, kv_len)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_kv_attention_masks_tail():
    """Entries beyond kv_len must not affect the output."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 4, 32))
    k_q = jax.random.randint(key, (1, 64, 2, 32), -128, 128,
                             jnp.int32).astype(jnp.int8)
    v_q = jax.random.randint(jax.random.fold_in(key, 1), (1, 64, 2, 32),
                             -128, 128, jnp.int32).astype(jnp.int8)
    out1 = ops.kv_attention(q, k_q, v_q, 10, int_bits=2, frac_bits=5,
                            block_t=16)
    k_q2 = k_q.at[:, 10:].set(127)
    v_q2 = v_q.at[:, 10:].set(-128)
    out2 = ops.kv_attention(q, k_q2, v_q2, 10, int_bits=2, frac_bits=5,
                            block_t=16)
    np.testing.assert_allclose(out1, out2, rtol=1e-6)
