"""Loop-aware HLO cost model: exactness on known-shape programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze

jax.config.update("jax_platform_name", "cpu")


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_multiplied_by_trip_count():
    def f(x):
        def body(c, _):
            return c @ x + 1.0, None
        y, _ = jax.lax.scan(body, jnp.ones((8, 8)), None, length=12)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    r = analyze(c.as_text())
    assert r["flops"] == 12 * 2 * 8 * 8 * 8
    # XLA's own analysis counts the body once (the bug we work around);
    # Compiled.cost_analysis returns a per-module list on some jax versions
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < r["flops"]


def test_nested_scan_trips_multiply():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ x, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, jnp.ones((4, 4)), None, length=3)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((4, 4), jnp.float32))
    r = analyze(c.as_text(), loops=True)
    assert r["flops"] == 3 * 5 * 2 * 4 * 4 * 4
    trips = sorted(l["trip"] for l in r["loops"])
    assert trips == [3, 5]


def test_dynamic_slice_not_priced_at_full_operand():
    """Slicing one row per scan step must cost ~row bytes, not the whole
    stacked array (the xs-threading pattern of lax.scan)."""
    def f(xs):
        def body(c, x_t):
            return c + x_t.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), xs)
        return out

    n, d = 64, 1024
    c = _compile(f, jax.ShapeDtypeStruct((n, d), jnp.float32))
    r = analyze(c.as_text())
    # true traffic ~= one pass over xs (4*n*d) + small carries; the broken
    # full-operand pricing would be ~n * (4*n*d) = 16 MiB * 64
    assert r["hbm_bytes"] < 10 * 4 * n * d


def test_dot_flops_use_contracting_dims():
    def f(a, b):
        return a @ b

    c = _compile(f, jax.ShapeDtypeStruct((32, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 16), jnp.float32))
    r = analyze(c.as_text())
    assert r["flops"] == 2 * 32 * 16 * 128


def test_cond_prices_expensive_branch():
    def f(p, x):
        return jax.lax.cond(p, lambda x: (x @ x) @ x, lambda x: x, x)

    c = _compile(f, jax.ShapeDtypeStruct((), jnp.bool_),
                 jax.ShapeDtypeStruct((16, 16), jnp.float32))
    r = analyze(c.as_text())
    assert r["flops"] >= 2 * 2 * 16 * 16 * 16
