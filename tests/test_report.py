"""Regression tests for benchmarks.report --serve: the BENCH_serve.json
trend table must render mixed-vintage trajectories — points that predate
the SLO fields (None values) or carry entirely different workload keys —
with explicit "n/a" cells, never a crash."""
import json

import benchmarks.report as report


def _points():
    return [
        # pre-PR-8 vintage: SLO metrics exist but are None
        {"when": "2026-01-01 00:00:00", "arch": "a", "fast": False,
         "summary": {"traffic": {"goodput": None, "ttft_p99_s": None,
                                 "token_agreement": 0.95}}},
        # current vintage: full numbers + a nested per-config dict
        {"when": "2026-02-01 00:00:00", "arch": "a", "fast": False,
         "summary": {"traffic": {"goodput": 0.91, "ttft_p99_s": 0.004,
                                 "token_agreement": 1.0}}},
        # a different workload that only ever appears once
        {"when": "2026-02-02 00:00:00", "arch": "a", "fast": True,
         "summary": {"replicas": {"goodput_1rep": 0.8,
                                  "goodput_2rep": 1.0,
                                  "goodput_delta": 0.2}}},
        # mixed-bench shape: metrics at summary top level
        {"when": "2026-02-03 00:00:00", "arch": "a", "fast": False,
         "summary": {"tokens_per_s": {"cfgA": 10.0, "cfgB": 12.5}}},
    ]


def test_serve_section_handles_missing_fields(tmp_path, monkeypatch):
    path = tmp_path / "BENCH_serve.json"
    path.write_text(json.dumps({"bench": "paged_serve",
                                "trajectory": _points()}))
    monkeypatch.setattr(report, "BENCH_TRAJECTORY", str(path))
    text = report.serve_section()
    # the None-valued first point renders as n/a, the numeric delta rows
    # render normally, and every workload gets its own table
    assert "n/a" in text
    assert "goodput" in text and "replicas" in text and "mixed" in text
    assert "0.91" in text


def test_serve_section_tolerates_absent_or_garbage_file(tmp_path,
                                                        monkeypatch):
    monkeypatch.setattr(report, "BENCH_TRAJECTORY",
                        str(tmp_path / "missing.json"))
    assert "no BENCH_serve.json trajectory" in report.serve_section()
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    monkeypatch.setattr(report, "BENCH_TRAJECTORY", str(bad))
    assert "no BENCH_serve.json trajectory" in report.serve_section()
