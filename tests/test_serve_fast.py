"""Serving hot-path tests: bucketed + batched prefill identity, unified
kernel-routed attention (prefill AND decode), and admission preflight.

* Bucketed chunked prefill must produce token-identical output to the
  slot-granular (token-at-a-time) reference prefill across bucket
  boundaries, at kv-bits {0, 8, 4}.
* Multi-request BATCHED prefill (same-bucket rows stacked into one
  [n, bucket] forward) must be token-identical to one-at-a-time bucketed
  prefill at kv-bits {0, 8, 4}, with strictly fewer forwards; an
  OutOfPagesError mid-batch rolls back every partially admitted row.
* ``attn_impl="pallas"`` (kernels.paged_kv_attention, interpret mode on
  CPU) must match the jnp gather path on fragmented page tables to float
  tolerance for BOTH chunk shapes — S=1 decode and S>1 prefill chunks
  (partial last pages, padded tails, mixed per-layer profiles); the
  kernel's per-page online softmax reorders accumulation, so the contract
  is allclose, not bitwise.
* Paged admission preflights worst-case page demand and raises
  ``OutOfPagesError`` with counts instead of dying mid-prefill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.paged_kv import OutOfPagesError, PageAllocator
from repro.launch.serve import BatchedServer, Request, _pow2_bucket
from repro.models.attention import (KVQuantSpec, gqa_apply, init_gqa,
                                    init_paged_kv_cache, paged_cache_update)
from repro.models.transformer import init_model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2-72b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_pow2_bucket():
    assert [_pow2_bucket(n, 16) for n in (1, 2, 3, 7, 8, 9, 16, 17, 40)] \
        == [1, 2, 4, 8, 8, 16, 16, 16, 16]


# ---------------------------------------------------------------------------
# Bucketed prefill == stepwise prefill, token for token
# ---------------------------------------------------------------------------
# Prompt lengths straddle the bucket-8 boundaries: 1 (no prefill at all),
# bucket-1, bucket, bucket+1, sub-bucket, and multi-chunk (21 -> chunks of
# 16-capped bucket 8: 8 + 8 + 4).
_BUCKET_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)

def mk():
    rng = np.random.default_rng(7)
    lens = [1, 7, 8, 9, 3, 21]
    return [Request(i, rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    5 + (i % 3)) for i, L in enumerate(lens)]

for kv_bits in (0, 8, 4):
    ref = BatchedServer(cfg, params, batch_size=3, max_len=32,
                        kv_bits=kv_bits, page_size=8, prefill="stepwise")
    out_ref = ref.run(mk())
    fast = BatchedServer(cfg, params, batch_size=3, max_len=32,
                         kv_bits=kv_bits, page_size=8, prefill="bucketed",
                         prefill_bucket=8)
    out_fast = fast.run(mk())
    for a, b in zip(out_ref, out_fast):
        assert a.out == b.out, (kv_bits, a.rid, a.out, b.out)
    assert all(r.done for r in out_fast)
    # the whole point: O(prompt) whole-batch forwards -> O(prompt/bucket)
    assert fast.prefill_forwards < ref.prefill_forwards, (
        fast.prefill_forwards, ref.prefill_forwards)
    assert fast.allocator.num_free == fast.allocator.num_usable
    print(f"kv_bits={kv_bits} identical "
          f"({ref.prefill_forwards} -> {fast.prefill_forwards} prefill fwd)")
print("BUCKETED_IDENTITY_OK")
"""


def test_bucketed_prefill_matches_stepwise():
    """Bucketed chunked prefill == token-at-a-time prefill, token for token,
    across bucket boundaries at kv-bits {0, 8, 4}.

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c", _BUCKET_IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "BUCKETED_IDENTITY_OK" in res.stdout


# ---------------------------------------------------------------------------
# Batched prefill == one-at-a-time bucketed prefill, token for token
# ---------------------------------------------------------------------------
# Lens stack a same-bucket first wave (three 9s + a 5 into one admission
# cycle at batch 4), a multi-chunk prompt (21), and a straggler — so the
# trace exercises stacked [n, bucket] forwards, mixed-bucket cycles, AND
# later single-row cycles, all of which must be bitwise-neutral per row.
_BATCHED_PREFILL_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)

def mk():
    rng = np.random.default_rng(7)
    lens = [9, 9, 9, 5, 21, 9]
    return [Request(i, rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    5 + (i % 3)) for i, L in enumerate(lens)]

for kv_bits in (0, 8, 4):
    seq = BatchedServer(cfg, params, batch_size=4, max_len=32,
                        kv_bits=kv_bits, page_size=8, prefill="bucketed",
                        prefill_bucket=8, prefill_batch=1)
    out_seq = seq.run(mk())
    bat = BatchedServer(cfg, params, batch_size=4, max_len=32,
                        kv_bits=kv_bits, page_size=8, prefill="bucketed",
                        prefill_bucket=8, prefill_batch=4)
    out_bat = bat.run(mk())
    for a, b in zip(out_seq, out_bat):
        assert a.out == b.out, (kv_bits, a.rid, a.out, b.out)
    assert all(r.done for r in out_bat)
    # the whole point: same-bucket rows share forwards
    assert bat.prefill_forwards < seq.prefill_forwards, (
        bat.prefill_forwards, seq.prefill_forwards)
    assert bat.allocator.num_free == bat.allocator.num_usable
    print(f"kv_bits={kv_bits} identical "
          f"({seq.prefill_forwards} -> {bat.prefill_forwards} prefill fwd)")
print("BATCHED_PREFILL_IDENTITY_OK")
"""


def test_batched_prefill_matches_sequential():
    """Multi-request batched prefill (same-bucket prompt rows stacked into
    one [n, bucket] forward) == one-at-a-time bucketed prefill, token for
    token, at kv-bits {0, 8, 4} — while running strictly fewer prefill
    forwards.

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c",
                          _BATCHED_PREFILL_IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "BATCHED_PREFILL_IDENTITY_OK" in res.stdout


def test_batched_admission_rolls_back_on_out_of_pages(smoke_model):
    """An OutOfPagesError raised mid-batched-admission (normally
    unreachable: the preflight reserves worst-case demand) must roll back
    EVERY partially admitted row of the batch — pages released, page-table
    rows re-parked on the scratch page, reservations zeroed, slots vacated
    — before the error surfaces, so accounting stays leak-free."""
    from repro.core.paged_kv import SCRATCH_PAGE
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=32, kv_bits=8,
                        page_size=8, prefill="bucketed", prefill_batch=2)
    real_alloc = srv.allocator.alloc
    calls = {"n": 0}

    def flaky_alloc():
        calls["n"] += 1
        if calls["n"] > 1:   # second row of the batch fails
            raise OutOfPagesError(needed=1, free=0,
                                  total=srv.allocator.num_usable)
        return real_alloc()

    srv.allocator.alloc = flaky_alloc
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                    4) for i in range(2)]
    with pytest.raises(OutOfPagesError):
        srv.run(reqs)
    srv.allocator.alloc = real_alloc
    # every row of the failed batch rolled back: no slot claimed, no page
    # leaked, no reservation outstanding
    assert all(s is None for s in srv.slots)
    assert all(r == 0 for r in srv.slot_reserved)
    assert all(not p for p in srv.slot_pages)
    assert (srv.page_table == SCRATCH_PAGE).all()
    assert srv.allocator.num_free == srv.allocator.num_usable
    assert all(isinstance(r.error, OutOfPagesError) for r in reqs)


# ---------------------------------------------------------------------------
# Fused ragged step == separate prefill/decode programs, token for token
# ---------------------------------------------------------------------------
# The trace mixes same-bucket admission waves, a multi-chunk prompt (21),
# and staggered retirements, so fused cycles cover every ragged shape:
# pure-decode (S=1), mixed prefill+decode rows, and prefill-only rounds.
# With prefix on, every prompt shares an 11-token system prefix, so fused
# admission ALSO exercises the prefix-aware wave dedupe.
_FUSED_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)
sys_prompt = np.random.default_rng(11).integers(
    0, cfg.vocab_size, 11).astype(np.int32)

def mk(shared):
    r = np.random.default_rng(13)
    reqs = []
    for i, L in enumerate([9, 9, 5, 21, 9]):
        p = r.integers(0, cfg.vocab_size, L).astype(np.int32)
        if shared:
            p = np.concatenate([sys_prompt, p])
        reqs.append(Request(i, p, 5 + (i % 3)))
    return reqs

for kv_bits in (0, 8, 4):
    for prefix in ("off", "on"):
        kw = dict(batch_size=4, max_len=48, kv_bits=kv_bits, page_size=8,
                  prefill="bucketed", prefill_bucket=8, prefix_cache=prefix)
        sep = BatchedServer(cfg, params, fused="off", **kw)
        out_sep = sep.run(mk(prefix == "on"))
        fus = BatchedServer(cfg, params, fused="on", **kw)
        out_fus = fus.run(mk(prefix == "on"))
        for a, b in zip(out_sep, out_fus):
            assert a.out == b.out, (kv_bits, prefix, a.rid, a.out, b.out)
        assert all(r.done for r in out_fus)
        # the fused contract: ONE jitted program per scheduler cycle
        assert fus.program_launches == fus.cycles, (
            fus.program_launches, fus.cycles)
        if fus.prefix_cache is not None:     # cached pages are retained...
            assert fus.prefix_cache.clear() == 0   # ...but not leaked
        assert fus.allocator.num_free == fus.allocator.num_usable
        print(f"kv_bits={kv_bits} prefix={prefix} identical "
              f"({fus.program_launches} programs / {fus.cycles} cycles)")
print("FUSED_IDENTITY_OK")
"""


def test_fused_matches_separate_programs():
    """The fused ragged step (one [rows, S] variable-length forward per
    scheduler cycle) == the separate prefill-chunk + decode-span program
    path, token for token, at kv-bits {0, 8, 4} x prefix-cache {off, on} —
    with exactly one program launch per cycle.

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c", _FUSED_IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "FUSED_IDENTITY_OK" in res.stdout


def test_fused_steady_state_one_program_per_cycle(smoke_model):
    """Compile-count discipline: a fused trace launches exactly one program
    per scheduler cycle, and the fused step retraces only per S bucket —
    one steady-state decode shape (S=1) plus one per prefill bucket —
    never per cycle."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=32, kv_bits=8,
                        page_size=8, prefill="bucketed", prefill_bucket=8,
                        fused="on")
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                    6) for i in range(3)]
    out = srv.run(reqs)
    assert all(r.done for r in out)
    assert srv.decode_steps > 0
    assert srv.program_launches == srv.cycles
    # two traced shapes total: the bucket-8 admission rounds and S=1 decode
    assert srv._fused._cache_size() <= 2, srv._fused._cache_size()
    assert srv.allocator.num_free == srv.allocator.num_usable


def test_fused_requires_bucketed_prefill(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="fused"):
        BatchedServer(cfg, params, batch_size=2, max_len=32, kv_bits=8,
                      page_size=8, prefill="stepwise", fused="on")


# ---------------------------------------------------------------------------
# Prefix sharing on == off, token for token (incl. per-layer profile)
# ---------------------------------------------------------------------------
# The trace makes every sharing mechanism fire: a common system prompt whose
# length (11) is NOT page-aligned at ps=8 forces full-page aliasing AND a
# copy-on-write inside page 1, a repeated identical prompt gives a full-chain
# hit (zero prefill forwards), and distinct suffixes exercise divergence.
_PREFIX_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.core.fixedpoint import FixedPointFormat
from repro.core.policy import LayerPolicy, PrecisionPolicy
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(11)
sys_prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)

def mk():
    r = np.random.default_rng(13)
    reqs = [Request(i, np.concatenate(
                [sys_prompt, r.integers(0, cfg.vocab_size, 2 + i)
                 .astype(np.int32)]), 4 + i % 3) for i in range(4)]
    reqs.append(Request(4, reqs[0].prompt.copy(), 6))   # full-chain hit
    return reqs

profile = PrecisionPolicy(
    tuple(f"layer_{i:03d}" for i in range(cfg.num_layers)),
    tuple(LayerPolicy(None, FixedPointFormat(2, 6 if i % 2 == 0 else 2))
          for i in range(cfg.num_layers)))

for tag, kw in [("kv0", dict(kv_bits=0)), ("kv8", dict(kv_bits=8)),
                ("kv4", dict(kv_bits=4)),
                ("profile", dict(kv_profile=profile))]:
    for prefill in ("bucketed", "stepwise"):
        # prefill_batch=1: compare sharing on/off at EQUAL prefill
        # discipline (auto would batch only the off side, muddying the
        # forward-count assertion; batched-vs-sequential identity has its
        # own test)
        base = dict(batch_size=2, max_len=32, page_size=8, prefill=prefill,
                    prefill_bucket=8, prefill_batch=1, **kw)
        off = BatchedServer(cfg, params, prefix_cache="off", **base)
        out_off = off.run(mk())
        on = BatchedServer(cfg, params, prefix_cache="on", **base)
        out_on = on.run(mk())
        for a, b in zip(out_off, out_on):
            assert a.out == b.out, (tag, prefill, a.rid, a.out, b.out)
        assert all(r.done for r in out_on)
        st = on.prefix_cache.stats()
        assert st["hits"] >= 4 and st["cow_copies"] >= 1, st
        assert on.prefill_forwards < off.prefill_forwards, (
            tag, prefill, on.prefill_forwards, off.prefill_forwards)
        assert on.release_prefix_cache() == 0          # no refcount leak
        assert on.allocator.num_free == on.allocator.num_usable
        print(f"{tag}/{prefill} identical "
              f"({off.prefill_forwards} -> {on.prefill_forwards} fwd, "
              f"{st['hit_tokens']} tokens reused, {st['cow_copies']} CoW)")
print("PREFIX_IDENTITY_OK")
"""


def test_prefix_sharing_matches_unshared():
    """--prefix-cache on produces token-identical output to off, across
    kv-bits {0, 8, 4} and a mixed per-layer profile, in both prefill modes,
    while saving prefill forwards and leaking no pages.

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c", _PREFIX_IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "PREFIX_IDENTITY_OK" in res.stdout


# ---------------------------------------------------------------------------
# Per-layer profile: grouped run-scan == fully unrolled reference
# ---------------------------------------------------------------------------
# Two profile shapes: contiguous (int8,int8,int4,int4 -> 2 scanned runs —
# the realistic core.search output) and pathologically alternating
# (int8,int4,int8,int4 -> all length-1 runs, i.e. full unroll through the
# grouped path). Both must match the _segment_unrolled reference token for
# token; fp layers ride along via a None-format layer.
_PROFILE_SCAN_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.core.fixedpoint import FixedPointFormat
from repro.core.policy import LayerPolicy, PrecisionPolicy
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)

def mk():
    r = np.random.default_rng(5)
    return [Request(i, r.integers(0, cfg.vocab_size, 7 + i)
                    .astype(np.int32), 5) for i in range(3)]

def prof(fmt_fn):
    return PrecisionPolicy(
        tuple(f"layer_{i:03d}" for i in range(cfg.num_layers)),
        tuple(LayerPolicy(None, fmt_fn(i)) for i in range(cfg.num_layers)))

L = cfg.num_layers
profiles = {
    "contig": prof(lambda i: FixedPointFormat(2, 6 if i < L // 2 else 2)),
    "alt": prof(lambda i: FixedPointFormat(2, 6 if i % 2 == 0 else 2)),
    "fpmix": PrecisionPolicy(
        tuple(f"layer_{i:03d}" for i in range(L)),
        tuple(LayerPolicy(None, None if i == 0 else FixedPointFormat(2, 6))
              for i in range(L))),
}
for name, p in profiles.items():
    outs = {}
    for scan in ("group", "unroll"):
        srv = BatchedServer(cfg, params, batch_size=2, max_len=32,
                            page_size=8, kv_profile=p, kv_profile_scan=scan)
        outs[scan] = [r.out for r in srv.run(mk())]
        assert srv.allocator.num_free == srv.allocator.num_usable
    assert outs["group"] == outs["unroll"], (name, outs)
    print(f"{name}: grouped-scan == unrolled")
print("PROFILE_SCAN_IDENTITY_OK")
"""


def test_profile_grouped_scan_matches_unrolled():
    """The grouped run-scan forward for per-layer KV containers (contiguous
    same-container runs ride lax.scan) is token-identical to the fully
    unrolled _segment_unrolled reference, for contiguous, alternating, and
    fp-mixed profiles.

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c",
                          _PROFILE_SCAN_IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "PROFILE_SCAN_IDENTITY_OK" in res.stdout


def test_per_layer_profile_shrinks_at_rest_bytes(smoke_model):
    """A profile with >= 2 distinct layer bit-widths stores its paged pools
    below uniform int8 (and above uniform int4) at rest."""
    from repro.core.fixedpoint import FixedPointFormat
    from repro.core.paged_kv import pool_bytes
    from repro.core.policy import LayerPolicy, PrecisionPolicy
    cfg, params = smoke_model

    def kv_bytes(srv):
        total = 0
        for seg in srv.caches:
            for entry in seg:
                for d in (entry if isinstance(entry, list) else [entry]):
                    if isinstance(d, dict) and "k_pages" in d:
                        total += pool_bytes(d)
        return total

    profile = PrecisionPolicy(
        tuple(f"layer_{i:03d}" for i in range(cfg.num_layers)),
        tuple(LayerPolicy(None, FixedPointFormat(2, 6 if i % 2 == 0 else 2))
              for i in range(cfg.num_layers)))
    mk = lambda kw: BatchedServer(cfg, params, batch_size=2, max_len=32,
                                  page_size=8, **kw)
    prof = kv_bytes(mk(dict(kv_profile=profile)))
    u8 = kv_bytes(mk(dict(kv_bits=8)))
    u4 = kv_bytes(mk(dict(kv_bits=4)))
    assert u4 < prof < u8, (u4, prof, u8)


def test_kv_profile_validation(smoke_model):
    cfg, params = smoke_model
    from repro.core.fixedpoint import FixedPointFormat
    from repro.core.policy import PrecisionPolicy
    profile = PrecisionPolicy.uniform(
        [f"layer_{i:03d}" for i in range(cfg.num_layers)], None,
        FixedPointFormat(2, 6))
    with pytest.raises(ValueError, match="paged"):
        BatchedServer(cfg, params, batch_size=2, max_len=32,
                      kv_profile=profile)
    with pytest.raises(ValueError, match="supersedes"):
        BatchedServer(cfg, params, batch_size=2, max_len=32, page_size=8,
                      kv_bits=8, kv_profile=profile)


# ---------------------------------------------------------------------------
# Pallas decode == gather decode on fragmented page tables (oracle-style)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_bits", [0, 8, 4])
def test_pallas_attn_impl_matches_gather_fragmented(kv_bits):
    """gqa_apply with attn_impl="pallas" (interpret mode) matches the gather
    path on a deliberately fragmented page table with partial last pages."""
    cfg = get_smoke_config("qwen2-72b")
    rng = np.random.default_rng(3)
    B, ps, NP = 3, 8, 4
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    quant = (None if kv_bits == 0 else
             KVQuantSpec(2, kv_bits - 2, "int8" if kv_bits == 8 else "int4"))
    cache = init_paged_kv_cache(1 + B * NP, ps, KV, hd,
                                cfg.compute_jnp_dtype, quant)
    # fragmented: pages interleaved across sequences, shuffled ids
    ids = np.arange(1, 1 + B * NP)
    rng.shuffle(ids)
    pt = jnp.asarray(ids.reshape(B, NP).astype(np.int32))
    lens = np.array([5, ps * 2, ps * 3 - 1], np.int32)  # partial last pages
    for t in range(int(lens.max())):
        k = jnp.asarray(rng.normal(size=(B, 1, KV, hd)) * 0.5, jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, 1, KV, hd)) * 0.5, jnp.float32)
        # rows past their length write their stale position (t clamped):
        # the serving loop does the same via per-row pos
        pos = jnp.asarray(np.minimum(t, lens - 1), jnp.int32)
        cache = paged_cache_update(cache, k, v, pt, pos, quant)

    params = init_gqa(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)) * 0.3,
                    cfg.compute_jnp_dtype)
    cache_pos = jnp.asarray(lens - 1, jnp.int32)  # writing the last token
    positions = cache_pos[:, None]
    outs = {}
    for impl in ("gather", "pallas"):
        y, _ = gqa_apply(params, x, positions, cfg=cfg, cache=cache,
                         cache_pos=cache_pos, kv_quant=quant,
                         page_table=pt, attn_impl=impl)
        outs[impl] = np.asarray(y, np.float32)
    np.testing.assert_allclose(outs["pallas"], outs["gather"],
                               rtol=2e-5, atol=2e-5)


def test_pallas_attn_impl_serving_smoke(smoke_model):
    """End-to-end: a pallas-routed server completes a mixed trace and agrees
    with the gather server on ~all tokens (argmax can flip on float-tolerance
    logit ties, so require agreement, not identity)."""
    cfg, params = smoke_model
    rng = np.random.default_rng(5)
    mk = lambda: [Request(i, rng.integers(0, cfg.vocab_size, 6)
                          .astype(np.int32), 6) for i in range(4)]
    a = BatchedServer(cfg, params, batch_size=2, max_len=32, kv_bits=8,
                      page_size=8, attn_impl="gather")
    rng = np.random.default_rng(5)
    out_a = a.run(mk())
    b = BatchedServer(cfg, params, batch_size=2, max_len=32, kv_bits=8,
                      page_size=8, attn_impl="pallas")
    rng = np.random.default_rng(5)
    out_b = b.run(mk())
    agree = np.mean([np.mean(np.asarray(x.out) == np.asarray(y.out))
                     for x, y in zip(out_a, out_b)])
    assert all(r.done for r in out_b)
    assert agree >= 0.9, agree


@pytest.mark.parametrize("kv_bits", [0, 8, 4])
def test_pallas_chunk_prefill_matches_gather_fragmented(kv_bits):
    """gqa_apply with a PREFILL CHUNK (S > 1) and attn_impl="pallas" routes
    the variable-length chunk kernel and matches the gather path on a
    fragmented page table with per-row start positions that straddle page
    boundaries (partial last pages included) — the S>=1 generalization of
    the decode oracle test above."""
    cfg = get_smoke_config("qwen2-72b")
    rng = np.random.default_rng(17)
    B, ps, NP, S = 3, 8, 4, 6
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    quant = (None if kv_bits == 0 else
             KVQuantSpec(2, kv_bits - 2, "int8" if kv_bits == 8 else "int4"))
    cache = init_paged_kv_cache(1 + B * NP, ps, KV, hd,
                                cfg.compute_jnp_dtype, quant)
    ids = np.arange(1, 1 + B * NP)
    rng.shuffle(ids)
    pt = jnp.asarray(ids.reshape(B, NP).astype(np.int32))
    lens = np.array([0, 5, ps * 2 + 3], np.int32)  # history before the chunk
    for t in range(int(lens.max())):
        k = jnp.asarray(rng.normal(size=(B, 1, KV, hd)) * 0.5, jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, 1, KV, hd)) * 0.5, jnp.float32)
        pos = jnp.asarray(np.minimum(t, np.maximum(lens - 1, 0)), jnp.int32)
        cache = paged_cache_update(cache, k, v, pt, pos, quant)

    params = init_gqa(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.3,
                    cfg.compute_jnp_dtype)
    cache_pos = jnp.asarray(lens, jnp.int32)
    positions = cache_pos[:, None] + jnp.arange(S)[None, :]
    outs = {}
    for impl in ("gather", "pallas"):
        y, _ = gqa_apply(params, x, positions, cfg=cfg, cache=cache,
                         cache_pos=cache_pos, kv_quant=quant,
                         page_table=pt, attn_impl=impl)
        outs[impl] = np.asarray(y, np.float32)
    np.testing.assert_allclose(outs["pallas"], outs["gather"],
                               rtol=2e-5, atol=2e-5)


def test_pallas_chunk_prefill_padded_tail_matches_gather():
    """A padded bucketed-prefill chunk (kv_valid_len < S): the kernel and
    gather paths agree on every REAL query row; padded rows are garbage
    nobody reads (their pool writes go to the scratch page)."""
    cfg = get_smoke_config("qwen2-72b")
    rng = np.random.default_rng(23)
    B, ps, NP, S, valid = 2, 8, 3, 8, 5
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    quant = KVQuantSpec(2, 6, "int8")
    cache = init_paged_kv_cache(1 + B * NP, ps, KV, hd,
                                cfg.compute_jnp_dtype, quant)
    ids = np.arange(1, 1 + B * NP)
    rng.shuffle(ids)
    pt = jnp.asarray(ids.reshape(B, NP).astype(np.int32))
    params = init_gqa(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.3,
                    cfg.compute_jnp_dtype)
    cache_pos = jnp.asarray([0, 3], jnp.int32)
    positions = cache_pos[:, None] + jnp.arange(S)[None, :]
    vl = jnp.asarray([valid, valid], jnp.int32)
    outs = {}
    for impl in ("gather", "pallas"):
        y, _ = gqa_apply(params, x, positions, cfg=cfg, cache=cache,
                         cache_pos=cache_pos, kv_quant=quant,
                         page_table=pt, attn_impl=impl, kv_valid_len=vl)
        outs[impl] = np.asarray(y, np.float32)
    np.testing.assert_allclose(outs["pallas"][:, :valid],
                               outs["gather"][:, :valid],
                               rtol=2e-5, atol=2e-5)


def test_pallas_serving_with_mixed_profile(smoke_model):
    """End-to-end --attn-impl pallas over a MIXED per-layer precision
    profile (int8/int4/fp containers via _segment_scan_grouped): bucketed
    chunk prefill and decode both route the kernel per-layer-bits, and the
    server agrees with the gather reference on ~all tokens."""
    from repro.core.fixedpoint import FixedPointFormat
    from repro.core.policy import LayerPolicy, PrecisionPolicy
    cfg, params = smoke_model
    L = cfg.num_layers
    profile = PrecisionPolicy(
        tuple(f"layer_{i:03d}" for i in range(L)),
        tuple(LayerPolicy(None, None if i == 0
                          else FixedPointFormat(2, 6 if i % 2 else 2))
              for i in range(L)))
    mk = lambda: [Request(i, np.random.default_rng(i).integers(
        0, cfg.vocab_size, 7 + i).astype(np.int32), 5) for i in range(3)]
    outs = {}
    for impl in ("gather", "pallas"):
        srv = BatchedServer(cfg, params, batch_size=2, max_len=32,
                            page_size=8, kv_profile=profile, attn_impl=impl,
                            prefill="bucketed", prefill_bucket=8)
        outs[impl] = srv.run(mk())
        assert all(r.done for r in outs[impl])
    agree = np.mean([np.mean(np.asarray(a.out) == np.asarray(b.out))
                     for a, b in zip(outs["gather"], outs["pallas"])])
    assert agree >= 0.9, agree


def test_pallas_requires_paged(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="page-size"):
        BatchedServer(cfg, params, batch_size=2, max_len=32,
                      attn_impl="pallas")


# ---------------------------------------------------------------------------
# Admission preflight: OutOfPagesError semantics
# ---------------------------------------------------------------------------
def test_allocator_preflight_and_exhaustion():
    al = PageAllocator(4)           # 3 usable
    al.check(3)                     # fits
    with pytest.raises(OutOfPagesError) as ei:
        al.check(4, rid=7)
    assert ei.value.needed == 4 and ei.value.free == 3
    assert ei.value.total == 3 and ei.value.rid == 7
    for _ in range(3):
        al.alloc()
    with pytest.raises(OutOfPagesError):
        al.alloc()


def test_admission_rejects_impossible_request(smoke_model):
    """A request whose prompt + max_new can NEVER be backed by the pool is
    rejected up front with counts, not an opaque failure mid-prefill."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=64,
                        kv_bits=8, page_size=8, num_pages=3)  # 2 usable
    rng = np.random.default_rng(0)
    req = Request(0, rng.integers(0, cfg.vocab_size, 20).astype(np.int32),
                  20)               # needs ceil(39/8)=5 pages > 2 usable
    with pytest.raises(OutOfPagesError) as ei:
        srv.run([req])
    assert ei.value.needed == 5 and ei.value.total == 2
    assert "request 0" in str(ei.value)
    assert srv.allocator.num_free == 2          # nothing leaked


def test_preflight_counts_forced_token_at_max_new_zero(smoke_model):
    """The decode loop always generates >= 1 token, so a max_new=0 request
    whose prompt exactly fills the pool must be REJECTED at admission (page
    demand includes the forced token), not die allocating mid-decode."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=1, max_len=64,
                        kv_bits=8, page_size=16, num_pages=2)  # 1 usable
    req = Request(0, (np.arange(17) % cfg.vocab_size).astype(np.int32), 0)
    with pytest.raises(OutOfPagesError):
        srv.run([req])
    assert srv.allocator.num_free == 1   # rejected up front, nothing leaked


def test_admission_defers_until_pages_free(smoke_model):
    """A request that merely has to WAIT for live requests to release pages
    is deferred, not rejected: the queue drains as slots complete."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=64,
                        kv_bits=8, page_size=8, num_pages=4)  # 3 usable
    rng = np.random.default_rng(1)
    # each request needs ceil((6-1+8)/8) = 2 pages; two concurrent would
    # need 4 > 3 usable, so the second must wait for the first to finish
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    8) for i in range(3)]
    srv.run(reqs)
    assert all(r.done and len(r.out) == 8 for r in reqs)
    assert srv.allocator.num_free == 3


def test_out_of_pages_reports_requantizable_inventory():
    """With a quant tier attached, admission rejects report how many cold
    cached pages could be requantized in place (``requantizable``) next to
    the evictable/host counts — the operator-facing hint that --kv-adapt
    headroom exists. Without a tier the field stays 0."""
    al = PageAllocator(4)           # 3 usable
    al.requant_inventory = lambda: 2
    with pytest.raises(OutOfPagesError) as ei:
        al.check(9, rid=3)
    assert ei.value.requantizable == 2
    assert "2 requantizable" in str(ei.value)
    al2 = PageAllocator(4)
    with pytest.raises(OutOfPagesError) as ei2:
        al2.check(9)
    assert ei2.value.requantizable == 0


# ---------------------------------------------------------------------------
# Online precision adaptation (--kv-adapt): identity off / under no pressure,
# requantization under pressure, page-scale sharing contract, validation
# ---------------------------------------------------------------------------
_ADAPT_IDENTITY_SCRIPT = r"""
import jax, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.registry import get_smoke_config
from repro.launch.serve import BatchedServer, Request
from repro.models.transformer import init_model

cfg = get_smoke_config("qwen2-72b")
params = init_model(jax.random.PRNGKey(0), cfg)
rng0 = np.random.default_rng(19)
sys_prompt = rng0.integers(0, cfg.vocab_size, 9).astype(np.int32)

def mk():
    r = np.random.default_rng(29)
    reqs = [Request(i, np.concatenate(
                [sys_prompt, r.integers(0, cfg.vocab_size, 2 + i)
                 .astype(np.int32)]), 4 + i % 3) for i in range(4)]
    reqs.append(Request(4, reqs[0].prompt.copy(), 6))   # full-chain hit
    return reqs

# --kv-adapt off must be a pure no-op: bitwise-identical to a server built
# without the flag at all, at every pool container
for kv_bits in (0, 8, 4):
    base = dict(batch_size=2, max_len=32, kv_bits=kv_bits, page_size=8,
                prefill="bucketed", prefill_bucket=8, prefill_batch=1,
                prefix_cache="on")
    seed = BatchedServer(cfg, params, **base)
    out_seed = seed.run(mk())
    off = BatchedServer(cfg, params, kv_adapt="off", **base)
    out_off = off.run(mk())
    for a, b in zip(out_seed, out_off):
        assert a.out == b.out, (kv_bits, a.rid, a.out, b.out)
    assert off.quant_tier is None
    print(f"kv_bits={kv_bits} adapt-off == seed")

# adapt ON with a roomy pool: the tier attaches but pressure never fires,
# so every token must stay bitwise-identical to adapt-off (requant only
# ever runs under eviction pressure, never on the hot path). kv_bits=4 is
# excluded: an int4 pool is already at the tier floor and the tier refuses
# to attach (asserted in test_kv_adapt_validation).
for kv_bits in (0, 8):
    base = dict(batch_size=2, max_len=32, kv_bits=kv_bits, page_size=8,
                prefill="bucketed", prefill_bucket=8, prefill_batch=1,
                prefix_cache="on")
    off = BatchedServer(cfg, params, kv_adapt="off", **base)
    out_off = off.run(mk())
    on = BatchedServer(cfg, params, kv_adapt="on", **base)
    out_on = on.run(mk())
    for a, b in zip(out_off, out_on):
        assert a.out == b.out, (kv_bits, a.rid, a.out, b.out)
    assert all(r.done for r in out_on)
    st = on.prefix_cache.stats()
    assert st["requants"] == 0 and st["tier_promotions"] == 0, st
    assert on.quant_tier.num_pages == 0 and on.quant_tier.nbytes == 0
    assert on.release_prefix_cache() == 0
    assert on.allocator.num_free == on.allocator.num_usable
    print(f"kv_bits={kv_bits} adapt-on (no pressure) == adapt-off")
print("ADAPT_IDENTITY_OK")
"""


def test_kv_adapt_off_matches_seed_and_on_is_noop_without_pressure():
    """--kv-adapt off is bitwise-identical to a server built without the
    flag (kv-bits {0, 8, 4}); --kv-adapt on with a roomy pool is
    bitwise-identical to off (requantization runs only under eviction
    pressure, never on the hot path) and ends with an empty, leak-free
    quant tier.

    Runs in a subprocess with single-threaded XLA: multi-threaded XLA:CPU
    GEMMs are not bitwise deterministic under thread contention, and exact
    argmax token identity needs bitwise-equal logits."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    res = subprocess.run([sys.executable, "-c", _ADAPT_IDENTITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "ADAPT_IDENTITY_OK" in res.stdout


def test_kv_adapt_requantizes_under_pressure(smoke_model):
    """End-to-end --kv-adapt on under real pool pressure: distinct
    per-tenant prefixes overflow a 9-page pool, so eviction must narrow
    cold cached pages into the quant tier BEFORE any host demotion, every
    request still completes, and pool + host + tier all drain leak-free."""
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=64, kv_bits=8,
                        page_size=4, num_pages=10, prefill="bucketed",
                        prefill_bucket=8, prefill_batch=1,
                        prefix_cache="on", kv_offload="host",
                        kv_adapt="on", adapt_pages=36)
    rng = np.random.default_rng(31)
    reqs = []
    for g in range(4):              # 4 tenants, distinct 8-token prefixes
        sys_p = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        sfx = rng.integers(0, cfg.vocab_size, 3).astype(np.int32)
        reqs.append(Request(g, np.concatenate([sys_p, sfx]), 4,
                            arrive_step=2 * g))
    srv.run(reqs)
    assert all(r.done and r.error is None and len(r.out) == 4 for r in reqs)
    st = srv.prefix_cache.stats()
    assert st["requants"] >= 1, st
    if st["demotions"]:             # requant strictly preceded host demotion
        assert st["requants_at_first_demotion"] >= 1, st
    assert srv.quant_tier.peak_pages >= 1
    # the new inventory surfaces in admission rejects while pages are cold
    verdict, info = srv._admission_plan(
        Request(99, rng.integers(0, cfg.vocab_size, 40).astype(np.int32),
                20))
    assert verdict == "reject"
    assert info["err"].requantizable == srv.prefix_cache.requantizable_pages()
    assert info["err"].requantizable >= 1
    # drain: releasing the cache empties the tier too
    assert srv.release_prefix_cache() == 0
    assert srv.quant_tier.num_pages == 0 and srv.quant_tier.nbytes == 0
    assert srv.host_store.num_pages == 0
    assert srv.allocator.num_free == srv.allocator.num_usable


def test_page_scale_sharing_preserves_sharer_bytes(smoke_model):
    """Page-scale sharing contract (regression): in --kv-scale page mode a
    per-page scale raise REWRITES the page's packed grid in place, so the
    prefix cache must never index the partial tail page its owner keeps
    writing. Only full pages are cached, and a later request that aliases
    a cached page and decodes onward leaves the shared page's packed bytes
    untouched."""
    from repro.core.page_store import extract_page
    cfg, params = smoke_model
    srv = BatchedServer(cfg, params, batch_size=2, max_len=48, kv_bits=8,
                        page_size=8, kv_scale="page", prefix_cache="on",
                        prefill="bucketed", prefill_bucket=8,
                        prefill_batch=1)
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    srv.run([Request(0, base, 4)])
    # prompt prefills 10 tokens = 1 full page + 2-token tail; page mode
    # caches ONLY the full page (static mode would index the tail too)
    hit = srv.prefix_cache.lookup(base)
    assert len(hit.nodes) == 1 and hit.matched == 8
    assert hit.cow_node is None, "partial tail leaked into the page-scale " \
                                 "cache"
    shared = int(hit.nodes[0].page)
    before = extract_page(srv.caches, shared)
    # a sharer aliases the page and decodes well past it: its scale raises
    # must land in its OWN pages, never the aliased one
    ext = Request(1, np.concatenate(
        [base, rng.integers(0, cfg.vocab_size, 6).astype(np.int32)]), 8)
    srv.run([ext])
    assert ext.done and ext.error is None
    assert srv.prefix_cache.stats()["hits"] >= 1
    after = extract_page(srv.caches, shared)
    for ra, rb in zip(before.arrays, after.arrays):
        for key in ("k", "v", "ks", "vs"):
            assert np.array_equal(ra[key], rb[key]), \
                f"shared page {key!r} bytes changed under an aliased reader"
    assert srv.release_prefix_cache() == 0
    assert srv.allocator.num_free == srv.allocator.num_usable


def test_kv_adapt_validation(smoke_model):
    cfg, params = smoke_model
    base = dict(batch_size=2, max_len=32)
    with pytest.raises(ValueError, match="kv_adapt"):
        BatchedServer(cfg, params, kv_adapt="maybe", **base)
    with pytest.raises(ValueError, match="prefix-cache"):
        BatchedServer(cfg, params, kv_bits=8, page_size=8, kv_adapt="on",
                      **base)
    with pytest.raises(ValueError, match="page-size"):
        BatchedServer(cfg, params, kv_adapt="on", **base)
    with pytest.raises(ValueError, match="floor_bits"):
        BatchedServer(cfg, params, kv_bits=8, page_size=8,
                      prefix_cache="on", kv_adapt="on", adapt_floor_bits=6,
                      **base)
    # a uniform-int4 pool is already at the tier floor: nothing to narrow
    with pytest.raises(ValueError, match="nothing to narrow"):
        BatchedServer(cfg, params, kv_bits=4, page_size=8,
                      prefix_cache="on", kv_adapt="on", **base)
