"""Sharded, async, mesh-agnostic checkpointing (no orbax in this container).

Layout per step::

    <dir>/step_000000420/
        arrays.npz          # flattened key -> full (global-shape) ndarray
        manifest.json       # step, keys, shapes, dtypes, extra state
        COMMIT              # written LAST -> atomic completeness marker

Design points (DESIGN.md §4 fault tolerance):

* **Mesh-agnostic**: arrays are saved at GLOBAL shape (device_get assembles
  the addressable shards), so a checkpoint written on a (16,16) mesh restores
  onto (2,16,16), (8,), or a single CPU — elasticity comes free. On restore,
  each array is device_put against the *target* sharding.
* **Atomic**: the COMMIT marker is written after arrays+manifest fsync; a
  crash mid-save leaves an incomplete dir that restore skips. ``keep`` old
  steps are retained for rollback.
* **Async**: save snapshots to host memory synchronously (cheap), then a
  daemon thread writes to disk — the train loop does not block on I/O.
  ``wait()`` joins outstanding saves (call before exit / before restore).
* **Quantized checkpoints** (paper tie-in): pass ``policy`` to store >=2-D
  float leaves on their per-layer Q(I,F) integer grid in the checkpoint's
  int8/int16 containers — bounded-memory persistence; restore dequantizes.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "::"


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_key(path)] = leaf
    return flat


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(directory, name, "COMMIT")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def _write(directory: str, step: int, arrays: Dict[str, np.ndarray],
           manifest: dict, keep: int):
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # GC old steps
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


def save_checkpoint(directory: str, step: int, state, *,
                    extra: Optional[dict] = None, keep: int = 3,
                    async_: bool = False, policy=None):
    """state: arbitrary pytree of arrays. Returns a join()-able thread when
    ``async_`` else None."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(state)
    arrays, qmeta = {}, {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        if policy is not None and arr.ndim >= 2 and \
                np.issubdtype(arr.dtype, np.floating):
            fmt = _fmt_for_key(policy, k)
            if fmt is not None:
                scale = float(2 ** fmt.frac_bits)
                q = np.clip(np.round(arr.astype(np.float32) * scale),
                            fmt.qmin, fmt.qmax)
                arr = q.astype(np.int8 if fmt.total_bits <= 8 else np.int16)
                qmeta[k] = {"int_bits": fmt.int_bits,
                            "frac_bits": fmt.frac_bits,
                            "orig_dtype": str(np.dtype(flat[k].dtype))}
        arrays[k] = arr
    manifest = {"step": step, "extra": extra or {}, "quant": qmeta,
                "keys": sorted(arrays.keys())}
    if async_:
        t = threading.Thread(target=_write,
                             args=(directory, step, arrays, manifest, keep),
                             daemon=True)
        t.start()
        return t
    _write(directory, step, arrays, manifest, keep)
    return None


def _fmt_for_key(policy, key: str):
    """Per-layer weight format lookup by layer name appearing in the key."""
    for name, lp in zip(policy.names, policy.layers):
        if name in key and lp.weight is not None:
            return lp.weight
    return None


def restore_checkpoint(directory: str, template, *, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). Returns (step, state, extra).

    ``shardings``: optional matching pytree of NamedSharding — each restored
    array is device_put against it (THIS is the elastic re-mesh path)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(d, "arrays.npz"))
    flat_t, tdef = jax.tree_util.tree_flatten_with_path(template)
    flat_s = (jax.tree_util.tree_leaves(shardings)
              if shardings is not None else [None] * len(flat_t))
    leaves = []
    for (path, leaf), shard in zip(flat_t, flat_s):
        key = _path_key(path)
        arr = npz[key]
        if key in manifest["quant"]:
            meta = manifest["quant"][key]
            arr = (arr.astype(np.float32) / 2 ** meta["frac_bits"]) \
                .astype(meta["orig_dtype"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != "
                             f"template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.device_put(arr))
    return step, jax.tree_util.tree_unflatten(tdef, leaves), manifest["extra"]


class CheckpointManager:
    """Step-gated async save + restore-latest, used by launch.train."""

    def __init__(self, directory: str, *, interval: int = 100, keep: int = 3,
                 policy=None):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        self.policy = policy
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, state, extra=None, force=False):
        if not force and (self.interval <= 0 or step % self.interval != 0):
            return False
        self.wait()
        self._pending = save_checkpoint(
            self.directory, step, state, extra=extra, keep=self.keep,
            async_=True, policy=self.policy)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, template, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, template,
                                  shardings=shardings)
