"""Optimized-HLO analysis: collective bytes for the roofline's third term.

``compiled.as_text()`` is the SPMD-partitioned module, so instruction shapes
are PER-DEVICE. For every collective op we record operand/output bytes and
the replica-group size g, then convert to ring-model WIRE bytes per device:

    all-gather          (g-1)/g x output bytes      (received)
    all-reduce          2 (g-1)/g x operand bytes   (RS + AG rings)
    reduce-scatter      (g-1)/g x operand bytes
    all-to-all          (g-1)/g x operand bytes
    collective-permute  1.0     x operand bytes     (one hop)

cost_analysis() gives HLO_FLOPs / HLO_bytes for the compute and memory terms;
this module is the only place HLO text is parsed.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8,
    "c128": 16, "u1": 1, "s1": 1, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+"
    r"([a-z][\w\-]*)\((.*)$")
_GROUPS_NEW_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_RING_FACTOR = {
    "all-gather": lambda g: (g - 1) / g,
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    opcode: str
    name: str
    output_bytes: int
    operand_bytes: int
    group_size: int
    wire_bytes: float  # ring-model per-device wire bytes


def _base_opcode(op: str) -> Optional[str]:
    op = op.removesuffix("-start")
    return op if op in COLLECTIVE_OPS else None


def parse_collectives(hlo_text: str, *, default_group: int = 1
                      ) -> List[CollectiveOp]:
    # pass 1: name -> output bytes
    shapes: Dict[str, int] = {}
    defs = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        shapes[name] = _type_bytes(type_str)
        defs.append((name, type_str, opcode, rest))

    out: List[CollectiveOp] = []
    for name, type_str, opcode, rest in defs:
        base = _base_opcode(opcode)
        if base is None:
            continue
        out_bytes = shapes[name]
        # operands: %name references inside the parens
        paren = rest.split(")")[0]
        operand_names = re.findall(r"%([\w.\-]+)", paren)
        op_bytes = sum(shapes.get(n, 0) for n in operand_names)
        if op_bytes == 0:  # typed-operand style or unresolvable: use text
            op_bytes = _type_bytes(paren) or out_bytes
        g = default_group
        m = _GROUPS_NEW_RE.search(rest)
        if m:
            g = int(m.group(2))  # [num_groups, group_size]
        else:
            m = _GROUPS_OLD_RE.search(rest)
            if m:
                g = max(1, m.group(1).count(",") + 1)
        wire = _RING_FACTOR[base](max(g, 1)) * (
            out_bytes if base == "all-gather" else op_bytes)
        out.append(CollectiveOp(base, name, out_bytes, op_bytes, g, wire))
    return out


def collective_summary(hlo_text: str) -> dict:
    ops = parse_collectives(hlo_text)
    by_type: Dict[str, dict] = {}
    for op in ops:
        d = by_type.setdefault(op.opcode, {"count": 0, "operand_bytes": 0,
                                           "output_bytes": 0,
                                           "wire_bytes": 0.0})
        d["count"] += 1
        d["operand_bytes"] += op.operand_bytes
        d["output_bytes"] += op.output_bytes
        d["wire_bytes"] += op.wire_bytes
    return {
        "per_type": by_type,
        "total_operand_bytes": sum(o.operand_bytes for o in ops),
        "total_wire_bytes_per_device": sum(o.wire_bytes for o in ops),
        "count": len(ops),
    }


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "peak_memory_in_bytes", "generated_code_size_in_bytes")
    return {k: int(getattr(ma, k, 0)) for k in keys}


def cost_summary(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if ca is None:
        return {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}
