"""Step functions shared by dryrun / train / serve.

Everything here is mesh-agnostic pure functions; launchers wrap them in
jax.jit with shardings from parallel.sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.transformer import (forward, forward_hidden, init_cache,
                                  init_model, train_loss)
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.compress import CompressionConfig, compress_gradients, \
    error_feedback_init


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    adamw: AdamWConfig = AdamWConfig()
    lb_coeff: float = 0.01
    grad_compress: Optional[CompressionConfig] = None


def init_train_state(key, cfg, hp: TrainHParams):
    params = init_model(key, cfg)
    state = {"params": params, "opt": adamw_init(params, hp.adamw)}
    if hp.grad_compress is not None:
        state["ef_residual"] = error_feedback_init(params)
    return state


def make_train_step(cfg, hp: TrainHParams, *, quant=None):
    """Returns fn(state, batch) -> (state, metrics)."""

    def step(state, batch):
        def loss_fn(params):
            return train_loss(params, batch, cfg, quant=quant,
                              lb_coeff=hp.lb_coeff)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        if hp.grad_compress is not None:
            grads, new_res = compress_gradients(
                grads, state["ef_residual"], hp.grad_compress)
        params, opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], hp.lr, hp.adamw)
        new_state = {"params": params, "opt": opt}
        if hp.grad_compress is not None:
            new_state["ef_residual"] = new_res
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_state, metrics

    return step


def make_prefill_step(cfg, *, max_len: int, quant=None):
    """fn(params, batch) -> (last_logits, caches). Encoder archs return
    (logits, None) — a plain forward.

    This is the dryrun/whole-prompt prefill against a fresh DENSE cache; the
    serving path prefills incrementally into a shared paged pool via
    ``make_chunk_prefill_step`` below."""

    def step(params, batch):
        if cfg.family == "encoder":
            _, logits, _, _ = forward(params, batch, cfg, quant=quant)
            return logits, None
        B = (batch["tokens"].shape[0] if "tokens" in batch
             else batch["embeds"].shape[0])
        caches = init_cache(cfg, B, max_len, quant)
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=0)
        return logits[:, -1], caches

    return step


def make_chunk_prefill_step(cfg, *, quant=None, attn_impl: str = "gather"):
    """fn(params, tokens (Bp, S), start_pos (Bp,), valid_len (Bp,), caches,
    page_table (Bp, NP)) -> caches.

    One **bucketed prefill** program: runs a whole prompt chunk through the
    backbone in a single forward, quantizing K/V per layer and scattering the
    chunk into the paged pool via the page table. ``Bp`` is the number of
    stacked same-bucket prompt rows (multi-request batched prefill — each
    row carries its own page table, start position, and valid length) and
    ``S`` the bucket size (callers pad prompts up to a power-of-two bucket
    and jit retraces per bucket, so a max bucket of 2^k costs at most k+1
    compilations per row count); only the first ``valid_len`` tokens of a
    row are real — padded tails are masked out of the pool write
    (scratch-page redirect) and their hidden states are garbage that nobody
    reads. Skips the LM head entirely (prefill logits are never sampled; the
    decode step consumes the last prompt token), which is why this wraps
    ``forward_hidden`` and not ``forward``.

    ``attn_impl`` routes the chunk's attention reads exactly like decode
    ("gather" = jnp bitwise reference, "pallas" = the variable-length paged
    chunk kernel) — prefill and decode share ONE attention entry point
    (``models.attention.route_paged_attention``).

    Prefix sharing composes here for free: a prefix-cache hit aliases the
    shared pages into the slot's page table and the server calls this step
    with ``start_pos`` at the first NON-shared token — fully cached pages
    never see a forward (O(suffix/bucket) admission), while the chunk's
    attention still reads the shared history through the same page table.
    """
    def step(params, tokens, start_pos, valid_len, caches, page_table):
        batch = {"tokens": tokens}
        _, aux = forward_hidden(params, batch, cfg, quant=quant,
                                caches=caches, cache_pos=start_pos,
                                page_table=page_table, attn_impl=attn_impl,
                                kv_valid_len=valid_len)
        return aux["caches"]

    return step


def make_decode_step(cfg, *, quant=None, greedy: bool = True,
                     attn_impl: str = "gather"):
    """fn(params, tokens (B,), pos, caches, page_table=None) ->
    (next_tokens, logits, caches).

    One new token per sequence against a preallocated cache — the function
    the decode_32k / long_500k cells lower. ``pos`` is a scalar (shared
    clock) or (B,) per-sequence lengths; ``page_table`` (B, NP) drives a
    paged cache (see core.paged_kv); ``attn_impl`` ("gather" | "pallas")
    picks the paged attention backend (models.attention.gqa_apply)."""

    def step(params, tokens, pos, caches, page_table=None):
        batch = {"tokens": tokens[:, None]}
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=pos,
                                       page_table=page_table,
                                       attn_impl=attn_impl)
        logits = logits[:, 0]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return step


def make_embed_decode_step(cfg, *, quant=None):
    """Decode step for frontend-stub archs (inputs are embeds)."""

    def step(params, embeds, pos, caches):
        batch = {"embeds": embeds}
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=pos)
        logits = logits[:, 0]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return step
