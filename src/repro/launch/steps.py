"""Step functions shared by dryrun / train / serve.

Everything here is mesh-agnostic pure functions; launchers wrap them in
jax.jit with shardings from parallel.sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.transformer import (forward, init_cache, init_model, train_loss)
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.compress import CompressionConfig, compress_gradients, \
    error_feedback_init


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    adamw: AdamWConfig = AdamWConfig()
    lb_coeff: float = 0.01
    grad_compress: Optional[CompressionConfig] = None


def init_train_state(key, cfg, hp: TrainHParams):
    params = init_model(key, cfg)
    state = {"params": params, "opt": adamw_init(params, hp.adamw)}
    if hp.grad_compress is not None:
        state["ef_residual"] = error_feedback_init(params)
    return state


def make_train_step(cfg, hp: TrainHParams, *, quant=None):
    """Returns fn(state, batch) -> (state, metrics)."""

    def step(state, batch):
        def loss_fn(params):
            return train_loss(params, batch, cfg, quant=quant,
                              lb_coeff=hp.lb_coeff)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        if hp.grad_compress is not None:
            grads, new_res = compress_gradients(
                grads, state["ef_residual"], hp.grad_compress)
        params, opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], hp.lr, hp.adamw)
        new_state = {"params": params, "opt": opt}
        if hp.grad_compress is not None:
            new_state["ef_residual"] = new_res
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_state, metrics

    return step


def make_prefill_step(cfg, *, max_len: int, quant=None):
    """fn(params, batch) -> (last_logits, caches). Encoder archs return
    (logits, None) — a plain forward."""

    def step(params, batch):
        if cfg.family == "encoder":
            _, logits, _, _ = forward(params, batch, cfg, quant=quant)
            return logits, None
        B = (batch["tokens"].shape[0] if "tokens" in batch
             else batch["embeds"].shape[0])
        caches = init_cache(cfg, B, max_len, quant)
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=0)
        return logits[:, -1], caches

    return step


def make_decode_step(cfg, *, quant=None, greedy: bool = True):
    """fn(params, tokens (B,), pos, caches, page_table=None) ->
    (next_tokens, logits, caches).

    One new token per sequence against a preallocated cache — the function
    the decode_32k / long_500k cells lower. ``pos`` is a scalar (shared
    clock) or (B,) per-sequence lengths; ``page_table`` (B, NP) drives a
    paged cache (see core.paged_kv)."""

    def step(params, tokens, pos, caches, page_table=None):
        batch = {"tokens": tokens[:, None]}
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=pos,
                                       page_table=page_table)
        logits = logits[:, 0]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return step


def make_embed_decode_step(cfg, *, quant=None):
    """Decode step for frontend-stub archs (inputs are embeds)."""

    def step(params, embeds, pos, caches):
        batch = {"embeds": embeds}
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=pos)
        logits = logits[:, 0]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return step
