"""Step functions shared by dryrun / train / serve.

Everything here is mesh-agnostic pure functions; launchers wrap them in
jax.jit with shardings from parallel.sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.common import lm_head
from ..models.transformer import (forward, forward_hidden, init_cache,
                                  init_model, train_loss)
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.compress import CompressionConfig, compress_gradients, \
    error_feedback_init


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    adamw: AdamWConfig = AdamWConfig()
    lb_coeff: float = 0.01
    grad_compress: Optional[CompressionConfig] = None


def init_train_state(key, cfg, hp: TrainHParams):
    params = init_model(key, cfg)
    state = {"params": params, "opt": adamw_init(params, hp.adamw)}
    if hp.grad_compress is not None:
        state["ef_residual"] = error_feedback_init(params)
    return state


def make_train_step(cfg, hp: TrainHParams, *, quant=None):
    """Returns fn(state, batch) -> (state, metrics)."""

    def step(state, batch):
        def loss_fn(params):
            return train_loss(params, batch, cfg, quant=quant,
                              lb_coeff=hp.lb_coeff)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        if hp.grad_compress is not None:
            grads, new_res = compress_gradients(
                grads, state["ef_residual"], hp.grad_compress)
        params, opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], hp.lr, hp.adamw)
        new_state = {"params": params, "opt": opt}
        if hp.grad_compress is not None:
            new_state["ef_residual"] = new_res
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_state, metrics

    return step


def make_prefill_step(cfg, *, max_len: int, quant=None):
    """fn(params, batch) -> (last_logits, caches). Encoder archs return
    (logits, None) — a plain forward.

    This is the dryrun/whole-prompt prefill against a fresh DENSE cache; the
    serving path prefills incrementally into a shared paged pool via
    ``make_chunk_prefill_step`` below."""

    def step(params, batch):
        if cfg.family == "encoder":
            _, logits, _, _ = forward(params, batch, cfg, quant=quant)
            return logits, None
        B = (batch["tokens"].shape[0] if "tokens" in batch
             else batch["embeds"].shape[0])
        caches = init_cache(cfg, B, max_len, quant)
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=0)
        return logits[:, -1], caches

    return step


def make_chunk_prefill_step(cfg, *, quant=None, attn_impl: str = "gather"):
    """fn(params, tokens (Bp, S), start_pos (Bp,), valid_len (Bp,), caches,
    page_table (Bp, NP)) -> caches.

    One **bucketed prefill** program: runs a whole prompt chunk through the
    backbone in a single forward, quantizing K/V per layer and scattering the
    chunk into the paged pool via the page table. ``Bp`` is the number of
    stacked same-bucket prompt rows (multi-request batched prefill — each
    row carries its own page table, start position, and valid length) and
    ``S`` the bucket size (callers pad prompts up to a power-of-two bucket
    and jit retraces per bucket, so a max bucket of 2^k costs at most k+1
    compilations per row count); only the first ``valid_len`` tokens of a
    row are real — padded tails are masked out of the pool write
    (scratch-page redirect) and their hidden states are garbage that nobody
    reads. Skips the LM head entirely (prefill logits are never sampled; the
    decode step consumes the last prompt token), which is why this wraps
    ``forward_hidden`` and not ``forward``.

    ``attn_impl`` routes the chunk's attention reads exactly like decode
    ("gather" = jnp bitwise reference, "pallas" = the variable-length paged
    chunk kernel) — prefill and decode share ONE attention entry point
    (``models.attention.route_paged_attention``).

    Prefix sharing composes here for free: a prefix-cache hit aliases the
    shared pages into the slot's page table and the server calls this step
    with ``start_pos`` at the first NON-shared token — fully cached pages
    never see a forward (O(suffix/bucket) admission), while the chunk's
    attention still reads the shared history through the same page table.
    """
    def step(params, tokens, start_pos, valid_len, caches, page_table):
        batch = {"tokens": tokens}
        _, aux = forward_hidden(params, batch, cfg, quant=quant,
                                caches=caches, cache_pos=start_pos,
                                page_table=page_table, attn_impl=attn_impl,
                                kv_valid_len=valid_len)
        return aux["caches"]

    return step


def make_fused_step(cfg, *, quant=None, attn_impl: str = "gather"):
    """fn(params, tokens (R, S), start_pos (R,), valid_len (R,), caches,
    page_table (R, NP), emit_idx (R,)) -> (next_tokens (R,), logits (R, V),
    caches).

    ONE ragged variable-length program per scheduler cycle: every row is
    either a decode row (its single next token, ``valid_len == 1``) or a
    prefill chunk row (``valid_len`` real prompt tokens padded up to the
    shared bucket ``S``), each carrying its own page table and start
    position. Padded tails are masked out of the pool write through the
    ``valid_len`` scratch-page redirect, and their attention outputs are
    garbage nobody reads — the causal bound of every REAL query position is
    tighter than the padded KV extent, so garbage keys never leak into real
    rows (see ``route_paged_attention``).

    The LM head runs only on ``emit_idx`` rows (the rows that actually
    sample a token this cycle — decode rows, plus prefill rows finishing
    their prompt): hidden states are gathered per row at the row's LAST
    valid position before the (len(emit_idx), 1, V) head GEMM, so prefill
    rows riding along never pay vocab-width compute. Callers keep
    ``emit_idx`` a fixed (R,) shape (padded with row 0 and discarded on the
    host) so the only retrace axis is the S bucket.

    Steady state (all rows decoding: S == 1, ``emit_idx == arange(R)``,
    ``valid_len == 1``) lowers to exactly the ``make_decode_step`` program —
    the gathers are identity copies and the head GEMM has the same shape and
    operands — so fused decode is bitwise-identical to the separate-program
    path, which the subprocess identity test in tests/test_serve_fast.py
    asserts at kv-bits {0, 8, 4}."""

    def step(params, tokens, start_pos, valid_len, caches, page_table,
             emit_idx):
        batch = {"tokens": tokens}
        x, aux = forward_hidden(params, batch, cfg, quant=quant,
                                caches=caches, cache_pos=start_pos,
                                page_table=page_table, attn_impl=attn_impl,
                                kv_valid_len=valid_len)
        # hidden of each emitting row at its last REAL position
        h = jnp.take(x, emit_idx, axis=0)                       # (E, S, D)
        last = jnp.take(valid_len, emit_idx) - 1                # (E,)
        h = jnp.take_along_axis(h, last[:, None, None], axis=1)  # (E, 1, D)
        tied = params["embed"]["table"] if cfg.tie_embeddings else None
        logits = lm_head(params.get("head"), h, tied_table=tied)[:, 0]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, aux["caches"]

    return step


def make_decode_step(cfg, *, quant=None, greedy: bool = True,
                     attn_impl: str = "gather"):
    """fn(params, tokens (B,), pos, caches, page_table=None) ->
    (next_tokens, logits, caches).

    One new token per sequence against a preallocated cache — the function
    the decode_32k / long_500k cells lower. ``pos`` is a scalar (shared
    clock) or (B,) per-sequence lengths; ``page_table`` (B, NP) drives a
    paged cache (see core.paged_kv); ``attn_impl`` ("gather" | "pallas")
    picks the paged attention backend (models.attention.gqa_apply)."""

    def step(params, tokens, pos, caches, page_table=None):
        batch = {"tokens": tokens[:, None]}
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=pos,
                                       page_table=page_table,
                                       attn_impl=attn_impl)
        logits = logits[:, 0]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return step


def make_embed_decode_step(cfg, *, quant=None):
    """Decode step for frontend-stub archs (inputs are embeds)."""

    def step(params, embeds, pos, caches):
        batch = {"embeds": embeds}
        _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                       caches=caches, cache_pos=pos)
        logits = logits[:, 0]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return step
