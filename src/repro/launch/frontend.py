"""Multi-replica admission front: prefix-affinity routing over a pool of
:class:`~repro.launch.serve.BatchedServer` replicas on ONE shared
decode-step clock.

One :class:`BatchedServer` is a single tensor-parallel serving replica
(its mesh from ``launch.mesh.make_serving_mesh``; weights and the paged
KV pool sharded by ``parallel.sharding``). This module scales OUT: a
:class:`ReplicaFrontend` consumes a multi-tenant arrival stream
(``core.traffic`` traces) and routes each request to a replica, driving
every replica's :class:`~repro.launch.serve.ServeLoop` in lockstep so all
replicas share the trace's decode-step clock — replica i may never run
ahead of the next global arrival, exactly as a request pending on a
single server caps its decode spans.

Routing is **prefix-cache affinity first**: requests carrying a shared
system prompt (a ``(tenant, prefix_id)`` key from the trace) stick to
the replica that prefilled that prefix, so its cached pages keep being
re-aliased instead of being re-prefilled N times across the pool. The
sticky map yields only when the favored replica is overloaded relative
to the pool — the load score reads the replica's own ``slo.*`` gauges
(queue-depth EWMA) plus slot occupancy and paged-pool headroom
(``kv.device_pages_free`` / ``kv.device_pages_usable``), so balancing is
fed by the same telemetry the JSONL snapshot stream exports.

The third leg is the :class:`SharedPrefixStore`: a cross-replica page
exchange built on the PR-4 prefix-snapshot format (``profile_key`` +
pool-geometry namespaced ``(tokens, PageBlob)`` chains). After each
global round the frontend publishes every replica's cached chains into
the store and installs missing ones into the other replicas' HOST tiers
(zero device pages until a hit promotes them) — a hot system prompt
prefilled once by one replica is aliasable by all.

Identity contract: a 1-replica frontend is the plain server. Delivering
arrivals late (at the shared clock instead of up front) is invisible —
``ServeLoop.tick(limit_step=next_arrival)`` caps spans exactly like the
request sitting in the loop's own pending list would — so
``ReplicaFrontend([srv]).run(reqs)`` produces bitwise-identical token
streams to ``srv.run(reqs)`` (asserted in tests/test_frontend.py at
kv-bits 0/8/4; the shared store is inert at one replica).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.page_store import PageBlob, cache_geometry, extract_page
from ..runtime.telemetry import MetricsRegistry
from .serve import BatchedServer, Request, ServeLoop


class SharedPrefixStore:
    """Cross-replica prefix-page exchange on the snapshot wire format.

    Entries are keyed ``(page_size, geometry, profile_key, tokens)`` —
    the same namespacing the on-disk snapshot header carries — so pages
    only ever flow between replicas whose pool geometry (layer dtypes,
    containers, head layout) matches bit for bit, and chains quantized
    under different KV profiles never collide. Values are host-side
    ``PageBlob``s (the demote/snapshot container), published parents
    before children (the trie's DFS order) so installs can always find
    their ancestors.
    """

    def __init__(self):
        # namespace -> {(profile_key, tokens): PageBlob}; dicts preserve
        # insertion order, which preserves the parents-first publish order
        self._chains: Dict[tuple, dict] = {}
        self.published = 0
        self.installed = 0

    def _namespace(self, srv: BatchedServer) -> tuple:
        return (srv.page_size, cache_geometry(srv.caches))

    def __len__(self) -> int:
        return sum(len(ns) for ns in self._chains.values())

    def publish(self, srv: BatchedServer) -> int:
        """Copy every cached chain page of ``srv`` not yet in the store.

        Device-resident pages are read off the pool, demoted ones from
        the host tier, requantized ones are widened back through the
        quant tier's export — identical sourcing to
        ``BatchedServer.snapshot_prefix_cache``. Blobs are deep-copied to
        host numpy so the store owns its bytes (a later eviction in the
        source replica cannot invalidate them)."""
        if srv.prefix_cache is None:
            return 0
        ns = self._chains.setdefault(self._namespace(srv), {})
        n = 0
        for key, tokens, node in srv.prefix_cache.iter_chain_nodes():
            ck = (key, tuple(int(t) for t in tokens))
            if ck in ns:
                continue
            if node.host is not None:
                blob = srv.host_store.get(node.host)
            elif node.tier is not None:
                blob = srv.quant_tier.export(node.tier)
            else:
                blob = extract_page(srv.caches, node.page)
            ns[ck] = PageBlob([{f: np.asarray(a) for f, a in rec.items()}
                               for rec in blob.arrays])
            n += 1
        self.published += n
        return n

    def install(self, srv: BatchedServer) -> int:
        """Land every matching store chain ``srv`` does not already cache
        in its HOST tier (the snapshot-restore path: zero device pages
        consumed until a prefix hit promotes them). Stops early when the
        host tier fills; duplicate/orphaned chains are skipped without
        consuming a handle."""
        if srv.prefix_cache is None or srv.host_store is None:
            return 0
        ns = self._chains.get(self._namespace(srv), {})
        n = 0
        for (key, tokens), blob in ns.items():
            if not srv.host_store.has_room(1):
                break
            # fresh PageBlob per replica: host stores must not share blob
            # identity (each may drop independently); the numpy pages
            # themselves are immutable and safely shared
            h = srv.host_store.put(PageBlob([dict(r) for r in blob.arrays]))
            if srv.prefix_cache.insert_host(list(tokens), h, key):
                n += 1
            else:
                srv.host_store.drop(h)
        self.installed += n
        return n


def requests_from_trace(trace) -> Tuple[List[Request], List[Optional[tuple]]]:
    """Expand a ``core.traffic.Trace`` into fresh serve ``Request``s plus
    their affinity keys: ``(tenant, prefix_id)`` for arrivals drawn from a
    shared-prefix pool, None for prefix-less traffic (Request is mutable
    run state, so every replay arm needs its own instances)."""
    reqs, keys = [], []
    for r in trace.requests:
        reqs.append(Request(r.rid, np.array(r.prompt), r.max_new,
                            priority=r.priority,
                            deadline_step=r.deadline_step,
                            arrive_step=r.arrive_step))
        keys.append((r.tenant, r.prefix_id) if r.prefix_id >= 0 else None)
    return reqs, keys


def aggregate_goodput(requests: Sequence[Request]) -> Optional[float]:
    """Pool-level goodput over every offered request, on the decode-step
    clock — the same accounting as ``Tracer.slo_summary`` (a deadlined
    request is good iff it finished unrejected by ``deadline_step``;
    no-deadline requests are good iff they completed), but computable
    across replicas from the Request records alone."""
    if not requests:
        return None
    met = 0
    for r in requests:
        finished = r.done and r.error is None
        if r.deadline_step is None:
            met += bool(finished)
        else:
            met += bool(finished and r.finish_step is not None
                        and r.finish_step <= r.deadline_step)
    return met / len(requests)


class ReplicaFrontend:
    """Admission front over N serving replicas (see module docstring).

    ``servers`` are fully constructed :class:`BatchedServer`s — typically
    from :func:`make_replicas`, each with its own namespaced metrics
    registry. ``share_prefixes`` enables the cross-replica
    :class:`SharedPrefixStore` sync after every global round (requires
    the replicas to run ``--prefix-cache on --kv-offload host``; it is
    forced off at one replica, where it could only churn handles).
    ``rebalance_margin`` is how much worse (in load-score units: one unit
    is roughly one queued request or a fully busy batch) the sticky
    replica must be than the pool's best before affinity yields.
    """

    def __init__(self, servers: Sequence[BatchedServer], *,
                 share_prefixes: bool = True,
                 rebalance_margin: float = 2.0,
                 metrics: Optional[MetricsRegistry] = None):
        if not servers:
            raise ValueError("ReplicaFrontend needs at least one replica")
        self.servers = list(servers)
        self.loops: List[ServeLoop] = [s.start_loop([]) for s in servers]
        # counter names carry the "frontend." prefix themselves, so the
        # registry stays un-namespaced and merges cleanly with the
        # replicas' namespaced snapshots
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.affinity: Dict[tuple, int] = {}
        self.store = (SharedPrefixStore()
                      if share_prefixes and len(self.servers) > 1
                      and all(s.prefix_cache is not None
                              and s.host_store is not None
                              for s in self.servers) else None)
        self.rebalance_margin = rebalance_margin

    # -- load / routing -----------------------------------------------------
    def load_score(self, i: int) -> float:
        """Replica i's routing load: queue-depth EWMA (the ``slo.*``
        gauge), plus undelivered+queued work and slot occupancy, minus
        paged-pool headroom — a replica with free pages absorbs a routed
        prefill without evicting, an exhausted one starts preempting."""
        srv, loop = self.servers[i], self.loops[i]
        g = srv.metrics.gauge
        score = float(g("slo.queue_depth_ewma").value)
        score += len(loop.queue) + len(loop.pending)
        score += sum(s is not None for s in srv.slots) / max(1, srv.B)
        if srv.paged:
            usable = float(g("kv.device_pages_usable").value)
            if usable > 0:
                score -= float(g("kv.device_pages_free").value) / usable
        return score

    def route(self, req: Request, key: Optional[tuple] = None) -> int:
        """Pick a replica for ``req``: sticky on the affinity key while
        the favored replica's load stays within ``rebalance_margin`` of
        the pool's best, least-loaded otherwise."""
        n = len(self.servers)
        if n == 1:
            return 0
        best = min(range(n), key=self.load_score)
        r = self.affinity.get(key) if key is not None else None
        if r is not None:
            if self.load_score(r) - self.load_score(best) \
                    > self.rebalance_margin:
                self.affinity[key] = r = best
                self.metrics.counter("frontend.rebalanced").inc()
            else:
                self.metrics.counter("frontend.affinity_hits").inc()
        else:
            r = best
            if key is not None:
                self.affinity[key] = r
        return r

    def _deliver(self, req: Request, key: Optional[tuple]) -> int:
        r = self.route(req, key)
        self.loops[r].add(req)
        self.metrics.counter("frontend.routed").inc()
        self.metrics.counter(f"frontend.routed_replica{r}").inc()
        return r

    def _sync_store(self) -> None:
        if self.store is None:
            return
        for srv in self.servers:
            self.store.publish(srv)
        n = sum(self.store.install(srv) for srv in self.servers)
        if n:
            self.metrics.counter("frontend.shared_prefix_pages").inc(n)

    # -- drive --------------------------------------------------------------
    def run(self, requests: Sequence[Request],
            keys: Optional[Sequence[Optional[tuple]]] = None
            ) -> List[Request]:
        """Serve ``requests`` to completion across the pool.

        The shared clock ``t`` jumps arrival to arrival: deliver every
        request with ``arrive_step <= t`` to its routed replica, then
        tick each unfinished replica loop with ``limit_step`` = the next
        global arrival until its clock reaches it — so no replica decodes
        past traffic it has not seen yet. Once arrivals are exhausted the
        replicas drain independently. Returns ``requests`` (now carrying
        out/done/finish_step, like ``BatchedServer.run``)."""
        if keys is None:
            keys = [None] * len(requests)
        if len(keys) != len(requests):
            raise ValueError("keys must parallel requests")
        pending = sorted(zip(requests, keys),
                         key=lambda rk: rk[0].arrive_step)
        while True:
            t = min(loop.clock for loop in self.loops)
            while pending and pending[0][0].arrive_step <= t:
                req, key = pending.pop(0)
                self._deliver(req, key)
            na = pending[0][0].arrive_step if pending else None
            for loop in self.loops:
                while not loop.finished and (na is None
                                             or loop.clock < na):
                    loop.tick(limit_step=na)
            if pending:
                # every replica reached the arrival step; deliver at na
                self._sync_store()
                continue
            if all(l.finished for l in self.loops):
                break
        for loop in self.loops:
            loop.close()
        self._sync_store()
        return list(requests)


def make_replicas(n: int, cfg, params, **server_kwargs
                  ) -> List[BatchedServer]:
    """Construct ``n`` identical replicas, each with its own namespaced
    registry (``replica0`` ... — the merged JSONL stream keeps the
    per-replica ``slo.*`` / ``kv.*`` streams apart). ``server_kwargs``
    are passed to every :class:`BatchedServer` verbatim; pass ``mesh=``
    for tensor-parallel replicas."""
    if n < 1:
        raise ValueError("need at least one replica")
    if "registry" in server_kwargs:
        raise ValueError("make_replicas owns the per-replica registries")
    return [BatchedServer(cfg, params,
                          registry=MetricsRegistry(namespace=f"replica{i}"),
                          **server_kwargs)
            for i in range(n)]


def merged_snapshot(frontend: ReplicaFrontend) -> dict:
    """One JSON-ready dict merging the frontend's own counters with every
    replica's namespaced snapshot (``replica0.slo.window_goodput`` etc.) —
    the multi-replica analogue of ``MetricsRegistry.snapshot``."""
    out = frontend.metrics.snapshot()
    for srv in frontend.servers:
        snap = srv.metrics.snapshot()
        for section in ("counters", "gauges", "histograms"):
            out[section].update(snap[section])
    return out
