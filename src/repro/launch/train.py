"""End-to-end training launcher.

Composes every substrate layer: synthetic LM pipeline (pure-function-of-step
batches), pjit'd train step with the production sharding rules, AdamW
(optionally int8 moments), per-layer precision (fake-quant via --policy /
--kv-bits), async checkpointing, fault-tolerant supervisor with straggler
log, and elastic restore (checkpoints are mesh-agnostic).

On this container it runs REAL training on the 1-CPU mesh — e.g. the ~100M
LM of examples/train_lm_mixed_precision.py; on a pod the same file drives
the production mesh (--mesh single|multi).

Usage (CPU example):
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --smoke \
      --steps 200 --batch-size 8 --seq-len 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.ckpt import CheckpointManager, latest_step
from ..configs.registry import get_config, get_smoke_config
from ..core.fixedpoint import FixedPointFormat
from ..core.policy import PrecisionPolicy
from ..data.lm import LMDataConfig, lm_batch
from ..data.pipeline import DataPipeline
from ..optim.adamw import AdamWConfig
from ..optim.compress import CompressionConfig
from ..optim.schedule import cosine_warmup
from ..parallel.hints import activation_hints
from ..parallel.sharding import (auto_batch_sharding, plan_for_mesh,
                                 state_shardings)
from ..quant.apply import build_model_quant, transformer_layer_names
from ..runtime.fault import StragglerMonitor, TrainSupervisor
from .mesh import make_host_mesh, make_production_mesh
from .steps import TrainHParams, init_train_state, make_train_step


def build_quant(cfg, *, weight_bits: int, data_bits: int, kv_bits: int,
                policy_json: str):
    if policy_json:
        with open(policy_json) as f:
            pol = PrecisionPolicy.from_json(f.read())
    elif weight_bits or data_bits:
        names = transformer_layer_names(cfg)
        w = FixedPointFormat(2, weight_bits - 2) if weight_bits else None
        d = FixedPointFormat(4, data_bits - 4) if data_bits else None
        pol = PrecisionPolicy.uniform(names, w, d)
    else:
        pol = None
    if pol is None and not kv_bits:
        return None
    if pol is None:
        names = transformer_layer_names(cfg)
        pol = PrecisionPolicy.uniform(
            names, None, FixedPointFormat(2, kv_bits - 2))
    return build_model_quant(pol, cfg, quantize_kv=kv_bits > 0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 gradient wire format + error feedback")
    # per-layer precision (the paper's feature, as first-class flags)
    ap.add_argument("--weight-bits", type=int, default=0)
    ap.add_argument("--data-bits", type=int, default=0)
    ap.add_argument("--kv-bits", type=int, default=0)
    ap.add_argument("--policy", default="", help="PrecisionPolicy json file")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multi"))
    plan = plan_for_mesh(mesh)

    hp = TrainHParams(
        lr=args.lr,
        adamw=AdamWConfig(quantize_moments=args.int8_moments),
        grad_compress=CompressionConfig() if args.grad_compress else None)
    quant = build_quant(cfg, weight_bits=args.weight_bits,
                        data_bits=args.data_bits, kv_bits=args.kv_bits,
                        policy_json=args.policy)

    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                        batch_size=args.batch_size, seed=args.seed + 1)

    state_struct = jax.eval_shape(
        lambda k: init_train_state(k, cfg, hp), jax.random.PRNGKey(args.seed))
    state_sh = state_shardings(state_struct, plan)
    batch_struct = jax.eval_shape(lambda: lm_batch(dcfg, 0))
    batch_sh = auto_batch_sharding(batch_struct, plan)

    lr_fn = cosine_warmup(args.lr, args.warmup, args.steps)

    def step_with_lr(state, batch, step_idx):
        hp_s = dataclasses.replace(hp, lr=lr_fn(step_idx))
        return make_train_step(cfg, hp_s, quant=quant)(state, batch)

    with activation_hints(plan):
        jit_step = jax.jit(step_with_lr,
                           in_shardings=(state_sh, batch_sh, None),
                           out_shardings=(state_sh, None),
                           donate_argnums=(0,))

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(args.ckpt_dir,
                                     interval=args.ckpt_interval)
        if args.resume and args.ckpt_dir and \
                latest_step(args.ckpt_dir) is not None:
            start_step, state, _ = ckpt.restore_latest(
                state_struct, shardings=state_sh)
            print(f"[train] resumed from step {start_step}")
        else:
            state = jax.jit(
                lambda k: init_train_state(k, cfg, hp),
                out_shardings=state_sh)(jax.random.PRNGKey(args.seed))

        pipe = DataPipeline(lambda s: lm_batch(dcfg, s),
                            sharding=batch_sh, start_step=start_step)
        monitor = StragglerMonitor()

        def one_step(state, step):
            batch = next(pipe)
            state, metrics = jit_step(state, batch, step)
            return state, metrics

        def save_hook(step, state):
            if ckpt:
                ckpt.maybe_save(step, state,
                                extra={"data": pipe.state})

        def restore_fn():
            step, state, extra = ckpt.restore_latest(state_struct,
                                                     shardings=state_sh)
            pipe.restore(extra.get("data", {"step": step}))
            return step, state

        sup = TrainSupervisor(step_fn=one_step, save_hook=save_hook,
                              restore_fn=restore_fn, monitor=monitor)

        log = []
        t_start = time.time()
        # run in chunks so we can print progress
        step = start_step
        while step < args.steps:
            n = min(args.log_every, args.steps - step)
            state, metrics_list = sup.run(state, step, n)
            step += n
            m = metrics_list[-1]
            loss = float(m["loss"])
            log.append({"step": step, "loss": loss,
                        "grad_norm": float(m["grad_norm"])})
            tok_s = (args.batch_size * args.seq_len * n /
                     max(time.time() - t_start, 1e-9))
            t_start = time.time()
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"grad_norm {float(m['grad_norm']):8.3f} tok/s {tok_s:,.0f}")

    if ckpt:
        ckpt.maybe_save(step, state, extra={"data": pipe.state}, force=True)
        ckpt.wait()
    print("[train] straggler summary:", json.dumps(monitor.summary()))
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(log, f, indent=1)
    return log


if __name__ == "__main__":
    main()
