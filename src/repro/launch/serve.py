"""Batched serving launcher: continuous-batching prefill + decode with an
optionally quantized KV cache (the paper's per-layer data bits where they
matter most — decode reads the whole cache every token).

A REQUEST = (prompt token ids, max_new_tokens). The server packs up to
--batch-size requests into one cache, prefills the longest-prompt-padded
batch, then decodes step-by-step; finished rows are refilled from the queue
(continuous batching at step granularity).

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
      --requests 12 --batch-size 4 --max-new 24 --kv-bits 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config, get_smoke_config
from ..core.fixedpoint import FixedPointFormat
from ..core.policy import PrecisionPolicy
from ..models.transformer import init_cache, init_model
from ..quant.apply import build_model_quant, transformer_layer_names
from .steps import make_decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-slot continuous batching over a single shared cache buffer."""

    def __init__(self, cfg, params, *, batch_size: int, max_len: int,
                 kv_bits: int = 0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.quant = None
        if kv_bits:
            names = transformer_layer_names(cfg)
            pol = PrecisionPolicy.uniform(
                names, None, FixedPointFormat(2, kv_bits - 2))
            self.quant = build_model_quant(pol, cfg, quantize_kv=True,
                                           quantize_activations=False)
        self.decode = jax.jit(make_decode_step(cfg, quant=self.quant))
        # one shared cache; per-slot write positions ride in `pos` per step.
        # Slots are synchronized to a common step clock (pos = max fill);
        # per-slot masks keep shorter prompts correct via left-padding.
        self.caches = init_cache(cfg, batch_size, max_len, self.quant)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.pos = 0
        self.tokens = jnp.zeros((batch_size,), jnp.int32)

    def _prefill_slot(self, slot: int, req: Request):
        """Feed the prompt through decode steps (slot-granular prefill keeps
        one compiled program; a production server would use a bucketed
        prefill jit — see launch.steps.make_prefill_step)."""
        for t in req.prompt:
            tok = self.tokens.at[slot].set(int(t))
            nxt, _, self.caches = self.decode(
                self.params, tok, jnp.int32(self.pos), self.caches)
            self.tokens = tok
            self.pos += 1

    def run(self, requests: List[Request], *, verbose: bool = False):
        queue = list(requests)
        active: List[Request] = []
        t0 = time.time()
        steps = 0
        while queue or any(not r.done for r in active):
            # fill free slots
            for i in range(self.B):
                if self.slots[i] is None and queue:
                    req = queue.pop(0)
                    self._prefill_slot(i, req)
                    self.slots[i] = req
                    active.append(req)
            # one decode step for all slots
            nxt, _, self.caches = self.decode(
                self.params, self.tokens, jnp.int32(self.pos), self.caches)
            self.pos += 1
            steps += 1
            nxt_np = np.asarray(nxt)
            self.tokens = nxt
            for i in range(self.B):
                req = self.slots[i]
                if req is None:
                    continue
                req.out.append(int(nxt_np[i]))
                if len(req.out) >= req.max_new or self.pos >= self.max_len - 1:
                    req.done = True
                    self.slots[i] = None
            if self.pos >= self.max_len - 1:
                break
        dt = time.time() - t0
        if verbose:
            print(f"[serve] {steps} decode steps, {len(requests)} requests, "
                  f"{steps * self.B / max(dt, 1e-9):,.1f} tok-slots/s")
        return requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--kv-bits", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only archs have no decode path")
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    srv = BatchedServer(cfg, params, batch_size=args.batch_size,
                        max_len=args.max_len, kv_bits=args.kv_bits)
    srv.run(reqs, verbose=True)
    for r in reqs[:4]:
        print(f"  req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    return reqs


if __name__ == "__main__":
    main()
