"""Batched serving launcher: continuous-batching prefill + decode with an
optionally quantized, optionally **paged** KV cache.

A REQUEST = (prompt token ids, max_new_tokens). The server packs up to
--batch-size requests into fixed slots and decodes step-by-step with
**per-slot positions**: each slot tracks its own length, finished slots are
refilled from the queue (continuous batching at step granularity), and idle
slots harmlessly rewrite a scratch location.

Two cache layouts:

* dense (default): one (batch, max_len, ...) slab per layer — HBM scales
  with the worst-case request even for short traffic.
* paged (--page-size N): per-layer page pools + a per-slot page table
  (core.paged_kv). Pages are allocated as a request grows and freed when it
  completes, so cache HBM scales with live tokens, not max_len. KV bits
  apply inside the page container: --kv-bits 8 stores int8 pages, --kv-bits
  4 lane-packs a 4-bit grid into int32 words (~8x smaller at rest than
  fp32). --num-pages sizes the shared pool (default: full capacity).

CPU demos:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
      --requests 12 --batch-size 4 --max-new 24 --kv-bits 8
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
      --requests 12 --batch-size 4 --max-new 24 --kv-bits 4 --page-size 16

Bench (tokens/sec + HBM bytes/token, dense vs paged int8 vs paged int4):
  PYTHONPATH=src python -m benchmarks.run paged_serve
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config, get_smoke_config
from ..core.fixedpoint import FixedPointFormat
from ..core.paged_kv import (SCRATCH_PAGE, PageAllocator, PagedCacheSpec,
                             max_pages_per_seq)
from ..core.policy import PrecisionPolicy
from ..models.transformer import init_cache, init_model
from ..quant.apply import build_model_quant, transformer_layer_names
from .steps import make_decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-slot continuous batching with per-slot positions.

    Invariant per occupied slot i: cache positions [0, pos[i]) hold the KV
    of the request's consumed tokens and ``tokens[i]`` is the next token to
    consume (last prompt token after prefill, last generated token after).
    Free slots sit at pos 0 with their page-table row parked on the scratch
    page, so the shared decode step can run them without corrupting live
    data.
    """

    def __init__(self, cfg, params, *, batch_size: int, max_len: int,
                 kv_bits: int = 0, page_size: int = 0,
                 num_pages: Optional[int] = None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.paged = page_size > 0
        if self.paged and cfg.attention_type == "mla":
            raise NotImplementedError("paged KV serving supports GQA archs")
        self.quant = None
        if kv_bits:
            container = "int4" if (self.paged and kv_bits <= 4) else "int8"
            names = transformer_layer_names(cfg)
            pol = PrecisionPolicy.uniform(
                names, None, FixedPointFormat(2, kv_bits - 2))
            self.quant = build_model_quant(pol, cfg, quantize_kv=True,
                                           quantize_activations=False,
                                           kv_container=container)
        self.decode = jax.jit(make_decode_step(cfg, quant=self.quant))

        paged_spec = None
        if self.paged:
            self.np_max = max_pages_per_seq(max_len, page_size)
            if num_pages is None:
                num_pages = 1 + batch_size * self.np_max  # full capacity
            paged_spec = PagedCacheSpec(page_size=page_size,
                                        num_pages=num_pages)
            self.allocator = PageAllocator(num_pages)
            self.page_size = page_size
            self.page_table = np.full((batch_size, self.np_max),
                                      SCRATCH_PAGE, np.int32)
            self.slot_pages: List[List[int]] = [[] for _ in range(batch_size)]
        self.caches = init_cache(cfg, batch_size, max_len, self.quant,
                                 paged=paged_spec)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.pos = np.zeros((batch_size,), np.int32)
        self.tokens = jnp.zeros((batch_size,), jnp.int32)

    # -- page bookkeeping ---------------------------------------------------
    def _ensure_page(self, slot: int, position: int):
        """Allocate pages so logical ``position`` of ``slot`` is backed."""
        blk = position // self.page_size
        while len(self.slot_pages[slot]) <= blk:
            page = self.allocator.alloc()
            self.page_table[slot, len(self.slot_pages[slot])] = page
            self.slot_pages[slot].append(page)

    def _release_slot(self, slot: int):
        if self.paged and self.slot_pages[slot]:
            self.allocator.free(self.slot_pages[slot])
            self.slot_pages[slot] = []
            self.page_table[slot, :] = SCRATCH_PAGE
        self.pos[slot] = 0

    # -- decode -------------------------------------------------------------
    def _step(self):
        pt = jnp.asarray(self.page_table) if self.paged else None
        nxt, logits, self.caches = self.decode(
            self.params, self.tokens, jnp.asarray(self.pos), self.caches, pt)
        return nxt

    def _prefill_slot(self, slot: int, req: Request):
        """Feed prompt[:-1] through shared decode steps, leaving the last
        prompt token in ``tokens`` for the run loop to consume (slot-granular
        prefill keeps one compiled program; a production server would use a
        bucketed prefill jit — see launch.steps.make_prefill_step). Other
        slots do not advance: they rewrite their current position with
        identical values."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid} has an empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"request {req.rid} prompt length "
                             f"{len(req.prompt)} >= max_len {self.max_len}")
        self.pos[slot] = 0
        for t in req.prompt[:-1]:
            if self.paged:
                self._ensure_page(slot, int(self.pos[slot]))
            self.tokens = self.tokens.at[slot].set(int(t))
            self._step()
            self.pos[slot] += 1
        self.tokens = self.tokens.at[slot].set(int(req.prompt[-1]))

    def run(self, requests: List[Request], *, verbose: bool = False):
        queue = list(requests)
        t0 = time.time()
        steps = 0
        gen_tokens = 0
        while queue or any(s is not None for s in self.slots):
            for i in range(self.B):
                if self.slots[i] is None and queue:
                    req = queue.pop(0)
                    self._prefill_slot(i, req)
                    self.slots[i] = req
            if self.paged:
                for i in range(self.B):
                    if self.slots[i] is not None:
                        self._ensure_page(i, int(self.pos[i]))
            nxt = self._step()
            steps += 1
            nxt_np = np.array(nxt)
            keep = np.asarray(self.tokens)
            for i in range(self.B):
                req = self.slots[i]
                if req is None:
                    nxt_np[i] = keep[i]     # idle slots hold their token
                    continue
                req.out.append(int(nxt_np[i]))
                gen_tokens += 1
                self.pos[i] += 1
                if (len(req.out) >= req.max_new
                        or self.pos[i] >= self.max_len - 1):
                    req.done = True
                    self.slots[i] = None
                    self._release_slot(i)
            self.tokens = jnp.asarray(nxt_np)
        dt = time.time() - t0
        if verbose:
            layout = (f"paged ps={self.page_size} "
                      f"free={self.allocator.num_free}"
                      if self.paged else "dense")
            print(f"[serve] {steps} decode steps, {len(requests)} requests, "
                  f"{gen_tokens / max(dt, 1e-9):,.1f} tok/s "
                  f"({steps * self.B / max(dt, 1e-9):,.1f} tok-slots/s, "
                  f"{layout})")
        return requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--kv-bits", type=int, default=0,
                    help="0=fp cache, 8=int8 pages/grid, 4=int4 "
                         "(lane-packed when --page-size > 0)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per KV page; 0 = dense max_len cache")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="shared pool pages (0 = full capacity)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only archs have no decode path")
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    srv = BatchedServer(cfg, params, batch_size=args.batch_size,
                        max_len=args.max_len, kv_bits=args.kv_bits,
                        page_size=args.page_size,
                        num_pages=args.num_pages or None)
    srv.run(reqs, verbose=True)
    for r in reqs[:4]:
        print(f"  req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    return reqs


if __name__ == "__main__":
    main()
