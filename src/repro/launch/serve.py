"""Batched serving launcher: continuous-batching prefill + decode with an
optionally quantized, optionally **paged** KV cache.

A REQUEST = (prompt token ids, max_new_tokens). The server packs up to
--batch-size requests into fixed slots and decodes step-by-step with
**per-slot positions**: each slot tracks its own length, finished slots are
refilled from the queue (continuous batching at step granularity), and idle
slots harmlessly rewrite a scratch location.

Two cache layouts:

* dense (default): one (batch, max_len, ...) slab per layer — HBM scales
  with the worst-case request even for short traffic.
* paged (--page-size N): per-layer page pools + a per-slot page table
  (core.paged_kv). Pages are allocated as a request grows and freed when it
  completes, so cache HBM scales with live tokens, not max_len. KV bits
  apply inside the page container: --kv-bits 8 stores int8 pages, --kv-bits
  4 lane-packs a 4-bit grid into int32 words (~8x smaller at rest than
  fp32). --num-pages sizes the shared pool (default: full capacity).
  Admission preflights the pool: a request whose worst-case page demand can
  never fit raises ``OutOfPagesError`` (with counts) up front; one that
  merely has to wait for live requests to finish is deferred in the queue.

The serving **hot path** is built around three ideas:

* **Bucketed chunked prefill** (paged, attention-only archs): prompts are
  admitted through ``launch.steps.make_chunk_prefill_step`` — one forward
  per power-of-two prompt chunk (--prefill-bucket caps the bucket), padded
  and masked, writing straight into the paged pool — instead of O(prompt)
  whole-batch decode steps. ``--prefill stepwise`` keeps the slot-granular
  reference path (bitwise-identical results; see tests/test_serve_fast.py).
* **Multi-request batched prefill** (--prefill-batch): one admission cycle
  may admit several waiting prompts (the scheduler's admit window surfaces
  them), and their same-bucket chunks STACK into single [n_reqs, bucket]
  prefill forwards with per-row page tables, start positions, and valid
  lengths — amortizing both forward count and per-bucket compilations
  across concurrent admissions. Rows are independent sequences writing
  disjoint pages, so batched == sequential bitwise (tests assert it).
  An ``OutOfPagesError`` mid-batch rolls back every partially admitted
  row before surfacing.
* **One fused ragged forward per scheduler cycle** (--fused on): prefill
  chunks and decode tokens ride ONE `[batch, bucket]` variable-length
  program (``launch.steps.make_fused_step``) — each row carries its own
  query length, start position, and page table (decode rows S=1 padded into
  the shared bucket, prefill rows S=bucket), the LM head gathers only the
  rows that emit a token this cycle, and steady-state serving runs exactly
  one jitted program per cycle with zero prefill/decode program switches
  (``cycles == program_launches``, asserted in tests). Fused output is
  bitwise-identical to the separate-program reference at every kv-bits
  setting (single-threaded XLA; see tests/test_serve_fast.py).
* **Prefix-aware batched prefill** (wave dedupe): inside one admission
  wave, a prompt sharing page-aligned full chunks with an earlier same-wave
  prompt WAITS until that leader has written through the shared span, then
  increfs the leader's pages into its own table instead of re-running their
  forwards — so --prefill-batch composes with --prefix-cache (auto batching
  no longer falls back to sequential admission under the prefix cache).
* **Unified kernel-routed attention** (--attn-impl pallas): decode AND
  chunked prefill attention run through ONE variable-length
  ``kernels.paged_kv_attention`` chunk kernel (scalar-prefetch DMA over
  the page table, dequant in VMEM, per-row causal masking against cache
  positions; interpret-mode on CPU, compiled on TPU) — there is no jnp
  fallback on the S>1 path. The default ``gather`` impl stays the
  bitwise-reference mode for every chunk shape.
* **Batched host<->device traffic**: decode advances in "runs" between slot
  events (admission/completion, both predictable from token counts), feeding
  next-token ids device-to-device and fetching generated tokens
  asynchronously at run boundaries — no per-token ``.at[slot].set`` and no
  blocking per-step ``np.array`` round-trips.
* **Shared-prefix page cache** (--prefix-cache on): a page-granular radix
  index over prompt tokens (``core.prefix_cache``). Admission aliases fully
  matched pages into the new slot's page table (refcounted — sharing is
  pure indirection, the kernels never know), copies-on-write the page where
  the prompt diverges mid-page, charges reservation accounting only for the
  non-shared suffix, and prefills only that suffix: prefix hits turn
  O(prompt/bucket) admission forwards into O(suffix/bucket). Unreferenced
  cached prefixes are LRU-evicted under pool pressure.
* **Per-layer KV precision profiles** (--kv-profile policy.json): the
  paper's central result — precision tolerance varies per layer — applied
  to the serving pool. Each layer's pages live in the container its policy
  data format needs (int4 / int8 / float), so a ``core.search`` policy
  drives the at-rest KV footprint directly; uniform --kv-bits stays the
  degenerate profile. Contiguous same-container layer runs still ride
  ``lax.scan`` (--kv-profile-scan unroll forces the unrolled reference).
  --kv-scale page additionally calibrates per-page max-abs dequant scales
  at write time instead of the static Q(I,F) grid.
* **Online precision adaptation** (--kv-adapt on): under pool pressure,
  cold cached prefix pages are REQUANTIZED one container step narrower
  (fp -> int8 -> int4, freshly calibrated per-page max-abs scales) into a
  bounded device-byte tier (``core.page_store.QuantTierStore``) *before*
  any host demotion — the paper's within-network precision-tolerance
  result applied temporally: a page's precision decays with coldness
  instead of being fixed at admission. Eviction order becomes
  requant -> host demote -> destructive drop; a re-hit widens the page
  back into the pool (the one-step quantization error is the price of
  having kept it on device). --kv-adapt-floor bounds the ladder (4 or 8
  data bits; per-layer --kv-profile containers are the starting rungs),
  --kv-adapt-pages bounds the tier's byte budget.
* **Tiered page store** (--kv-offload host): a host-memory page tier
  (``core.page_store``) behind the bounded device pool. Pool pressure
  *demotes* unreferenced cached prefixes to host numpy (bytes stay in their
  packed int4/int8/fp containers, so offload traffic scales with the
  precision policy) instead of destroying them; admission *promotes*
  matched host pages back before aliasing. --host-pages bounds the tier.
  ``snapshot_prefix_cache``/``restore_prefix_cache`` (--prefix-snapshot)
  persist the cached chains across server restarts — the snapshot is
  profile-key-namespaced like the trie, so an int8 snapshot never backs an
  int4 server.
* **SLO scheduling + preemption** (--sched slo): admission orders the queue
  by (priority, deadline, arrival) and may admit up to --admit-window
  requests past a deferred head (killing the FIFO head-of-line block). A
  queued request strictly more urgent than a running one may PREEMPT it:
  the victim's written pages demote to the host tier, the request
  re-queues, and on re-admission its pages promote back and decoding
  resumes bitwise-identically — no re-prefill (gather mode; see
  tests/test_scheduler.py). Preemption requires --kv-offload host.

CPU demos:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
      --requests 12 --batch-size 4 --max-new 24 --kv-bits 8
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
      --requests 12 --batch-size 4 --max-new 24 --kv-bits 4 --page-size 16 \
      --attn-impl pallas

Bench (tok/s, prefill latency, HBM bytes/token; dense vs paged, gather vs
pallas, stepwise vs bucketed):
  PYTHONPATH=src python -m benchmarks.run paged_serve
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config, get_smoke_config
from ..core.fixedpoint import FixedPointFormat
from ..core.page_store import (HostPageStore, QuantTierStore, TieredPager,
                               cache_geometry, extract_page, inject_page,
                               load_prefix_snapshot, save_prefix_snapshot,
                               snapshot_path)
from ..core.paged_kv import (SCRATCH_PAGE, OutOfPagesError, PageAllocator,
                             PagedCacheSpec, caches_kv_bytes, copy_pool_pages,
                             map_kv_pools, max_pages_per_seq)
from ..core.policy import LayerPolicy, PrecisionPolicy
from ..core.prefix_cache import PrefixCache
from ..models.transformer import init_cache, init_model
from ..parallel.sharding import (paged_pool_shardings, param_shardings,
                                 plan_for_mesh)
from ..quant.apply import (build_model_quant, kv_profile_key,
                           transformer_layer_names)
from ..runtime.telemetry import (MetricsRegistry, MetricsSnapshotter,
                                 SLOMonitor, make_tracer, metric_attr)
from .mesh import make_serving_mesh
from .scheduler import DeadlineMissPredictor, SchedPolicy, SLOScheduler
from .steps import make_chunk_prefill_step, make_decode_step, make_fused_step


@dataclasses.dataclass
class PreemptedState:
    """Slot state captured at a span boundary when a request is preempted:
    everything resume needs to continue decoding bitwise-identically —
    the cache position, the next token to consume, the generated count,
    and one entry per slot page (in page-table order). An entry is either
    ``("host", handle)`` — the page's bytes were demoted to the host tier —
    or ``("alias", node)`` — the page aliases a still-resident prefix-cache
    node (refcount > 1, so demoting it frees nothing): the victim's slot
    reference was dropped, the node PINNED against eviction, and resume
    re-aliases it with a fresh incref instead of paying the host round
    trip (preemption re-aliasing)."""

    pos: int
    token: int
    gen: int
    entries: List[tuple]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # --- scheduling metadata (core.launch.scheduler orders on these) ---
    priority: int = 0           # higher = more urgent
    deadline_step: Optional[int] = None  # SLO: finish by this decode step
    arrive_step: int = 0        # becomes visible to admission at this step
    # --- outcome / preemption state ---
    error: Optional[Exception] = None    # set when admission rejects
    finish_step: Optional[int] = None    # decode-step clock at retirement
    preemptions: int = 0
    _paused: Optional[PreemptedState] = None
    # admission-cycle feature vector (predictor on, deadlined requests
    # only): the training example paired with the miss/met label at retire
    _risk_feat: Optional[list] = None


def _pow2_bucket(n: int, cap: int) -> int:
    """Smallest power-of-two >= n, clipped to cap (the max bucket)."""
    return min(cap, 1 << max(0, n - 1).bit_length())


def _shared_page_tokens(a: np.ndarray, b: np.ndarray, ps: int) -> int:
    """Length of the common prompt prefix of ``a`` and ``b`` that both
    requests actually CACHE (each writes prompt[:-1]; the last token is
    consumed by decode), rounded down to full ``ps``-token pages — the span
    one admission-wave prompt can alias off another's freshly written
    pages."""
    n = min(len(a), len(b)) - 1
    if n <= 0:
        return 0
    eq = a[:n] == b[:n]
    common = n if eq.all() else int(np.argmin(eq))
    return (common // ps) * ps


@dataclasses.dataclass
class _PrefillJob:
    """One planned bucketed prefill (slot already reserved/aliased): feed
    ``req.prompt[start:-1]`` into the pool. ``done`` tracks written tokens
    across the batched rounds; ``finished`` flips once the slot's clock and
    token state are final (rollback on a failed batch skips finished
    jobs). ``wait_for = (leader_job, shared)`` marks a wave-dedupe
    follower: it sits out prefill rounds until ``leader_job`` has written
    through token ``shared``, then aliases the leader's pages for
    [start, shared) instead of re-running their forwards (see
    ``_plan_wave_dedupe``). ``start`` is mutable for exactly that jump."""

    slot: int
    req: Request
    start: int
    done: int = 0
    finished: bool = False
    wait_for: Optional[tuple] = None

    @property
    def total(self) -> int:
        return max(0, len(self.req.prompt) - 1 - self.start)


def _upload(x):
    """Device-put a host-MUTABLE numpy buffer via a host-side snapshot.

    jax may zero-copy alias numpy memory on CPU, and even copying uploads
    can complete asynchronously — so handing jax a buffer the serving loop
    later mutates in place (pos, tokens, page_table) is a data race. The
    snapshot is synchronous host work and nobody ever mutates it."""
    return jnp.asarray(np.array(x))


class BatchedServer:
    """Fixed-slot continuous batching with per-slot positions.

    Invariant per occupied slot i: cache positions [0, pos[i]) hold the KV
    of the request's consumed tokens and ``tokens[i]`` is the next token to
    consume (last prompt token after prefill, last generated token after).
    Free slots sit at pos 0 with their page-table row parked on the scratch
    page, so the shared decode step can run them without corrupting live
    data.

    ``prefill``: "auto" picks the bucketed chunked prefill whenever the
    layout supports it (paged + attention-only arch), "bucketed" demands it,
    "stepwise" forces the slot-granular reference path. ``prefill_batch``
    caps how many same-bucket prompts one admission cycle stacks into a
    single batched prefill forward (0 = auto, 1 = sequential).
    ``attn_impl``: "gather" (jnp reference) or "pallas" (the unified
    variable-length paged chunk kernel, decode AND prefill; paged only).

    ``metrics``: "on" records request-lifecycle spans on ``self.tracer``
    (Chrome-trace exportable) and enables the JSONL snapshot stream;
    "off" (default) installs the no-op ``NullTracer``. The
    ``MetricsRegistry`` itself is ALWAYS live — counters are pure host
    bookkeeping outside every jitted program, so tokens are identical
    either way (subprocess-asserted, like ``--kv-adapt off``).
    ``registry`` injects a shared registry; the default is per-server so
    A/B benches comparing two servers in one process never mix counters.
    """

    # Legacy counter attributes, registry-backed via telemetry.metric_attr:
    # every historical call site (`srv.prefill_forwards += 1`, test/bench
    # reads, hand-zeroing) works unchanged, but the value lives in
    # `self.metrics` — serve, tests and benches read one source of truth.
    prefill_forwards = metric_attr("serve.prefill_forwards")
    prefill_tokens = metric_attr("serve.prefill_tokens")
    prefill_s = metric_attr("serve.prefill_s")
    decode_steps = metric_attr("serve.decode_steps")
    program_launches = metric_attr("serve.program_launches")
    cycles = metric_attr("serve.cycles")
    wave_dedup_pages = metric_attr("serve.wave_dedup_pages")
    _gen_tokens = metric_attr("serve.gen_tokens")
    prefix_hit_tokens = metric_attr("serve.prefix_hit_tokens")
    prefill_forwards_saved = metric_attr("serve.prefill_forwards_saved")
    preempt_count = metric_attr("serve.preempt_count")
    resume_count = metric_attr("serve.resume_count")
    realias_skipped = metric_attr("serve.realias_skipped")

    def __init__(self, cfg, params, *, batch_size: int, max_len: int,
                 kv_bits: int = 0, page_size: int = 0,
                 num_pages: Optional[int] = None, seed: int = 0,
                 attn_impl: str = "gather", prefill: str = "auto",
                 prefill_bucket: int = 32, prefill_batch: int = 0,
                 kv_profile: Optional[PrecisionPolicy] = None,
                 kv_scale: str = "static", prefix_cache: str = "off",
                 kv_profile_scan: str = "group",
                 kv_offload: str = "none",
                 host_pages: Optional[int] = None,
                 sched: str = "fifo", admit_window: int = 4,
                 preempt: Optional[bool] = None,
                 kv_adapt: str = "off", adapt_pages: int = 0,
                 adapt_floor_bits: int = 4, fused: str = "off",
                 metrics: str = "off",
                 registry: Optional[MetricsRegistry] = None,
                 snapshot_out: Optional[str] = None,
                 snapshot_every: int = 50,
                 predictor: str = "off", pager_async: str = "off",
                 mesh=None):
        # telemetry first: counter attributes below are registry-backed
        # descriptors, so `self.metrics` must exist before any assignment
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = make_tracer(metrics)
        self._snapshotter = (MetricsSnapshotter(self.metrics, snapshot_out,
                                                every=snapshot_every)
                             if snapshot_out else None)
        self._clock = 0         # decode-step clock of the current run()
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.paged = page_size > 0
        if self.paged and cfg.attention_type == "mla":
            raise NotImplementedError("paged KV serving supports GQA archs")
        if attn_impl not in ("gather", "pallas"):
            raise ValueError(f"attn_impl must be 'gather' or 'pallas', "
                             f"got {attn_impl!r}")
        if attn_impl == "pallas" and not self.paged:
            raise ValueError("--attn-impl pallas routes the paged decode "
                             "kernel; it needs --page-size > 0")
        self.attn_impl = attn_impl
        if prefill not in ("auto", "bucketed", "stepwise"):
            raise ValueError(f"prefill must be auto|bucketed|stepwise, "
                             f"got {prefill!r}")
        attn_only = all(k == "attn" for k in cfg.layer_kinds)
        # bucketed prefill is only offered where it is output-equivalent to
        # the stepwise reference: SSM states are per-slot dense, and
        # capacity-bounded scatter MoE routes differently at chunk batch
        # shapes (capacity scales with tokens-per-forward)
        bucketed_ok = (self.paged and attn_only
                       and (cfg.num_experts == 0
                            or cfg.moe_mode == "eval_all"))
        if prefill == "bucketed" and not bucketed_ok:
            raise ValueError("bucketed prefill needs a paged cache, an "
                             "attention-only arch (SSM states are per-slot "
                             "dense), and exact MoE routing (scatter-mode "
                             "expert capacity depends on the forward's "
                             "token count); use prefill='stepwise'")
        self.prefill_mode = ("bucketed" if prefill in ("auto", "bucketed")
                             and bucketed_ok else "stepwise")
        if prefill_bucket < 1:
            raise ValueError("prefill_bucket must be >= 1")
        self.prefill_bucket = prefill_bucket
        if prefill_batch < 0:
            raise ValueError("prefill_batch must be >= 0 (0 = auto)")
        self.prefill_batch = prefill_batch
        if kv_scale not in ("static", "page"):
            raise ValueError(f"kv_scale must be 'static' or 'page', "
                             f"got {kv_scale!r}")
        if kv_scale == "page" and not (self.paged
                                       and (kv_bits or kv_profile)):
            raise ValueError("--kv-scale page calibrates per-page dequant "
                             "scales; it needs a quantized paged cache "
                             "(--page-size > 0 and --kv-bits/--kv-profile)")
        self.kv_scale = kv_scale
        if prefix_cache not in ("on", "off"):
            raise ValueError(f"prefix_cache must be 'on' or 'off', "
                             f"got {prefix_cache!r}")
        if prefix_cache == "on" and not self.paged:
            raise ValueError("--prefix-cache on shares pool pages; it needs "
                             "--page-size > 0")
        if prefix_cache == "on" and not attn_only:
            raise ValueError("prefix sharing needs an attention-only arch: "
                             "an SSM state folds the whole prefix, so "
                             "cached KV pages cannot stand in for skipped "
                             "prefill forwards")
        if kv_offload not in ("none", "host"):
            raise ValueError(f"kv_offload must be 'none' or 'host', "
                             f"got {kv_offload!r}")
        if kv_offload == "host" and not self.paged:
            raise ValueError("--kv-offload host demotes pool pages; it "
                             "needs --page-size > 0")
        if kv_adapt not in ("off", "on"):
            raise ValueError(f"kv_adapt must be 'off' or 'on', "
                             f"got {kv_adapt!r}")
        if kv_adapt == "on" and not (self.paged and prefix_cache == "on"):
            raise ValueError("--kv-adapt on requantizes cold CACHED prefix "
                             "pages under pool pressure; it needs "
                             "--page-size > 0 and --prefix-cache on")
        if adapt_floor_bits not in (4, 8):
            raise ValueError(f"adapt_floor_bits must be 4 or 8, "
                             f"got {adapt_floor_bits}")
        if sched not in ("fifo", "slo"):
            raise ValueError(f"sched must be 'fifo' or 'slo', got {sched!r}")
        if sched == "slo" and not self.paged:
            raise ValueError("--sched slo schedules page-pool admission; "
                             "it needs --page-size > 0")
        if preempt is None:
            preempt = sched == "slo" and kv_offload == "host"
        if preempt and kv_offload != "host":
            raise ValueError("preemption parks victim pages in the host "
                             "tier; it needs --kv-offload host")
        if preempt and sched != "slo":
            raise ValueError("preemption is driven by the SLO scheduler; "
                             "it needs --sched slo")
        self.sched = sched
        self.scheduler = (SLOScheduler(SchedPolicy(admit_window=admit_window,
                                                   preempt=preempt),
                                       metrics=self.metrics)
                          if sched == "slo" else None)
        if predictor not in ("off", "on"):
            raise ValueError(f"predictor must be 'off' or 'on', "
                             f"got {predictor!r}")
        if predictor == "on" and sched != "slo":
            raise ValueError("--predictor on gates speculative admissions "
                             "inside the SLO admission loop; it needs "
                             "--sched slo")
        if pager_async not in ("off", "on"):
            raise ValueError(f"pager_async must be 'off' or 'on', "
                             f"got {pager_async!r}")
        if pager_async == "on" and kv_offload != "host":
            raise ValueError("--pager-async on overlaps host-tier page "
                             "transfers with decode; it needs "
                             "--kv-offload host")
        # rolling-window SLO reductions are always live (pure host-side
        # bookkeeping, like the registry itself); the predictor that ACTS
        # on them is strictly opt-in so default serving stays bitwise
        # identical
        self.slo_monitor = SLOMonitor(self.metrics)
        self.predictor = (DeadlineMissPredictor(metrics=self.metrics)
                          if predictor == "on" else None)
        self._risk_feat_last: Optional[list] = None
        if kv_profile_scan not in ("group", "unroll"):
            raise ValueError(f"kv_profile_scan must be 'group' or 'unroll', "
                             f"got {kv_profile_scan!r}")
        self.quant = None
        if kv_profile is not None:
            if kv_bits:
                raise ValueError("--kv-profile supersedes --kv-bits; "
                                 "pass only one")
            if not (self.paged and attn_only):
                raise ValueError("--kv-profile (per-layer KV containers) "
                                 "needs a paged cache and an attention-only "
                                 "arch")
            # serving quantizes the CACHE only: data formats drive the KV
            # containers, weight formats (if the policy has them, e.g. from
            # core.search output) are dropped
            kv_profile = PrecisionPolicy(
                kv_profile.names,
                tuple(LayerPolicy(None, lp.data) for lp in kv_profile.layers))
            self.quant = build_model_quant(kv_profile, cfg, quantize_kv=True,
                                           quantize_activations=False,
                                           per_layer_kv=True,
                                           kv_scale_mode=kv_scale,
                                           kv_unroll=(kv_profile_scan
                                                      == "unroll"))
        elif kv_bits:
            container = "int4" if (self.paged and kv_bits <= 4) else "int8"
            names = transformer_layer_names(cfg)
            pol = PrecisionPolicy.uniform(
                names, None, FixedPointFormat(2, kv_bits - 2))
            self.quant = build_model_quant(pol, cfg, quantize_kv=True,
                                           quantize_activations=False,
                                           kv_container=container,
                                           kv_scale_mode=kv_scale)
        # pages may only be shared between identically-quantized configs:
        # the prefix cache namespaces its trie by this key
        self.profile_key = kv_profile_key(kv_profile, kv_bits=kv_bits,
                                          kv_scale_mode=kv_scale)
        self.decode = jax.jit(make_decode_step(cfg, quant=self.quant,
                                               attn_impl=attn_impl))
        self._chunk_prefill = (
            jax.jit(make_chunk_prefill_step(cfg, quant=self.quant,
                                            attn_impl=attn_impl))
            if self.prefill_mode == "bucketed" else None)
        if fused not in ("on", "off"):
            raise ValueError(f"fused must be 'on' or 'off', got {fused!r}")
        if fused == "on" and self.prefill_mode != "bucketed":
            raise ValueError("--fused on runs ONE ragged prefill+decode "
                             "program per scheduler cycle; it needs the "
                             "bucketed prefill path (paged cache, "
                             "attention-only arch, exact MoE routing)")
        self.fused = fused == "on"
        self._fused = (jax.jit(make_fused_step(cfg, quant=self.quant,
                                               attn_impl=attn_impl))
                       if self.fused else None)
        if self.fused:
            # steady-state span constants: every row decodes (valid_len 1)
            # and every row emits — reused across steps so the only retrace
            # axis anywhere in fused serving is the prefill bucket
            self._ones_dev = jnp.ones((batch_size,), jnp.int32)
            self._arange_dev = jnp.arange(batch_size, dtype=jnp.int32)

        paged_spec = None
        self.prefix_cache: Optional[PrefixCache] = None
        self.host_store: Optional[HostPageStore] = None
        self.pager: Optional[TieredPager] = None
        if self.paged:
            self.np_max = max_pages_per_seq(max_len, page_size)
            if num_pages is None:
                num_pages = 1 + batch_size * self.np_max  # full capacity
            paged_spec = PagedCacheSpec(page_size=page_size,
                                        num_pages=num_pages)
            self.allocator = PageAllocator(num_pages, metrics=self.metrics)
            self.page_size = page_size
            self.page_table = np.full((batch_size, self.np_max),
                                      SCRATCH_PAGE, np.int32)
            self.slot_pages: List[List[int]] = [[] for _ in range(batch_size)]
            self.slot_reserved = [0] * batch_size  # worst-case page demand
            self._pt_dev = _upload(self.page_table)
            self._pt_dirty = False
            if kv_offload == "host":
                self.host_store = HostPageStore(max_pages=host_pages,
                                                metrics=self.metrics)
                self.pager = TieredPager(
                    self.allocator, self.host_store,
                    lambda: self.caches,
                    lambda c: setattr(self, "caches", c),
                    metrics=self.metrics,
                    async_mode=(pager_async == "on"),
                    tracer=self.tracer)
                self.allocator.host_inventory = \
                    lambda: self.host_store.num_pages
            if prefix_cache == "on":
                self.prefix_cache = PrefixCache(self.allocator, page_size,
                                                self.profile_key,
                                                pager=self.pager,
                                                metrics=self.metrics,
                                                tracer=self.tracer)
                # pool pressure demotes (host tier) or evicts cold cached
                # prefixes before failing the allocation
                self.allocator.reclaim = self.prefix_cache.evict
        self.caches = init_cache(cfg, batch_size, max_len, self.quant,
                                 paged=paged_spec)
        # --- tensor-parallel placement (ROADMAP item 1) --------------------
        # mesh= shards ONE replica across devices: weights TP-only over
        # "model" (plan_for_mesh + inference=True strips the FSDP axis) and
        # the paged KV pool over the attention KV-head axis
        # (parallel.sharding.paged_pool_shardings — per-page scales
        # replicate, int4 lane-packing is along head_dim so head shards
        # stay whole). GSPMD propagates the layout through the existing
        # jitted decode/prefill programs unchanged; host-side page ops
        # (extract/inject, np.asarray reads) force gathers and stay exact.
        self.mesh = mesh
        self.mesh_plan = None
        if mesh is not None:
            if not self.paged:
                raise ValueError("mesh-sharded serving shards the paged KV "
                                 "pool; it needs --page-size > 0")
            self.mesh_plan = plan_for_mesh(mesh)
            self.params = params = jax.device_put(
                params, param_shardings(params, self.mesh_plan,
                                        inference=True))
            self.caches = jax.device_put(
                self.caches,
                paged_pool_shardings(self.caches, self.mesh_plan))
        # online precision adaptation (--kv-adapt): a bounded device-byte
        # tier that REQUANTIZES cold cached prefix pages one container step
        # narrower (fp -> int8 -> int4) before any host round trip; built
        # after the caches because it probes the pool geometry for its
        # per-page byte quotes
        self.quant_tier: Optional[QuantTierStore] = None
        if kv_adapt == "on":
            self.quant_tier = QuantTierStore(
                lambda: self.caches,
                lambda c: setattr(self, "caches", c),
                pages=adapt_pages or self.allocator.num_usable,
                floor_bits=adapt_floor_bits, metrics=self.metrics)
            self.prefix_cache.tier = self.quant_tier
            # admission preflight / OutOfPagesError inventory hook
            self.allocator.requant_inventory = \
                self.prefix_cache.requantizable_pages
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.pos = np.zeros((batch_size,), np.int32)    # host-side lengths
        self.tokens = np.zeros((batch_size,), np.int32)  # host-side tokens
        self.slot_gen = [0] * batch_size                 # generated counts
        # hot-path instrumentation: registry-backed class descriptors (see
        # above); zeroing them here just initializes the "serve.*" counters
        self.prefill_forwards = 0   # forward-program executions in prefill
        self.prefill_tokens = 0     # prompt tokens consumed by prefill
        self.prefill_s = 0.0
        self.decode_steps = 0
        self.program_launches = 0   # every jitted forward executed
        self.cycles = 0             # scheduler cycles (fused rounds + span
        #                             steps); fused == program_launches
        self.wave_dedup_pages = 0   # pages aliased off a same-wave leader
        self._gen_tokens = 0        # generated tokens (all run() calls)
        self.prefix_hit_tokens = 0        # prompt tokens served from cache
        self.prefill_forwards_saved = 0   # forwards prefix hits avoided
        self.preempt_count = 0            # victim slots demoted + re-queued
        self.resume_count = 0             # preempted requests resumed
        self.realias_skipped = 0          # preempt host-copies skipped by
        #                                   re-aliasing resident cache nodes
        self.rejected: List[Request] = []  # never-fit requests (error set)
        # one shared KV-inventory gauge schema (``kv_inventory`` and the
        # snapshot stream read the SAME callbacks; satellite of ISSUE 8)
        if self.paged:
            reg = self.metrics.register_gauge
            reg("kv.device_bytes",
                lambda: sum(caches_kv_bytes(self.caches).values()))
            reg("kv.device_pages_free", lambda: self.allocator.num_free)
            reg("kv.device_pages_usable", lambda: self.allocator.num_usable)
            reg("kv.host_bytes",
                lambda: self.host_store.nbytes if self.host_store else 0)
            reg("kv.host_pages",
                lambda: self.host_store.num_pages if self.host_store else 0)
            reg("kv.tier_bytes",
                lambda: self.quant_tier.nbytes if self.quant_tier else 0)
            reg("kv.tier_pages",
                lambda: self.quant_tier.num_pages if self.quant_tier else 0)

    # -- page bookkeeping ---------------------------------------------------
    def _ensure_page(self, slot: int, position: int):
        """Allocate pages so logical ``position`` of ``slot`` is backed."""
        blk = position // self.page_size
        if self.kv_scale == "page" and blk < len(self.slot_pages[slot]):
            # SHARING CONTRACT (core.paged_kv._paged_update_page_scale): a
            # per-page scale raise rewrites the whole page's grid in place,
            # so a write target must be exclusively owned. _cache_insert
            # never shares a page the owner keeps writing (page mode skips
            # the partial tail), so any violation here is a refcount bug.
            assert self.allocator.refcount(self.slot_pages[slot][blk]) == 1, \
                "page-scale write into a CoW-shared page"
        while len(self.slot_pages[slot]) <= blk:
            page = self.allocator.alloc()
            self.page_table[slot, len(self.slot_pages[slot])] = page
            self.slot_pages[slot].append(page)
            self._pt_dirty = True

    def _release_slot(self, slot: int):
        if self.paged:
            if self.slot_pages[slot]:
                self.allocator.free(self.slot_pages[slot])
                self.slot_pages[slot] = []
                self.page_table[slot, :] = SCRATCH_PAGE
                self._pt_dirty = True
            self.slot_reserved[slot] = 0
        self.pos[slot] = 0
        self.slot_gen[slot] = 0

    def _page_table_dev(self):
        if self._pt_dirty:
            self._pt_dev = _upload(self.page_table)
            self._pt_dirty = False
        return self._pt_dev

    def _pages_needed(self, req: Request) -> int:
        """Worst-case pages ``req`` can ever occupy: prompt + generation,
        clipped by the max_len-1 position ceiling of the decode loop. The
        loop always generates at least one token (``_run_span`` >= 1), so
        max_new counts as >= 1 here or the preflight would under-reserve."""
        tokens = min(len(req.prompt) - 1 + max(req.max_new, 1),
                     self.max_len - 1)
        return -(-max(tokens, 1) // self.page_size)

    def _outstanding_reservation(self) -> int:
        """Pages promised to live requests but not yet allocated."""
        return sum(max(0, self.slot_reserved[i] - len(self.slot_pages[i]))
                   for i in range(self.B) if self.slots[i] is not None)

    # -- prefill ------------------------------------------------------------
    def _sync_step(self):
        """One whole-batch decode step driven from the host-side state
        (the slot-granular prefill path; output tokens are discarded)."""
        pt = self._page_table_dev() if self.paged else None
        _, _, self.caches = self.decode(
            self.params, _upload(self.tokens), _upload(self.pos),
            self.caches, pt)
        self.prefill_forwards += 1
        self.program_launches += 1

    def _prefill_stepwise(self, slot: int, req: Request, start: int = 0):
        """Feed prompt[start:-1] through shared decode steps, leaving the
        last prompt token in ``tokens`` for the run loop to consume
        (``start`` > 0 = prefix-cache hit: positions [0, start) are already
        backed by shared/copied pages). Other slots do not advance: they
        rewrite their current position with identical values. This is the
        bitwise-reference prefill (one compiled program, O(prompt_len)
        whole-batch forwards)."""
        self.pos[slot] = start
        for t in req.prompt[start:-1]:
            if self.paged:
                self._ensure_page(slot, int(self.pos[slot]))
            self.tokens[slot] = int(t)
            self._sync_step()
            self.pos[slot] += 1
        self.tokens[slot] = int(req.prompt[-1])

    def _n_chunks(self, n: int) -> int:
        """Bucketed-prefill forwards needed for ``n`` prompt tokens."""
        c, done = 0, 0
        while done < n:
            done += min(_pow2_bucket(n - done, self.prefill_bucket), n - done)
            c += 1
        return c

    def _prefill_slot(self, slot: int, req: Request, start: int = 0):
        """Slot-granular reference prefill (stepwise mode / dense caches);
        bucketed admissions go through ``_run_prefills`` instead."""
        t0 = time.perf_counter()
        self._prefill_stepwise(slot, req, start)
        self.prefill_forwards_saved += start
        self.prefill_s += time.perf_counter() - t0
        self.prefill_tokens += len(req.prompt)
        self.slot_gen[slot] = 0

    # -- batched bucketed prefill -------------------------------------------
    def _prefill_group_cap(self) -> int:
        """Max prompt rows stacked into one batched prefill forward.
        ``prefill_batch=0`` is auto: the batch size. Intra-wave prefix
        sharing no longer forces sequential admission — same-wave prompts
        sharing page-aligned chunks are deduped inside the batched wave by
        ``_plan_wave_dedupe`` (followers alias the leader's fresh pages),
        so --prefill-batch composes with --prefix-cache."""
        if self.prefill_batch:
            return self.prefill_batch
        return self.B

    def _plan_wave_dedupe(self, pending: List[_PrefillJob]) -> None:
        """Prefix-AWARE batched prefill: pair each wave job with the
        earlier same-wave job (leader) whose prompt shares the most
        page-aligned full chunks beyond the follower's own prefix-cache
        hit. The follower sits out rounds (``wait_for``) until the leader
        has written through the shared span, then ``_apply_wave_aliases``
        increfs the leader's pages into its table — the forwards for those
        chunks run ONCE per wave instead of once per request.

        A follower must start page-aligned (a CoW divergence means its own
        content already differs mid-page) with a leader starting at or
        below it (so the leader's slot holds every needed page), and only
        FULLY-written pages are ever aliased — the leader's later writes
        (including page-scale rescales, which touch only blocks of the
        chunk being written) never revisit them, honoring the page-scale
        sharing contract."""
        ps = self.page_size
        leaders: List[_PrefillJob] = []
        for job in pending:
            best, best_shared = None, job.start
            if job.done == 0 and job.start % ps == 0:
                for lead in leaders:
                    if lead.start > job.start:
                        continue
                    shared = _shared_page_tokens(lead.req.prompt,
                                                 job.req.prompt, ps)
                    if shared > best_shared:
                        best, best_shared = lead, shared
            if best is not None:
                job.wait_for = (best, best_shared)
            else:
                leaders.append(job)

    def _apply_wave_aliases(self, pending: List[_PrefillJob]) -> None:
        """Unblock wave-dedupe followers whose leader has written through
        the shared span: alias the leader's pages for [start, shared) into
        the follower's table (one incref per page, no forwards) and jump
        the follower's start to ``shared``. Runs at every round boundary —
        a leader that just finished its prefill unblocks its followers
        BEFORE it can retire from decode, so the increfs always land on
        live pages."""
        for job in pending:
            if job.wait_for is None or job.finished:
                continue
            lead, shared = job.wait_for
            if lead.start + lead.done < shared:
                continue
            ps = self.page_size
            b0, b1 = job.start // ps, shared // ps
            assert len(self.slot_pages[job.slot]) == b0, \
                "wave-dedupe follower holds pages past its start"
            chunks_before = self._n_chunks(job.total)
            for b in range(b0, b1):
                page = self.slot_pages[lead.slot][b]
                self.allocator.incref(page)   # the follower's reference
                self.page_table[job.slot, b] = page
                self.slot_pages[job.slot].append(page)
            self._pt_dirty = True
            job.start = shared
            job.wait_for = None
            self.pos[job.slot] = shared
            self.wave_dedup_pages += b1 - b0
            self.prefill_forwards_saved += (chunks_before
                                            - self._n_chunks(job.total))
            if job.total == 0:
                self._finish_job(job)

    def _prefill_group(self, rows: List[_PrefillJob], bucket: int):
        """ONE batched prefill forward: each row's next ``bucket``-sized
        chunk, stacked into a [n_rows, bucket] program with per-row page
        tables, start positions, and valid lengths. Rows are independent
        sequences writing disjoint pages, so stacking is bitwise-neutral
        per row (asserted in tests/test_serve_fast.py)."""
        n = len(rows)
        chunk = np.zeros((n, bucket), np.int32)
        starts = np.zeros((n,), np.int32)
        valids = np.zeros((n,), np.int32)
        pts = np.empty((n, self.np_max), np.int32)
        for r, job in enumerate(rows):
            off = job.start + job.done
            toks = job.req.prompt[off:len(job.req.prompt) - 1]
            valid = min(bucket, len(toks))
            self._ensure_page(job.slot, off + valid - 1)
            chunk[r, :valid] = toks[:valid]
            starts[r], valids[r] = off, valid
            pts[r] = self.page_table[job.slot]
        # chunk/starts/valids/pts are private copies nobody mutates later,
        # so plain asarray uploads are race-free (cf. _upload)
        with self.tracer.span("prefill_chunk",
                              args={"rows": n, "bucket": bucket,
                                    "step": self._clock}):
            self.caches = self._chunk_prefill(
                self.params, jnp.asarray(chunk), jnp.asarray(starts),
                jnp.asarray(valids), self.caches, jnp.asarray(pts))
        self.prefill_forwards += 1
        self.program_launches += 1
        for r, job in enumerate(rows):
            job.done += int(valids[r])
            self.pos[job.slot] = job.start + job.done

    def _finish_job(self, job: _PrefillJob):
        """Seal a prefilled slot: clock at the last prompt token (which the
        decode loop consumes) and the fresh pages indexed into the prefix
        cache."""
        self.pos[job.slot] = len(job.req.prompt) - 1
        self.tokens[job.slot] = int(job.req.prompt[-1])
        if self.prefix_cache is not None:
            self._cache_insert(job.slot, job.req)
        job.finished = True

    def _rollback_admission(self, job: _PrefillJob, err) -> None:
        """Undo one partially executed admission after a failed batch:
        release every page the row holds (aliased prefix pages just drop
        the slot's reference), clear the reservation, and vacate the slot —
        so an OutOfPagesError mid-batch leaves the accounting exactly as if
        the row was never admitted."""
        i = job.slot
        self.slots[i] = None
        if self.slot_pages[i]:
            self.allocator.free(self.slot_pages[i])
            self.slot_pages[i] = []
        self.page_table[i, :] = SCRATCH_PAGE
        self._pt_dirty = True
        self.slot_reserved[i] = 0
        self.pos[i] = 0
        self.tokens[i] = 0
        self.slot_gen[i] = 0
        self._discard_paused(job.req)
        job.req.error = err

    def _run_prefills(self, jobs: List[_PrefillJob]):
        """Execute one admission cycle's bucketed prefills, stacking
        same-bucket rows of different requests into single [n, bucket]
        forwards (capped at ``_prefill_group_cap`` rows): the scheduler's
        admit window surfaces several admissible prompts per cycle, and
        stacking amortizes both the forward count and the per-bucket
        compilations across them. Round-robin: every round, each unfinished
        row contributes its next power-of-two chunk; rows sharing a bucket
        share a forward. An ``OutOfPagesError`` mid-batch (the preflight
        makes this unreachable; defense against accounting bugs) rolls back
        every not-yet-finished row before re-raising."""
        t0 = time.perf_counter()
        cap = self._prefill_group_cap()
        try:
            pending = []
            for job in jobs:
                self.prefill_tokens += len(job.req.prompt)
                self.prefill_forwards_saved += (
                    self._n_chunks(len(job.req.prompt) - 1)
                    - self._n_chunks(job.total))
                if job.total == 0:
                    self._finish_job(job)   # full-chain hit / 1-token prompt
                else:
                    pending.append(job)
            if self.prefix_cache is not None and cap > 1:
                # intra-wave sharing: followers alias a leader's fresh
                # pages instead of forcing sequential admission
                self._plan_wave_dedupe(pending)
            while pending:
                groups = {}
                for job in pending:
                    if job.wait_for is not None:
                        continue        # follower: leader still writing
                    b = _pow2_bucket(job.total - job.done,
                                     self.prefill_bucket)
                    groups.setdefault(b, []).append(job)
                for bucket in sorted(groups):
                    grp = groups[bucket]
                    for k in range(0, len(grp), cap):
                        self._prefill_group(grp[k:k + cap], bucket)
                self._apply_wave_aliases(pending)
                nxt = []
                for job in pending:
                    if job.finished:
                        continue        # alias jump covered the whole job
                    if job.wait_for is None and job.done >= job.total:
                        self._finish_job(job)
                    else:
                        nxt.append(job)
                pending = nxt
        except OutOfPagesError as err:
            for job in jobs:
                if not job.finished:
                    self._rollback_admission(job, err)
            raise
        finally:
            self.prefill_s += time.perf_counter() - t0

    # -- fused ragged cycles (--fused on) -----------------------------------
    def _fused_round(self, pending: List[_PrefillJob]) -> List[_PrefillJob]:
        """ONE ragged [B, bucket] program: every unfinished prefill job
        contributes its next prompt chunk (padded to the round's shared
        bucket, tail masked via valid_len) and every OTHER live slot
        decodes one token in the same launch — prefill piggybacks on the
        decode cycle instead of dispatching its own programs. Returns the
        jobs still pending after the round."""
        ready = [j for j in pending if j.wait_for is None]
        bucket = max(_pow2_bucket(j.total - j.done, self.prefill_bucket)
                     for j in ready)
        prefilling = {j.slot for j in pending}
        decode = [i for i in range(self.B) if self.slots[i] is not None
                  and i not in prefilling]
        tokens = np.zeros((self.B, bucket), np.int32)
        starts = np.zeros((self.B,), np.int32)
        valids = np.ones((self.B,), np.int32)
        emit = np.zeros((self.B,), np.int32)   # fixed shape; host
        #                                        discards padding entries
        for j in ready:
            off = j.start + j.done
            toks = j.req.prompt[off:len(j.req.prompt) - 1]
            valid = min(bucket, len(toks))
            self._ensure_page(j.slot, off + valid - 1)
            tokens[j.slot, :valid] = toks[:valid]
            starts[j.slot] = off
            valids[j.slot] = valid
        for k, i in enumerate(decode):
            self._ensure_page(i, int(self.pos[i]))
            tokens[i, 0] = self.tokens[i]
            starts[i] = self.pos[i]
            emit[k] = i
        pt = self._page_table_dev()
        # private host copies nobody mutates later: plain asarray uploads
        with self.tracer.span("fused_round",
                              args={"bucket": bucket,
                                    "prefill_rows": len(ready),
                                    "decode_rows": len(decode),
                                    "step": self._clock}):
            nxt, _, self.caches = self._fused(
                self.params, jnp.asarray(tokens), jnp.asarray(starts),
                jnp.asarray(valids), self.caches, pt, jnp.asarray(emit))
        self.program_launches += 1
        self.cycles += 1
        self.prefill_forwards += 1
        for j in ready:
            j.done += int(valids[j.slot])
            self.pos[j.slot] = j.start + j.done
        still = []
        for j in pending:
            if j.wait_for is None and j.done >= j.total:
                self._finish_job(j)     # decode-eligible next round
            else:
                still.append(j)
        # unblock followers BEFORE retirement can free a leader's pages
        self._apply_wave_aliases(still)
        still = [j for j in still if not j.finished]
        if decode:
            arr = np.asarray(nxt)
            self.decode_steps += 1
            self._gen_tokens += len(decode)
            for k, i in enumerate(decode):
                tok = int(arr[k])
                req = self.slots[i]
                if not req.out:
                    self.tracer.req_first_token(req.rid)
                    self.slo_monitor.note_first_token(req.rid)
                req.out.append(tok)
                self.tokens[i] = tok
                self.pos[i] += 1
                self.slot_gen[i] += 1
                if (self.slot_gen[i] >= req.max_new
                        or self.pos[i] >= self.max_len - 1):
                    req.done = True
                    self.slots[i] = None
                    self._release_slot(i)
                    # fused admission rounds decode without advancing the
                    # run clock; the cycle's clock is the finish step
                    self._note_finish(req, self._clock)
        return still

    def _run_fused_rounds(self, jobs: List[_PrefillJob]):
        """Fused-mode admission: same accounting/rollback contract as
        ``_run_prefills``, but every round is one ragged fused program that
        also advances all non-prefilling decode slots — so admitting new
        prompts costs ZERO extra program launches per cycle. Per-request
        token streams are unchanged vs the separate-program path (each
        row's math depends only on its own cache/position; the subprocess
        identity test asserts bitwise equality)."""
        t0 = time.perf_counter()
        try:
            pending = []
            for job in jobs:
                self.prefill_tokens += len(job.req.prompt)
                self.prefill_forwards_saved += (
                    self._n_chunks(len(job.req.prompt) - 1)
                    - self._n_chunks(job.total))
                if job.total == 0:
                    self._finish_job(job)
                else:
                    pending.append(job)
            if self.prefix_cache is not None:
                self._plan_wave_dedupe(pending)
            while pending:
                pending = self._fused_round(pending)
        except OutOfPagesError as err:
            for job in jobs:
                if not job.finished:
                    self._rollback_admission(job, err)
            raise
        finally:
            self.prefill_s += time.perf_counter() - t0

    # -- prefix sharing -----------------------------------------------------
    def _copy_pool_pages(self, src: int, dst: int):
        """Copy page ``src`` -> ``dst`` in EVERY attention layer's pool
        (copy-on-write: one host-side allocator, one page-id space, all
        layers alias the same table)."""
        self.caches = map_kv_pools(
            self.caches,
            lambda pool, axis: copy_pool_pages(pool, src, dst,
                                               page_axis=axis))

    def _cache_insert(self, slot: int, req: Request):
        """Index the request's freshly prefilled prompt pages (tokens
        [0, P-1)) into the prefix cache; chunks already cached dedupe.

        In --kv-scale page mode the PARTIAL tail page is not inserted: the
        owner slot keeps decoding into it, and a per-page scale raise
        rewrites the page's grid in place — sharing it would silently
        change dequant values under aliased readers (the page-scale
        sharing contract; see core.paged_kv._paged_update_page_scale).
        Static-grid mode shares the tail safely (writes touch only
        offsets past every sharer's valid length)."""
        n_tok = len(req.prompt) - 1
        if self.kv_scale == "page":
            n_tok = (n_tok // self.page_size) * self.page_size
        if n_tok <= 0:
            return
        n_pages = -(-n_tok // self.page_size)
        self.prefix_cache.insert(req.prompt[:n_tok],
                                 self.slot_pages[slot][:n_pages])

    # -- admission ----------------------------------------------------------
    def _admission_plan(self, req: Request):
        """Preflight one request against the pool. Returns
        ``(verdict, info)`` with verdict in {"admit", "defer", "reject"}.

        Paged admission preflights the request's WORST-CASE page demand
        (prompt + max_new, minus fully-matched RESIDENT prefix pages, plus
        one promotion page per matched HOST page) against the free list
        less outstanding reservations, counting reclaimable cached pages —
        so ``_ensure_page`` can never hit an empty free list mid-run.

        On "admit" the hit's chain is PINNED in the trie (``info["hit"]``);
        the caller must either complete the admission (``_do_admit``
        unpins) or unpin itself. "defer" means the request must wait for
        live requests' pages; "reject" means it can NEVER fit (its error
        carries the full device/host/evictable inventory).

        Malformed requests raise here, BEFORE any pin/reservation is
        taken, so the error cannot leak cache state."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid} has an empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"request {req.rid} prompt length "
                             f"{len(req.prompt)} >= max_len {self.max_len}")
        if not self.paged:
            return "admit", {"hit": None, "total": 0}
        total = self._pages_needed(req)
        hit = None
        if req._paused is not None:
            # resume allocates only the host-demoted pages; re-aliased
            # (pinned) cache nodes cost nothing
            need_new = total - sum(1 for kind, _ in req._paused.entries
                                   if kind == "alias")
        elif self.prefix_cache is not None:
            # record=False: a deferred request retries this lookup every
            # span; hit-rate stats count once, on admission
            hit = self.prefix_cache.lookup(req.prompt[:-1], record=False)
            # pin the chain so preflight/admission eviction can't touch it
            self.prefix_cache.pin(hit)
            need_new = (total - len(hit.nodes)
                        + self.prefix_cache.host_nodes_in(hit))
        else:
            need_new = total
        avail = self.allocator.num_free - self._outstanding_reservation()
        evictable = 0
        if need_new > avail and self.prefix_cache is not None:
            # only walk the trie when the free list alone won't do
            evictable = self.prefix_cache.evictable_pages()
            avail += evictable
        if need_new <= avail:
            return "admit", {"hit": hit, "total": total,
                             "need_new": need_new}
        if hit is not None:
            self.prefix_cache.unpin(hit)
        if (need_new > self.allocator.num_usable
                or not any(s is not None for s in self.slots)):
            written = len(set().union(*map(set, self.slot_pages)))
            err = OutOfPagesError(
                needed=need_new, free=self.allocator.num_free,
                total=self.allocator.num_usable, rid=req.rid,
                reserved=self._outstanding_reservation(),
                written=written, evictable=evictable,
                requantizable=self.allocator.requant_pages(),
                host_pages=self.allocator.host_pages())
            return "reject", {"err": err}
        return "defer", {"total": total, "need_new": need_new,
                         "shortfall": need_new - avail}

    def _do_admit(self, i: int, req: Request, info: dict,
                  jobs: List[_PrefillJob]):
        """Execute a planned admission into free slot ``i``: alias/promote
        the pinned prefix chain, CoW-copy a mid-page divergence, and stage
        the non-shared suffix's prefill (or promote+resume a preempted
        request). Bucketed-mode prefills are only PLANNED here (appended to
        ``jobs``); the admission cycle runs them batched at the end
        (``_run_prefills``), so several same-cycle admissions share
        forwards. The slot is claimed immediately — reservation accounting
        for the rest of the cycle sees it. (Prompt validation happened in
        ``_admission_plan``, before the hit chain was pinned.)"""
        if (self.predictor is not None and req.deadline_step is not None
                and req._risk_feat is None):
            # pair this cycle's consulted features with the request: its
            # met/missed outcome at retirement is the training label
            req._risk_feat = self._risk_feat_last
        if not self.paged:
            self.tracer.req_admit(req.rid, self._clock)
            self._prefill_slot(i, req, 0)
            self.slots[i] = req
            return
        if req._paused is not None:
            self._resume_slot(i, req, info["total"])
            return
        self.tracer.req_admit(req.rid, self._clock)
        hit = info["hit"]
        self.slot_reserved[i] = info["total"]
        start = 0
        if hit is not None:
            for j, node in enumerate(hit.nodes):
                # host-state nodes promote back to device pages first
                page = self.prefix_cache.ensure_resident(node)
                self.allocator.incref(page)   # the slot's alias reference
                self.page_table[i, j] = page
                self.slot_pages[i].append(page)
                self._pt_dirty = True
            start = len(hit.nodes) * self.page_size
            if hit.cow_node is not None and hit.cow_valid > 0:
                # divergence inside a partially shared page: private copy
                src = self.prefix_cache.ensure_resident(hit.cow_node)
                dst = self.allocator.alloc()   # reclaim hook may evict
                self.page_table[i, len(hit.nodes)] = dst
                self.slot_pages[i].append(dst)
                self._pt_dirty = True
                self._copy_pool_pages(int(src), int(dst))
                self.prefix_cache.cow_copies += 1
                start += hit.cow_valid
            self.prefix_cache.unpin(hit)
            self.prefix_cache.note_lookup(len(req.prompt) - 1, start)
            self.prefix_hit_tokens += start
        self.slots[i] = req
        self.pos[i] = start
        self.slot_gen[i] = 0
        if self.prefill_mode == "bucketed":
            job = _PrefillJob(i, req, start)
            if self.fused or self._prefill_group_cap() > 1:
                jobs.append(job)     # cycle runs these batched at the end
            else:
                # sequential discipline (explicit --prefill-batch 1):
                # prefill AND cache-insert complete before the next
                # admission plans, so a same-wave prompt can still alias
                # this request's fresh pages through the trie
                self._run_prefills([job])
        else:
            self._prefill_slot(i, req, start)
            if self.prefix_cache is not None:
                self._cache_insert(i, req)

    def _discard_paused(self, req: Request) -> None:
        """Release a preempted request's parked resources once it will
        NEVER resume (admission reject / rollback): unpin every re-aliased
        prefix node and drop every host-tier page its resume state holds.
        Without this, rejecting a preempted request leaks PINNED trie
        nodes — they survive ``clear()``, so the leak gate reports phantom
        retained pages — and orphaned host blobs that count against
        --host-pages forever."""
        st = req._paused
        if st is None:
            return
        for kind, val in st.entries:
            if kind == "alias":
                self.prefix_cache.unpin_node(val)
            else:
                self.host_store.drop(val)
        req._paused = None

    def _reject(self, queue: List[Request], idx: int, err) -> None:
        """Drop a never-fit request from the queue WITHOUT killing the run
        (the legacy behavior stalled everything behind a too-large head):
        the error is recorded on the request; FIFO mode re-raises it after
        the serviceable traffic drained. A preempted request rejected
        before resume releases its parked pages/pins first."""
        req = queue.pop(idx)
        self._discard_paused(req)
        req.error = err
        req.done = True
        self.rejected.append(req)
        self.metrics.counter("sched.rejects").inc()
        self.slo_monitor.note_finish(req.rid, False, 0)
        self.tracer.req_reject(req.rid, self._clock,
                               reason=type(err).__name__)

    def _admit_fifo(self, queue: List[Request], jobs: List[_PrefillJob]):
        """Legacy FIFO admission: strict queue order, but a permanently
        -too-large head is SKIPPED (recorded + surfaced at end of run)
        instead of stalling the queue forever behind it."""
        for i in range(self.B):
            if self.slots[i] is not None:
                continue
            while queue:
                verdict, info = self._admission_plan(queue[0])
                if verdict == "reject":
                    self._reject(queue, 0, info["err"])
                    continue              # next head, same free slot
                if verdict == "defer":
                    self.metrics.counter("sched.defers").inc()
                    self.tracer.req_defer(queue[0].rid, self._clock)
                    return                # wait for live requests' pages
                self._do_admit(i, queue.pop(0), info, jobs)
                break

    def _admit_slo(self, queue: List[Request], jobs: List[_PrefillJob],
                   spec_budget: Optional[int] = None):
        """Priority/EDF admission with bounded out-of-order admission past
        a deferred head, and preemption of strictly less urgent running
        requests when a candidate's page shortfall can be met by demoting
        a victim to the host tier. ``spec_budget`` (predictor on) caps NEW
        speculative admissions this cycle — no-deadline, non-resumed
        requests past the budget are passed over (they stay queued and are
        re-examined next cycle); deadlined and preempted-resume requests
        are never gated."""
        pol = self.scheduler.policy
        self.scheduler.sort_queue(queue)
        preempts_left = pol.max_preempt_per_admit
        deferred = False
        examined = 0          # requests examined past the deferred head
        idx = 0
        while idx < len(queue):
            if deferred:
                examined += 1
                if examined > pol.admit_window:
                    break
            req = queue[idx]
            speculative = (req.deadline_step is None
                           and req._paused is None)
            if speculative and spec_budget is not None and spec_budget <= 0:
                # gate BEFORE planning: an admit plan pins the prefix hit
                # chain, so skipping after planning would leak pins
                self.predictor.gated += 1
                self.tracer.req_defer(req.rid, self._clock)
                idx += 1
                continue
            free = [i for i in range(self.B) if self.slots[i] is None]
            if not free:
                # batch full: the most urgent queued request may claim a
                # slot by preempting a strictly less urgent running one
                n = self._preempt_for(req, queue, 0, preempts_left)
                if n:
                    preempts_left -= n
                    continue
                break
            verdict, info = self._admission_plan(req)
            if verdict == "reject":
                self._reject(queue, idx, info["err"])
                continue
            if verdict == "admit":
                queue.pop(idx)
                self._do_admit(free[0], req, info, jobs)
                if speculative and spec_budget is not None:
                    spec_budget -= 1
                if deferred:
                    self.scheduler.ooo_admissions += 1
                continue
            # defer: try preemption before stepping past this request
            n = self._preempt_for(req, queue, info["shortfall"],
                                  preempts_left)
            if n:
                preempts_left -= n
                continue                  # re-plan the same request
            self.metrics.counter("sched.defers").inc()
            self.tracer.req_defer(req.rid, self._clock)
            deferred = True
            idx += 1

    def _risk_features(self, queue: List[Request]) -> list:
        """Assemble the predictor's per-cycle feature vector from live
        telemetry. Queue depth and prefill debt count DEADLINED requests
        only — a gated backlog of speculative work must not feed back into
        the very gate holding it, or the gate would never reopen."""
        deadlined = [r for r in queue if r.deadline_step is not None]
        live = sum(1 for s in self.slots if s is not None)
        if self.paged:
            usable = max(1, self.allocator.num_usable)
            free = max(0, self.allocator.num_free
                       - self._outstanding_reservation())
            free_frac = free / usable
        else:
            free_frac = 1.0 - live / self.B
        return self.predictor.features(
            queue_deadlined=len(deadlined), batch=self.B,
            free_frac=free_frac,
            prefill_debt=sum(len(r.prompt) for r in deadlined),
            debt_cap=self.B * self.prefill_bucket,
            live_frac=live / self.B,
            arrival_ewma=self.slo_monitor.arrival_rate.get(),
            tpot_slowdown=self.slo_monitor.tpot_slowdown())

    def _admit(self, queue: List[Request]):
        """One admission cycle: plan/claim as many queued requests as slots
        and pages allow, then execute their prefills BATCHED (same-bucket
        rows of different requests stack into one forward)."""
        if not queue:
            return
        self.metrics.histogram("sched.queue_depth").observe(len(queue))
        self.slo_monitor.note_queue_depth(len(queue))
        spec_budget: Optional[int] = None
        if self.predictor is not None:
            feat = self._risk_features(queue)
            self._risk_feat_last = feat
            self.predictor.consult(feat)
            spec_budget = self.predictor.spec_budget(self.B)
            if (spec_budget <= 0
                    and all(s is None for s in self.slots)
                    and not any(r.deadline_step is not None
                                or r._paused is not None for r in queue)):
                # progress valve: nothing live and nothing the gate would
                # ever let through — admit one row so purely speculative
                # traffic still drains instead of stranding the run
                spec_budget = 1
        with self.tracer.span("admission", args={"queued": len(queue),
                                                 "step": self._clock}):
            jobs: List[_PrefillJob] = []
            if self.scheduler is not None:
                self._admit_slo(queue, jobs, spec_budget)
            else:
                self._admit_fifo(queue, jobs)
            if jobs:
                if self.fused:
                    self._run_fused_rounds(jobs)
                else:
                    self._run_prefills(jobs)

    # -- preemption ---------------------------------------------------------
    def _preempt_gain(self, i: int) -> int:
        """Device pages preempting slot ``i`` recovers: its exclusively
        held pages (shared/aliased pages only drop a refcount) plus its
        not-yet-allocated reservation."""
        freed = sum(1 for p in self.slot_pages[i]
                    if self.allocator.refcount(p) == 1)
        return freed + max(0, self.slot_reserved[i] - len(self.slot_pages[i]))

    def _realias_plan(self, i: int) -> dict:
        """Slot pages of ``i`` that alias STILL-RESIDENT prefix-cache nodes
        (page-table index -> node). Demoting such a page at preemption
        frees nothing (the cache's reference keeps it alive) and pays a
        host copy + a resume promotion for bytes that never leave the
        device — so ``_preempt_slot`` pins the node and drops only the
        slot's reference, and resume re-aliases it (preemption
        re-aliasing). A victim's own freshly inserted prompt pages match
        here too (``_cache_insert`` made them chain nodes), so typically
        only decode-tail pages take the host round trip."""
        req = self.slots[i]
        if self.prefix_cache is None or req is None:
            return {}
        hit = self.prefix_cache.lookup(req.prompt[:-1], record=False)
        plan = {}
        for j, node in enumerate(hit.nodes):
            if (j < len(self.slot_pages[i]) and node.resident
                    and node.page == self.slot_pages[i][j]):
                plan[j] = node
            else:
                return plan     # private page (e.g. CoW): chain ends here
        j = len(hit.nodes)
        if (hit.cow_node is not None and j < len(self.slot_pages[i])
                and hit.cow_node.resident
                and hit.cow_node.page == self.slot_pages[i][j]):
            plan[j] = hit.cow_node   # the victim's own partial leaf page
        return plan

    def _preempt_for(self, req: Request, queue: List[Request],
                     shortfall: int, budget: int) -> int:
        """Preempt strictly-less-urgent running slots so ``req`` becomes
        admissible (``shortfall`` pages short; 0 = needs only a slot),
        spending at most ``budget`` victims (the admission cycle's
        remaining max_preempt_per_admit allowance). Victims demote to the
        host tier (cache-aliased pages are re-alias-pinned instead — they
        need no host room) and re-queue. Returns the number of slots
        preempted."""
        if self.scheduler is None or self.host_store is None or budget <= 0:
            return 0
        running = [(i, self.slots[i], 0) for i in range(self.B)
                   if self.slots[i] is not None]
        victims = self.scheduler.choose_victims(
            req, running, max(0, shortfall), self._preempt_gain,
            limit=budget)
        preempted = 0
        for i in victims:
            plan = self._realias_plan(i)
            need_room = len(self.slot_pages[i]) - len(plan)
            while not self.host_store.has_room(need_room):
                # make host room by dropping cold demoted prefixes
                if (self.prefix_cache is None
                        or not self.prefix_cache.drop_host_lru()):
                    return preempted      # host tier genuinely full
            queue.append(self._preempt_slot(i, plan))
            preempted += 1
        return preempted

    def _preempt_slot(self, i: int, plan: Optional[dict] = None) -> Request:
        """Evict the request in slot ``i`` mid-decode (at a span boundary,
        where host-side slot state is consistent): every written page
        either demotes to the host tier (private pages) or stays resident
        as a PINNED prefix-cache node with the slot's reference dropped
        (cache-aliased pages — host-copying a refcount>1 page frees
        nothing). Device pages + reservation are released and the resume
        state captured. The request re-queues; resume promotes the host
        pages back / re-increfs the pinned nodes and continues decoding
        bitwise-identically (no re-prefill)."""
        if plan is None:
            plan = self._realias_plan(i)
        req = self.slots[i]
        self.tracer.req_preempt(req.rid, self._clock)
        entries = []
        with self.tracer.req_span(req.rid, "offload",
                                  args={"pages": len(self.slot_pages[i]),
                                        "step": self._clock}):
            for j, p in enumerate(self.slot_pages[i]):
                node = plan.get(j)
                if node is not None:
                    # page survives via the cache's reference; pin the node
                    # so eviction (demote AND drop) cannot touch it before
                    # resume
                    self.prefix_cache.pin_node(node)
                    entries.append(("alias", node))
                    self.realias_skipped += 1
                else:
                    # pager.offload: sync mode is byte-for-byte the old
                    # host_store.put(extract_page(...)); async mode issues
                    # the D2H copy and resolves it at the next span
                    # boundary drain
                    entries.append(("host", self.pager.offload(p)))
                self.allocator.free([p])
        self.slot_pages[i] = []
        self.page_table[i, :] = SCRATCH_PAGE
        self._pt_dirty = True
        self.slot_reserved[i] = 0
        req._paused = PreemptedState(pos=int(self.pos[i]),
                                     token=int(self.tokens[i]),
                                     gen=int(self.slot_gen[i]),
                                     entries=entries)
        req.preemptions += 1
        self.preempt_count += 1
        self.pos[i] = 0
        self.slot_gen[i] = 0
        self.tokens[i] = 0
        self.slots[i] = None
        return req

    def _resume_slot(self, i: int, req: Request, total: int):
        """Re-admit a preempted request: promote its demoted pages back
        into freshly allocated device pages (byte-identical — see
        core.page_store) and re-alias its pinned cache nodes (an incref,
        no byte movement), restore the slot clock/token state, and
        continue decoding where it left off. No prefill runs."""
        st = req._paused
        self.slot_reserved[i] = total
        with self.tracer.req_span(req.rid, "resume",
                                  args={"pages": len(st.entries),
                                        "step": self._clock}):
            for j, (kind, val) in enumerate(st.entries):
                if kind == "alias":
                    assert val.resident, "pinned prefix node lost residency"
                    page = val.page
                    self.allocator.incref(page)  # the slot's alias reference
                    self.prefix_cache.unpin_node(val)
                else:
                    page = self.allocator.alloc()  # reclaim may evict/demote
                    self.caches = inject_page(self.caches,
                                              self.host_store.pop(val), page)
                self.page_table[i, j] = page
                self.slot_pages[i].append(page)
                self._pt_dirty = True
        self.pos[i] = st.pos
        self.tokens[i] = st.token
        self.slot_gen[i] = st.gen
        req._paused = None
        self.resume_count += 1
        self.tracer.req_admit(req.rid, self._clock, resumed=True)
        self.slots[i] = req

    # -- decode -------------------------------------------------------------
    def _run_span(self) -> int:
        """Decode steps until the next slot event (a completion), computable
        purely from counts — the span the hot loop can run without any
        host<->device synchronization."""
        spans = []
        for i in range(self.B):
            req = self.slots[i]
            if req is None:
                continue
            spans.append(min(req.max_new - self.slot_gen[i],
                             (self.max_len - 1) - int(self.pos[i])))
        return max(1, min(spans))

    def _note_finish(self, req: Request, step: int) -> None:
        """Retirement bookkeeping shared by the span-boundary and fused
        paths: the deadline-miss counter is measured on the decode-step
        clock (deterministic), the tracer closes the request's record,
        the rolling SLO window absorbs the outcome, and (predictor on)
        the retired request's admission-time features become one SGD
        example with the miss as its label."""
        req.finish_step = step
        missed = (req.deadline_step is not None
                  and step > req.deadline_step)
        if missed:
            self.metrics.counter("sched.deadline_misses").inc()
        if self.predictor is not None and req._risk_feat is not None:
            self.predictor.observe(req._risk_feat, missed)
        self.slo_monitor.note_finish(req.rid, not missed, len(req.out))
        self.tracer.req_finish(req.rid, step, len(req.out))

    def start_loop(self, requests: List[Request]) -> "ServeLoop":
        """Begin a steppable serving loop over ``requests``.

        The multi-replica admission front (``launch.frontend``) drives N
        of these on one shared decode-step clock; :meth:`run` is exactly
        ``start_loop`` + tick-until-drained, so the single-server token
        streams are the refactor's bitwise identity baseline."""
        return ServeLoop(self, requests)

    def _prefetch_promotes(self, queue: List[Request]) -> None:
        """Promote-path prefetch: requests still queued after an admission
        pass are next cycle's admission candidates, so any prefix-chain
        (or preemption-state) page parked on the host tier is likely to be
        promoted then. Stage its host->device copy NOW — the transfer
        dispatches asynchronously and rides behind the decode span about
        to run (the promote-direction mirror of the async demote double
        buffer); the synchronous promote that follows consumes the staged
        device arrays instead of paying the H2D copy inside admission.
        Pure staging: no page allocation, no trie stamps (``peek_chain``),
        so token streams are bit-identical with prefetch on or off."""
        pager = self.pager
        if pager is None or not pager.async_mode or not queue:
            return
        budget = pager.stage_room()
        # only the queue head region can be admitted next cycle — scanning
        # deeper would stage copies that expire before their promote
        for req in queue[:max(2, self.B)]:
            if budget <= 0:
                return
            if req._paused is not None:
                for kind, val in req._paused.entries:
                    if kind == "host" and budget > 0:
                        budget -= pager.prefetch(val)
                continue
            if self.prefix_cache is None:
                continue
            for node in self.prefix_cache.peek_chain(req.prompt[:-1]):
                if node.host is not None and budget > 0:
                    budget -= pager.prefetch(node.host)

    def run(self, requests: List[Request], *, verbose: bool = False):
        t0 = time.time()
        gen0 = self._gen_tokens
        # instance counters are cumulative across run() calls (benchmarks
        # zero them between warmup and measurement); the verbose print
        # reports THIS run's deltas
        steps0, pf0 = self.decode_steps, self.prefill_forwards
        rejected0 = len(self.rejected)
        loop = self.start_loop(requests)
        while not loop.finished:
            loop.tick()
        loop.close()
        dt = time.time() - t0
        gen_tokens = self._gen_tokens - gen0
        if verbose:
            layout = (f"paged ps={self.page_size} "
                      f"free={self.allocator.num_free}"
                      if self.paged else "dense")
            steps = self.decode_steps - steps0
            mode = "fused" if self.fused else self.prefill_mode
            print(f"[serve] {steps} decode steps, "
                  f"{self.prefill_forwards - pf0} prefill forwards "
                  f"({mode}), {len(requests)} requests, "
                  f"{gen_tokens / max(dt, 1e-9):,.1f} tok/s "
                  f"({steps * self.B / max(dt, 1e-9):,.1f} "
                  f"tok-slots/s, {layout}, attn={self.attn_impl}, "
                  f"{self.program_launches} programs / "
                  f"{self.cycles} cycles)")
            if self.prefix_cache is not None:
                s = self.prefix_cache.stats()
                print(f"[serve] prefix cache: {s['hits']}/{s['lookups']} "
                      f"hits, {s['hit_tokens']} tokens reused, "
                      f"{self.prefill_forwards_saved} prefill forwards "
                      f"saved, {s['cow_copies']} CoW copies, "
                      f"{s['cached_pages']} pages cached + "
                      f"{s['host_pages']} host "
                      f"({s['evictions']} evicted, {s['demotions']} demoted, "
                      f"{s['promotions']} promoted)")
            if self.quant_tier is not None:
                s = self.prefix_cache.stats()
                print(f"[serve] quant tier: {self.quant_tier.num_pages} "
                      f"pages / {self.quant_tier.nbytes / 2**20:.2f} MiB "
                      f"parked (peak {self.quant_tier.peak_pages}), "
                      f"{s['requants']} requants, {s['deepens']} deepens, "
                      f"{s['tier_promotions']} promotions")
            if self.host_store is not None:
                print(f"[serve] host tier: {self.host_store.num_pages} "
                      f"pages / {self.host_store.nbytes / 2**20:.2f} MiB "
                      f"(peak {self.host_store.peak_pages}), "
                      f"{self.preempt_count} preemptions, "
                      f"{self.resume_count} resumes, "
                      f"{self.realias_skipped} demotions skipped "
                      f"(re-aliased)")
        new_rejects = self.rejected[rejected0:]
        if new_rejects and self.scheduler is None:
            # legacy strict semantics: surface the first impossible request
            # — but only AFTER the serviceable traffic drained (the old
            # code raised immediately, stalling everything queued behind a
            # too-large head). SLO mode records errors on the requests.
            raise new_rejects[0].error
        return requests

    def release_prefix_cache(self) -> int:
        """Drop every unreferenced cached prefix page back to the free
        list (and every demoted page out of the host tier). Returns the
        DEVICE page count the cache STILL holds — with all requests
        completed that must be 0, anything else is a refcount leak (the
        bench-smoke CI gate checks exactly this)."""
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.clear()

    # -- tiered-store introspection / persistence ---------------------------
    def kv_inventory(self) -> dict:
        """Device/host split of the KV store (bytes per container, page
        counts) — the two-tier generalization of ``pool_bytes``. Scalar
        fields read the registered ``kv.*`` gauges, so this dict, the
        snapshot stream, and any direct ``metrics.gauge("kv.…")`` reader
        share one schema (tests assert the byte reconciliation)."""
        if not self.paged:
            return {"device_bytes": 0, "device_by_container": {},
                    "device_pages_free": 0, "device_pages_usable": 0,
                    "host_bytes": 0, "host_pages": 0,
                    "host_by_container": {},
                    "tier_bytes": 0, "tier_pages": 0,
                    "tier_by_container": {}}
        g = self.metrics.gauge
        hs = self.host_store
        qt = self.quant_tier
        return {
            "device_bytes": g("kv.device_bytes").value,
            "device_by_container": caches_kv_bytes(self.caches),
            "device_pages_free": g("kv.device_pages_free").value,
            "device_pages_usable": g("kv.device_pages_usable").value,
            "host_bytes": g("kv.host_bytes").value,
            "host_pages": g("kv.host_pages").value,
            "host_by_container": hs.bytes_by_container() if hs else {},
            "tier_bytes": g("kv.tier_bytes").value,
            "tier_pages": g("kv.tier_pages").value,
            "tier_by_container": qt.bytes_by_container() if qt else {},
        }

    def snapshot_prefix_cache(self, path: str) -> int:
        """Serialize every cached prefix page (resident pages read straight
        off the device pools, demoted ones from the host tier) to ``path``.
        The snapshot is profile-key-namespaced like the trie and carries a
        pool-geometry signature; returns the number of pages written."""
        if self.prefix_cache is None:
            raise ValueError("snapshot needs --prefix-cache on")
        entries = []
        for key, tokens, node in self.prefix_cache.iter_chain_nodes():
            if node.host is not None:
                blob = self.host_store.get(node.host)
            elif node.tier is not None:
                # widened back to pool-native containers so the snapshot
                # geometry signature matches (the requant cost is already
                # baked into the grid values)
                blob = self.quant_tier.export(node.tier)
            else:
                blob = extract_page(self.caches, node.page)
            entries.append((key, tokens, blob))
        return save_prefix_snapshot(path, entries, page_size=self.page_size,
                                    geometry=cache_geometry(self.caches))

    def restore_prefix_cache(self, path: str) -> int:
        """Load a snapshot into the HOST tier: every chain page becomes a
        host-state trie node (zero device pages consumed until a hit
        promotes it). Chains whose profile key differs from this server's
        stay in their own namespace — harmless, never matched. Returns the
        pages restored (stops early when the host tier fills)."""
        if self.prefix_cache is None:
            raise ValueError("restore needs --prefix-cache on")
        if self.host_store is None:
            raise ValueError("restore lands pages in the host tier; it "
                             "needs --kv-offload host")
        meta, entries = load_prefix_snapshot(path)
        if meta["page_size"] != self.page_size:
            raise ValueError(f"snapshot page_size {meta['page_size']} != "
                             f"server page_size {self.page_size}")
        geo = cache_geometry(self.caches)
        if meta["geometry"] != geo:
            raise ValueError("snapshot pool geometry does not match this "
                             "server's architecture/profile")
        n = 0
        for key, tokens, blob in entries:
            if not self.host_store.has_room(1):
                break
            h = self.host_store.put(blob)
            if self.prefix_cache.insert_host(tokens, h, key):
                n += 1
            else:
                self.host_store.drop(h)   # duplicate / orphaned chain
        return n


class ServeLoop:
    """One in-flight :meth:`BatchedServer.run`, steppable one scheduler
    cycle at a time.

    Extracted from ``run()`` so a multi-replica admission front
    (``launch.frontend.ReplicaFrontend``) can interleave N servers on one
    shared decode-step clock: each :meth:`tick` executes exactly one
    iteration of the serving loop — arrivals, admission, promote
    prefetch, one decode span — and ``limit_step`` caps how far the
    replica clock may advance, behaving exactly like a pending arrival at
    that step (span cap while busy, clock jump while idle). With
    ``limit_step=None`` the tick sequence is the pre-refactor ``run()``
    body line for line, which is what keeps the single-server token
    streams bitwise identical.

    Arrivals are measured on a per-run decode-step clock (deterministic,
    unlike wall time): a request joins the queue once
    ``clock >= arrive_step``; requests with the default ``arrive_step=0``
    reproduce the all-at-once legacy behavior exactly.
    """

    def __init__(self, srv: "BatchedServer", requests: List[Request]):
        self.srv = srv
        self.pending = sorted(requests, key=lambda r: r.arrive_step)
        self.queue: List[Request] = []
        self.clock = 0
        self.finished = False

    @property
    def live(self) -> bool:
        return any(s is not None for s in self.srv.slots)

    def add(self, req: Request) -> None:
        """Deliver one more request mid-run (frontend routing). Stable
        insert: same-step arrivals keep their delivery order, matching
        the sort in ``__init__``."""
        i = len(self.pending)
        while i > 0 and self.pending[i - 1].arrive_step > req.arrive_step:
            i -= 1
        self.pending.insert(i, req)
        self.finished = False

    def tick(self, limit_step: Optional[int] = None) -> bool:
        """One scheduler cycle. Never advances ``clock`` past
        ``limit_step`` (when given). Returns True while the loop is doing
        work or moving its clock; False once fully drained (also sets
        ``finished``)."""
        srv = self.srv
        pending, queue = self.pending, self.queue
        if not (pending or queue or self.live):
            if limit_step is not None and limit_step > self.clock:
                # empty but clock-limited: the frontend may still route
                # arrivals here — follow the shared clock, don't drain
                self.clock = limit_step
                return True
            self.finished = True
            return False
        clock = self.clock
        srv._clock = clock
        while pending and pending[0].arrive_step <= clock:
            req = pending.pop(0)
            srv.tracer.req_arrive(req.rid, req.arrive_step,
                                  req.deadline_step)
            srv.slo_monitor.note_arrive(req.rid)
            queue.append(req)
        srv._admit(queue)
        srv._prefetch_promotes(queue)
        live = [i for i in range(srv.B) if srv.slots[i] is not None]
        if not live:
            # nothing runnable: everything admissible was admitted (or
            # rejected), so only a future arrival can change the state
            if pending:
                nxt = pending[0].arrive_step
                if limit_step is not None:
                    nxt = min(nxt, limit_step)
                self.clock = max(clock, nxt)
                return True
            if limit_step is not None and limit_step > clock:
                # idle but the frontend may still route arrivals here:
                # follow the shared clock instead of draining
                self.clock = limit_step
                return True
            self.finished = True
            return False
        span = srv._run_span()
        if pending:
            # cap the span at the next arrival so urgent latecomers
            # get an admission (and preemption) opportunity promptly
            span = max(1, min(span, pending[0].arrive_step - clock))
        if limit_step is not None:
            span = max(1, min(span, limit_step - clock))
        # device-resident state for the span: tokens advance
        # device-to-device; generated ids are fetched asynchronously and
        # materialized only at the span boundary
        tokens_dev = _upload(srv.tokens)
        pos_dev = _upload(srv.pos)
        live_mask = np.zeros((srv.B,), bool)
        live_mask[live] = True
        all_live = bool(live_mask.all())
        live_mask_dev = jnp.asarray(live_mask)
        live_inc = jnp.asarray(live_mask.astype(np.int32))
        fetches = []                       # (nxt_dev, owner snapshot)
        with srv.tracer.span("decode_span",
                             args={"steps": span, "rows": len(live),
                                   "step": clock}):
            for _ in range(span):
                if srv.paged:
                    for i in live:
                        srv._ensure_page(i, int(srv.pos[i]))
                pt = srv._page_table_dev() if srv.paged else None
                if srv.fused:
                    # steady state: the SAME fused program as admission
                    # rounds at S=1 — every row decodes, every row
                    # emits. Bitwise-identical to srv.decode (the
                    # gathers are identity copies; see make_fused_step).
                    nxt, _, srv.caches = srv._fused(
                        srv.params, tokens_dev[:, None], pos_dev,
                        srv._ones_dev, srv.caches, pt,
                        srv._arange_dev)
                else:
                    nxt, _, srv.caches = srv.decode(
                        srv.params, tokens_dev, pos_dev, srv.caches,
                        pt)
                srv.program_launches += 1
                srv.cycles += 1
                nxt.copy_to_host_async()
                fetches.append((nxt, tuple(srv.slots)))
                # idle slots hold their token (keeps runs reproducible
                # across layouts even when idle rows share MoE capacity)
                tokens_dev = (nxt if all_live
                              else jnp.where(live_mask_dev, nxt,
                                             tokens_dev))
                pos_dev = pos_dev + live_inc
                for i in live:
                    srv.pos[i] += 1
                    srv.slot_gen[i] += 1
                srv.decode_steps += 1
                srv._gen_tokens += len(live)
            # span boundary: materialize tokens, retire finishers
            last_np = None
            for nxt_dev, owners in fetches:
                arr = np.asarray(nxt_dev)
                last_np = arr
                for i, req in enumerate(owners):
                    if req is not None:
                        if not req.out:
                            srv.tracer.req_first_token(req.rid)
                            srv.slo_monitor.note_first_token(req.rid)
                        req.out.append(int(arr[i]))
        if srv.pager is not None:
            # span boundary: resolve in-flight async page transfers —
            # their D2H copies ran concurrently with the decode span
            # above (the Chrome trace's pager track shows the overlap)
            srv.pager.drain()
        for i in live:
            srv.tokens[i] = int(last_np[i])
            req = srv.slots[i]
            if (srv.slot_gen[i] >= req.max_new
                    or srv.pos[i] >= srv.max_len - 1):
                req.done = True
                srv.slots[i] = None
                srv._release_slot(i)
                # everyone retiring here hit exactly span's end: span
                # is the min remaining capacity over live slots
                srv._note_finish(req, clock + span)
        self.clock = clock + span
        srv.slo_monitor.advance(span)
        if srv._snapshotter is not None:
            srv._snapshotter.maybe_emit(srv.cycles)
        return True

    def close(self) -> None:
        """Final pager drain (the epilogue ``run()`` always executed)."""
        if self.srv.pager is not None:
            self.srv.pager.drain()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--kv-bits", type=int, default=0,
                    help="0=fp cache, 8=int8 pages/grid, 4=int4 "
                         "(lane-packed when --page-size > 0)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per KV page; 0 = dense max_len cache")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="shared pool pages (0 = full capacity)")
    ap.add_argument("--attn-impl", choices=["gather", "pallas"],
                    default="gather",
                    help="paged decode backend: jnp gather (bitwise "
                         "reference) or the Pallas paged-attention kernel "
                         "(interpret-mode on CPU)")
    ap.add_argument("--prefill", choices=["auto", "bucketed", "stepwise"],
                    default="auto",
                    help="bucketed = chunked prefill jit straight into the "
                         "paged pool; stepwise = slot-granular reference")
    ap.add_argument("--prefill-bucket", type=int, default=32,
                    help="max power-of-two prompt chunk for bucketed prefill")
    ap.add_argument("--prefill-batch", type=int, default=0,
                    help="max same-bucket prompts stacked into ONE batched "
                         "prefill forward per admission cycle (0 = auto: "
                         "the batch size — intra-wave prefix sharing is "
                         "handled by wave dedupe, so this composes with "
                         "--prefix-cache; 1 = sequential reference)")
    ap.add_argument("--fused", choices=["on", "off"], default="off",
                    help="on = ONE ragged [batch, bucket] program per "
                         "scheduler cycle: prefill chunks and decode "
                         "tokens share a single variable-length forward "
                         "(per-row start/length/page-table, LM head only "
                         "on emitting rows), bitwise-identical to the "
                         "separate-program path; needs bucketed prefill. "
                         "--prefill-batch is ignored in fused mode (every "
                         "cycle is already one program)")
    ap.add_argument("--kv-profile", default="",
                    help="path to a core.policy.PrecisionPolicy JSON (e.g. "
                         "core.search output): per-layer KV containers — "
                         "int4 pages for <=4 data bits, int8 for <=8, float "
                         "pages for fp32 layers (paged, attention-only "
                         "archs; supersedes --kv-bits)")
    ap.add_argument("--kv-scale", choices=["static", "page"],
                    default="static",
                    help="paged dequant scales: static = the layer's Q(I,F) "
                         "grid (bitwise-reproducible reference); page = "
                         "dynamic per-page max-abs calibration")
    ap.add_argument("--prefix-cache", choices=["on", "off"], default="off",
                    help="share page-aligned common prompt prefixes across "
                         "requests (refcounted aliasing + copy-on-write; "
                         "LRU eviction of unreferenced prefixes under pool "
                         "pressure)")
    ap.add_argument("--kv-profile-scan", choices=["group", "unroll"],
                    default="group",
                    help="per-layer profile forward: group contiguous "
                         "same-container layer runs into lax.scan segments "
                         "(default) or force the fully unrolled reference")
    ap.add_argument("--kv-offload", choices=["none", "host"], default="none",
                    help="host = add a host-memory page tier: pool pressure "
                         "DEMOTES cached prefixes (packed containers ride "
                         "along) instead of destroying them; enables "
                         "preemption and snapshot persistence")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="host-tier capacity in pages (0 = unbounded)")
    ap.add_argument("--kv-adapt", choices=["off", "on"], default="off",
                    help="on = online precision adaptation: pool pressure "
                         "REQUANTIZES cold cached prefix pages one "
                         "container step narrower (fp->int8->int4, fresh "
                         "per-page max-abs scales) into a bounded device "
                         "tier BEFORE any host demotion or drop; needs "
                         "--prefix-cache on")
    ap.add_argument("--kv-adapt-pages", type=int, default=0,
                    help="adaptation-tier byte budget, quoted in "
                         "floor-container page equivalents (0 = auto: the "
                         "pool's usable page count)")
    ap.add_argument("--kv-adapt-floor", type=int, choices=[4, 8], default=4,
                    help="narrowest container requantization may reach "
                         "(per-pool: a layer whose head_dim cannot "
                         "lane-pack floors at int8 regardless)")
    ap.add_argument("--sched", choices=["fifo", "slo"], default="fifo",
                    help="admission order: fifo = legacy arrival order "
                         "(too-large heads are skipped, not stalled "
                         "behind); slo = priority + earliest-deadline with "
                         "bounded out-of-order admission and preemption")
    ap.add_argument("--admit-window", type=int, default=4,
                    help="SLO sched: max requests admitted past a deferred "
                         "head per cycle")
    ap.add_argument("--no-preempt", action="store_true",
                    help="SLO sched: disable preemption of running "
                         "requests")
    ap.add_argument("--predictor", choices=["off", "on"], default="off",
                    help="on = consult the online deadline-miss predictor "
                         "every admission cycle: gates NEW speculative "
                         "(no-deadline) admissions while the hazard says "
                         "an overload is in progress; trains on retired "
                         "deadlined requests' outcomes; needs --sched slo")
    ap.add_argument("--pager-async", choices=["off", "on"], default="off",
                    help="on = double-buffered async host-tier transfers: "
                         "demote/offload D2H copies are issued immediately "
                         "and resolved at the next decode-span boundary, "
                         "overlapping decode compute; needs --kv-offload "
                         "host")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree for ONE serving replica: "
                         "builds a (n_devices//tp, tp) data x model mesh "
                         "(launch.mesh.make_serving_mesh) and shards the "
                         "attention-head axis of weights AND the paged KV "
                         "pool over 'model' (per-page scales replicate; "
                         "int4 lane-packed words shard along heads). 1 = "
                         "single-device reference. CI exercises tp>1 on "
                         "virtual host devices")
    ap.add_argument("--prefix-snapshot", default="",
                    help="path: restore the prefix cache from it at start "
                         "(if the file exists) and snapshot back at exit — "
                         "cached prefixes survive server restarts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics", choices=["off", "on"], default="off",
                    help="on = record request-lifecycle spans (arrival/"
                         "admit/defer/reject/prefill/decode/preempt/"
                         "resume/finish) on a monotonic clock and report "
                         "an SLO summary (p50/p99 TTFT+TPOT, goodput). "
                         "The metrics registry itself is always live; "
                         "tokens are identical either way")
    ap.add_argument("--trace-out", default="",
                    help="path: export the request-lifecycle trace as "
                         "Chrome trace-event JSON (load in chrome://"
                         "tracing or https://ui.perfetto.dev). Implies "
                         "--metrics on")
    ap.add_argument("--metrics-out", default="",
                    help="path: append a JSONL registry snapshot every "
                         "--metrics-every scheduler cycles. Implies "
                         "--metrics on")
    ap.add_argument("--metrics-every", type=int, default=50,
                    help="scheduler cycles between JSONL snapshots "
                         "(with --metrics-out)")
    args = ap.parse_args(argv)
    if args.trace_out or args.metrics_out:
        args.metrics = "on"

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only archs have no decode path")
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    kv_profile = None
    if args.kv_profile:
        with open(args.kv_profile) as f:
            kv_profile = PrecisionPolicy.from_json(f.read())
    srv = BatchedServer(cfg, params, batch_size=args.batch_size,
                        max_len=args.max_len, kv_bits=args.kv_bits,
                        page_size=args.page_size,
                        num_pages=args.num_pages or None,
                        attn_impl=args.attn_impl, prefill=args.prefill,
                        prefill_bucket=args.prefill_bucket,
                        prefill_batch=args.prefill_batch,
                        kv_profile=kv_profile, kv_scale=args.kv_scale,
                        prefix_cache=args.prefix_cache,
                        kv_profile_scan=args.kv_profile_scan,
                        kv_offload=args.kv_offload,
                        host_pages=args.host_pages or None,
                        sched=args.sched, admit_window=args.admit_window,
                        preempt=False if args.no_preempt else None,
                        kv_adapt=args.kv_adapt,
                        adapt_pages=args.kv_adapt_pages,
                        adapt_floor_bits=args.kv_adapt_floor,
                        fused=args.fused, metrics=args.metrics,
                        snapshot_out=args.metrics_out or None,
                        snapshot_every=args.metrics_every,
                        predictor=args.predictor,
                        pager_async=args.pager_async,
                        mesh=make_serving_mesh(args.tp)
                        if args.tp > 1 else None)
    import os
    if args.prefix_snapshot and os.path.exists(
            snapshot_path(args.prefix_snapshot)):
        n = srv.restore_prefix_cache(args.prefix_snapshot)
        print(f"[serve] restored {n} prefix pages from "
              f"{args.prefix_snapshot} (host tier)")
    srv.run(reqs, verbose=True)
    if args.prefix_snapshot:
        n = srv.snapshot_prefix_cache(args.prefix_snapshot)
        print(f"[serve] snapshotted {n} prefix pages to "
              f"{args.prefix_snapshot}")
    if args.metrics == "on":
        slo = srv.tracer.slo_summary()
        ttft = slo.get("ttft_p50_s")
        tpot = slo.get("tpot_p50_s")
        goodput = slo.get("goodput")
        print(f"[serve] slo: "
              f"goodput={'n/a' if goodput is None else format(goodput, '.3f')} "
              f"({slo['finished']}/{slo['requests']} finished, "
              f"{slo['deadline_misses']} deadline misses), "
              f"ttft p50={0.0 if ttft is None else ttft * 1e3:.1f}ms "
              f"p99={0.0 if slo['ttft_p99_s'] is None else slo['ttft_p99_s'] * 1e3:.1f}ms, "
              f"tpot p50={0.0 if tpot is None else tpot * 1e3:.2f}ms")
    if args.trace_out:
        srv.tracer.export_chrome(args.trace_out)
        print(f"[serve] wrote {len(srv.tracer.events)} trace events to "
              f"{args.trace_out}")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    return reqs


if __name__ == "__main__":
    main()
