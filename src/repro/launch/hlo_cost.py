"""Loop-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — useless for
scan-over-layers models where >95% of work sits inside loops. This module
parses the SPMD-partitioned HLO text and aggregates, with every while body
multiplied by its ``known_trip_count``:

  * flops      — 2*prod(out)*prod(contracting) per dot (descends into fusions)
  * hbm_bytes  — operands+output bytes of every FUSION-BOUNDARY instruction
                 (XLA moves HBM data at fusion boundaries; inside-fusion
                 temporaries stay in registers/VMEM)
  * wire_bytes — ring-model collective bytes (see hlo_analysis)

Shapes in the partitioned module are per-device, so all results are
per-device per-step.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

from .hlo_analysis import (_DTYPE_BYTES, _RING_FACTOR, _SHAPE_RE,
                           COLLECTIVE_OPS, _base_opcode, _type_bytes)

_COMP_HDR_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z][a-z0-9]*\[[^\]]*\](?:\{[^}]*\})?))\s+([a-z][\w\-]*)\((.*)$")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*((?:\([^()]*\)|[a-z][a-z0-9]*\[[^\]]*\]))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|to_apply|true_computation|false_computation)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_NEW_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")

# ops that move no HBM data at the top level
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota",
             "all-gather-done", "all-reduce-done", "collective-permute-done",
             "copy-done", "opt-barrier", "custom-call-done"}
# control-flow / call ops we descend into instead of pricing directly
_DESCEND = {"while", "call", "conditional", "fusion"}


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    rest: str            # everything after the opening paren
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    instrs: List[Instr]


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            is_entry, name, params_str, _ = m.groups()
            params = dict(_PARAM_RE.findall(params_str))
            cur = Computation(name, params, [])
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, out_type, opcode, rest = mi.groups()
            cur.instrs.append(Instr(name, out_type, opcode, rest,
                                    is_root=line.lstrip().startswith("ROOT")))
    return comps, entry


def _operand_names(rest: str) -> List[str]:
    # operands live before the first "), " at paren depth 0
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", rest[:end]), rest[end:]


def _dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.wire_bytes += o.wire_bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.hbm_bytes * m, self.wire_bytes * m,
                    {k: v * m for k, v in self.coll.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}
        self.warnings: List[str] = []
        self.loops: List[dict] = []   # populated during cost_of()

    # -- shape environment per computation -------------------------------
    def _shapes(self, comp: Computation) -> Dict[str, str]:
        env = dict(comp.params)
        for ins in comp.instrs:
            env[ins.name] = ins.out_type
        return env

    def _flops_of_dot(self, ins: Instr, env: Dict[str, str]) -> float:
        out_elems = 1
        for d in _dims(ins.out_type):
            out_elems *= d
        operands, attrs = _operand_names(ins.rest)
        contract = 1
        m = _CONTRACT_RE.search(attrs)
        if m and operands:
            lhs_dims = _dims(env.get(operands[0], ""))
            idxs = [int(i) for i in m.group(1).split(",")] if m.group(1) \
                else []
            for i in idxs:
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
        return 2.0 * out_elems * contract

    # slicing ops read/write only the slice, not the whole operand buffer
    _READ_SLICE = {"slice", "dynamic-slice", "gather"}
    _ALIASED_WRITE = {"dynamic-update-slice", "scatter"}

    def _root_opcode(self, ins: Instr) -> str:
        if ins.opcode != "fusion":
            return ins.opcode
        _, attrs = _operand_names(ins.rest)
        for c in _CALL_ATTR_RE.findall(attrs):
            comp = self.comps.get(c)
            if comp:
                for i2 in comp.instrs:
                    if i2.is_root:
                        return i2.opcode
        return ins.opcode

    def _fusion_param_slice_bytes(self, called: str) -> Dict[int, float]:
        """For a fused computation: param index -> total bytes actually READ
        when every consumer of that param is a (dynamic-)slice/gather (the
        scan xs pattern: fusions embed a per-step slice of a big stacked
        buffer; HBM traffic is the slice, not the buffer)."""
        comp = self.comps.get(called)
        if comp is None:
            return {}
        pidx: Dict[str, int] = {}
        for ins in comp.instrs:
            if ins.opcode == "parameter":
                mnum = re.match(r"\s*(\d+)", ins.rest)
                if mnum:
                    pidx[ins.name] = int(mnum.group(1))
        consumers: Dict[str, List[Instr]] = {n: [] for n in pidx}
        for ins in comp.instrs:
            ops, _ = _operand_names(ins.rest)
            for o in ops:
                if o in consumers:
                    consumers[o].append(ins)
        out: Dict[int, float] = {}
        for name, idx in pidx.items():
            cons = consumers.get(name, [])
            if cons and all(c.opcode in ("dynamic-slice", "slice", "gather")
                            for c in cons):
                out[idx] = float(sum(_type_bytes(c.out_type) for c in cons))
        return out

    def _bytes_of(self, ins: Instr, env: Dict[str, str]) -> float:
        operands, attrs = _operand_names(ins.rest)
        op_bytes = [_type_bytes(env.get(o, "")) for o in operands]
        out_b = _type_bytes(ins.out_type)
        root = self._root_opcode(ins) if ins.opcode in (
            "fusion", "dynamic-slice", "slice", "gather",
            "dynamic-update-slice", "scatter") else ins.opcode
        if ins.opcode == "fusion":
            for c in _CALL_ATTR_RE.findall(attrs):
                for idx, b in self._fusion_param_slice_bytes(c).items():
                    if idx < len(op_bytes):
                        op_bytes[idx] = min(op_bytes[idx], b)
        big = max(op_bytes, default=0)
        if root in self._READ_SLICE and op_bytes:
            # read the slice (out) + indices; not the whole source buffer
            return float(sum(op_bytes) - big + out_b)
        if root in self._ALIASED_WRITE and op_bytes:
            # in-place window write: update + indices (buffer is aliased)
            return float(sum(op_bytes) - big + max(out_b - big, 0))
        return float(sum(op_bytes) + out_b)

    def _wire_of(self, ins: Instr, env: Dict[str, str], base: str) -> float:
        operands, attrs = _operand_names(ins.rest)
        out_b = _type_bytes(ins.out_type)
        op_b = sum(_type_bytes(env.get(o, "")) for o in operands) or out_b
        g = 1
        m = _GROUPS_NEW_RE.search(attrs)
        if m:
            g = int(m.group(2))
        else:
            m = _GROUPS_OLD_RE.search(attrs)
            if m:
                g = max(1, m.group(1).count(",") + 1)
        return _RING_FACTOR[base](max(g, 1)) * (
            out_b if base == "all-gather" else op_b)

    # -- recursive cost ----------------------------------------------------
    def cost_of(self, comp_name: str, *, inside_fusion: bool = False) -> Cost:
        key = (comp_name, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        if comp is None:
            return Cost()
        env = self._shapes(comp)
        total = Cost()
        for ins in comp.instrs:
            op = ins.opcode
            base = _base_opcode(op)
            if base is not None:
                w = self._wire_of(ins, env, base)
                total += Cost(0.0, 0.0 if inside_fusion
                              else self._bytes_of(ins, env), w,
                              {base: w})
                continue
            if op == "dot":
                total += Cost(self._flops_of_dot(ins, env),
                              0.0 if inside_fusion
                              else self._bytes_of(ins, env), 0.0)
                continue
            if op == "while":
                _, attrs = _operand_names(ins.rest)
                mt = _TRIP_RE.search(attrs)
                trip = int(mt.group(1)) if mt else 1
                if not mt:
                    self.warnings.append(
                        f"while {ins.name}: no known_trip_count; using 1")
                called = _CALL_ATTR_RE.findall(attrs)
                body = [c for c in called if self.comps.get(c)]
                inner = Cost()
                for c in body:
                    inner += self.cost_of(c)
                self.loops.append({
                    "name": ins.name, "in": comp_name, "trip": trip,
                    "carry_bytes": _type_bytes(ins.out_type),
                    "body_flops": inner.flops,
                    "body_hbm_bytes": inner.hbm_bytes,
                    "body_wire_bytes": inner.wire_bytes,
                    "total_hbm_bytes": inner.hbm_bytes * trip,
                })
                total += inner.scaled(trip)
                continue
            if op == "fusion":
                _, attrs = _operand_names(ins.rest)
                for c in _CALL_ATTR_RE.findall(attrs):
                    total += self.cost_of(c, inside_fusion=True)
                total += Cost(0.0, self._bytes_of(ins, env), 0.0)
                continue
            if op in ("call", "conditional", "async-start"):
                _, attrs = _operand_names(ins.rest)
                branches = _CALL_ATTR_RE.findall(attrs)
                mb = _BRANCHES_RE.search(attrs)
                if mb:
                    branches += re.findall(r"%?([\w.\-]+)", mb.group(1))
                sub = [self.cost_of(c) for c in branches
                       if self.comps.get(c)]
                if op == "conditional" and sub:
                    # price the most expensive branch
                    total += max(sub, key=lambda c: c.flops + c.hbm_bytes)
                else:
                    for c in sub:
                        total += c
                continue
            if op in _FREE_OPS:
                continue
            if op == "custom-call":
                # e.g. topk; price data movement only
                if not inside_fusion:
                    total += Cost(0.0, self._bytes_of(ins, env), 0.0)
                continue
            # plain op at fusion boundary: price its data movement
            if not inside_fusion:
                total += Cost(0.0, self._bytes_of(ins, env), 0.0)
        self._memo[key] = total
        return total

    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def analyze(hlo_text: str, *, loops: bool = False) -> dict:
    model = HloCostModel(hlo_text)
    c = model.total()
    out = {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "wire_bytes": c.wire_bytes,
        "collectives": c.coll,
        "warnings": model.warnings[:20],
    }
    if loops:
        out["loops"] = sorted(model.loops,
                              key=lambda d: -d["total_hbm_bytes"])
    return out


def top_instructions(hlo_text: str, comp_name: str, *, by: str = "bytes",
                     n: int = 10) -> List[dict]:
    """Most expensive instructions of one computation (perf-loop drilldown)."""
    model = HloCostModel(hlo_text)
    comp = model.comps.get(comp_name)
    if comp is None:
        return []
    env = model._shapes(comp)
    rows = []
    for ins in comp.instrs:
        if ins.opcode in _FREE_OPS:
            continue
        if ins.opcode == "fusion":
            _, attrs = _operand_names(ins.rest)
            fl = sum(model.cost_of(c, inside_fusion=True).flops
                     for c in _CALL_ATTR_RE.findall(attrs))
        elif ins.opcode == "dot":
            fl = model._flops_of_dot(ins, env)
        else:
            fl = 0.0
        rows.append({"name": ins.name, "op": ins.opcode,
                     "bytes": model._bytes_of(ins, env), "flops": fl,
                     "out": ins.out_type[:60]})
    key = "bytes" if by == "bytes" else "flops"
    return sorted(rows, key=lambda r: -r[key])[:n]
