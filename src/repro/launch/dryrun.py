import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first init, and the production meshes need 512 host devices.

Per cell this produces (results/dryrun/<tag>/<mesh>_<arch>_<shape>.json):
  * compiled.memory_analysis()  -> per-device bytes (proves it fits v5e HBM)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes (roofline terms 1-2)
  * parsed collective bytes     -> roofline term 3 (launch.hlo_analysis)
plus model-analytic params/FLOPs. benchmarks/roofline.py turns these into
the EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--tag baseline] [--set mla_absorbed=True] [--kv-bits 8]
"""
import argparse
import ast
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.registry import ARCH_IDS, get_config
from ..configs.shapes import SHAPES, applicable, decode_specs, input_specs
from ..core.fixedpoint import FixedPointFormat
from ..core.policy import PrecisionPolicy
from ..models.transformer import init_model
from ..optim.adamw import AdamWConfig
from ..parallel.hints import activation_hints
from ..parallel.sharding import (auto_batch_sharding, cache_shardings,
                                 param_shardings, plan_for_mesh,
                                 state_shardings)
from ..quant.apply import build_model_quant, transformer_layer_names
from .hlo_analysis import collective_summary, cost_summary, memory_summary
from .hlo_cost import analyze as hlo_loop_analyze
from .mesh import make_production_mesh
from .steps import (TrainHParams, init_train_state, make_decode_step,
                    make_embed_decode_step, make_prefill_step,
                    make_train_step)


def dryrun_config(cfg, shape):
    """Pod-scale numerics: bf16 params (fp32 master lives in the optimizer
    problem domain; see DESIGN.md §8), chunked CE for train/prefill."""
    return dataclasses.replace(
        cfg, param_dtype="bfloat16",
        loss_chunk=2048 if shape.kind == "train" else 0)


def make_quant(cfg, kv_bits: int):
    if kv_bits <= 0:
        return None
    names = transformer_layer_names(cfg)
    pol = PrecisionPolicy.uniform(
        names, None, FixedPointFormat(2, kv_bits - 2))
    return build_model_quant(pol, cfg, quantize_kv=True,
                             quantize_activations=False,
                             kv_container="int8" if kv_bits <= 8 else "int16")


def lower_cell(cfg, shape, mesh, *, kv_bits: int = 0,
               tp_decode: bool = False):
    """Returns (lowered, aux_info)."""
    plan = plan_for_mesh(mesh)
    quant = make_quant(cfg, kv_bits) if shape.kind == "decode" else None

    if shape.kind == "train":
        hp = TrainHParams(adamw=AdamWConfig(quantize_moments=True))
        state_struct = jax.eval_shape(
            lambda k: init_train_state(k, cfg, hp), jax.random.PRNGKey(0))
        state_sh = state_shardings(state_struct, plan)
        batch = input_specs(cfg, shape)
        batch_sh = auto_batch_sharding(batch, plan)
        step = make_train_step(cfg, hp)
        with activation_hints(plan):
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,)).lower(state_struct, batch)
        return lowered

    params_struct = jax.eval_shape(
        lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    param_sh = param_shardings(params_struct, plan,
                               inference=(tp_decode
                                          and shape.kind == "decode"))

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        batch_sh = auto_batch_sharding(batch, plan)
        step = make_prefill_step(cfg, max_len=shape.seq_len)
        with activation_hints(plan):
            lowered = jax.jit(
                step, in_shardings=(param_sh, batch_sh)).lower(
                params_struct, batch)
        return lowered

    # decode
    specs = decode_specs(cfg, shape, quant=quant)
    caches_sh = cache_shardings(specs["caches"], plan, lead=1)
    tok_sh = auto_batch_sharding(
        {"t": specs.get("tokens", specs.get("embeds"))}, plan)["t"]
    pos_sh = NamedSharding(mesh, P())
    if "embeds" in specs:
        step = make_embed_decode_step(cfg, quant=quant)
        first = specs["embeds"]
    else:
        step = make_decode_step(cfg, quant=quant)
        first = specs["tokens"]
    with activation_hints(plan):
        lowered = jax.jit(
            step,
            in_shardings=(param_sh, tok_sh, pos_sh, caches_sh),
            out_shardings=(None, None, caches_sh),
            donate_argnums=(3,)).lower(
            params_struct, first, specs["pos"], specs["caches"])
    return lowered


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             kv_bits: int = 0, overrides=None, hlo_dir=None,
             tp_decode: bool = False):
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if not applicable(cfg, shape):
        return {"skipped": True, "reason": "not applicable"}
    cfg = dryrun_config(cfg, shape)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "kv_bits": kv_bits,
           "overrides": overrides or {}, "tp_decode": tp_decode,
           "skipped": False}
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, kv_bits=kv_bits,
                         tp_decode=tp_decode)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    rec["memory"] = memory_summary(compiled)
    rec["cost"] = cost_summary(compiled)       # XLA aggregate (loop body x1)
    hlo = compiled.as_text()
    rec["collectives"] = collective_summary(hlo)
    # loop-aware per-device costs: while bodies x known_trip_count — the
    # numbers the roofline terms are built from (see launch.hlo_cost)
    rec["loop_cost"] = hlo_loop_analyze(hlo)
    rec["hlo_bytes"] = len(hlo)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(
                hlo_dir, f"{mesh_kind}_{arch}_{shape_name}.hlo.txt"),
                "w") as f:
            f.write(hlo)

    # analytic model terms for the roofline
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    factor = 6 if shape.kind == "train" else 2
    rec["model"] = {
        "n_params": n_params, "n_active_params": n_active,
        "tokens_per_step": tokens,
        "model_flops": float(factor * n_active * tokens),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--kv-bits", type=int, default=0)
    ap.add_argument("--tp-decode", action="store_true",
                    help="inference TP sharding for decode cells (no FSDP "
                         "weight gathers per token)")
    ap.add_argument("--set", action="append", default=[],
                    metavar="FIELD=PYVALUE", dest="sets")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo-dir", default=None,
                    help="also dump optimized HLO text per cell")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    overrides = {}
    for s in args.sets:
        k, v = s.split("=", 1)
        overrides[k] = ast.literal_eval(v)

    outdir = os.path.join(args.out, args.tag)
    os.makedirs(outdir, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = os.path.join(outdir,
                                    f"{mesh_kind}_{arch}_{shape}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {mesh_kind:6s} {arch} {shape}")
                    continue
                try:
                    rec = run_cell(arch, shape, mesh_kind,
                                   kv_bits=args.kv_bits, overrides=overrides,
                                   hlo_dir=args.hlo_dir,
                                   tp_decode=args.tp_decode)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_kind, repr(e)))
                    continue
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("skipped"):
                    print(f"[n/a ] {mesh_kind:6s} {arch:26s} {shape}")
                else:
                    mem = (rec["memory"].get("argument_size_in_bytes", 0)
                           + rec["memory"].get("temp_size_in_bytes", 0)) \
                        / 2**30
                    lc = rec["loop_cost"]
                    print(f"[ok  ] {mesh_kind:6s} {arch:26s} {shape:12s} "
                          f"dev_mem={mem:7.2f}GiB flops={lc['flops']:.3e} "
                          f"hbm={lc['hbm_bytes']:.3e} "
                          f"wire={lc['wire_bytes'] / 2**20:9.1f}MiB "
                          f"compile={rec['compile_s']:.0f}s")
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
