"""Priority + SLO-aware admission scheduling and preemption policy.

The FIFO ``_admit`` loop of :class:`repro.launch.serve.BatchedServer` has a
head-of-line problem: one request whose page demand cannot be met right now
blocks every request behind it, even ones that would fit immediately. This
module is the POLICY side of the replacement — pure decision functions over
request metadata, no server state mutated — and the server is the MECHANISM
(it executes admissions, demotions and preemptions through the tiered page
store).

Ordering is (priority, deadline, arrival): higher ``Request.priority``
first, then earliest ``deadline_step`` (EDF inside a priority class; a
request without a deadline sorts after every deadlined one), then arrival
order. On top of the ordering:

* **bounded out-of-order admission** — when the queue head must defer for
  pages, up to ``admit_window`` requests past it may still be examined and
  admitted if they fit, so the head blocks the *pages* it is waiting for,
  not the whole queue;
* **preemption** — a queued request strictly more urgent than a running one
  may evict it: the victim's written pages demote to the host tier, the
  victim re-queues (its position in the order is unchanged — it is less
  urgent by construction, so it cannot immediately preempt back), and on
  re-admission its pages promote back and decoding resumes bitwise
  identically (no re-prefill). Victims are chosen least-urgent-first and
  only when the freed pages actually make the preemptor admissible.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Tuple

from ..runtime.telemetry import MetricsRegistry, metric_attr

_NO_DEADLINE = float("inf")


@dataclasses.dataclass(frozen=True)
class SchedPolicy:
    """Knobs for the SLO scheduler.

    ``admit_window``: how many requests past a deferred head admission may
    examine per cycle (0 = strict FIFO order, just priority-sorted).
    ``preempt``: allow evicting running requests for strictly more urgent
    queued ones (needs the host-memory tier to park victim pages).
    ``max_preempt_per_admit``: cap on victims per admission cycle — bounds
    demotion burst latency under adversarial priority traffic.
    """

    admit_window: int = 4
    preempt: bool = True
    max_preempt_per_admit: int = 2


def request_key(req) -> Tuple[int, float, int, int]:
    """Total urgency order: smaller sorts first (more urgent)."""
    deadline = (_NO_DEADLINE if req.deadline_step is None
                else float(req.deadline_step))
    return (-req.priority, deadline, req.arrive_step, req.rid)


class SLOScheduler:
    """Stateless-ish policy object (holds only the knobs + counters)."""

    # registry-backed legacy attribute (see runtime.telemetry.metric_attr)
    ooo_admissions = metric_attr("sched.ooo_admissions")

    def __init__(self, policy: Optional[SchedPolicy] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.policy = policy or SchedPolicy()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.ooo_admissions = 0   # admissions past a deferred head

    def sort_queue(self, queue: List) -> None:
        """Stable-sort the queue most-urgent-first (priority, EDF,
        arrival)."""
        queue.sort(key=request_key)

    def choose_victims(self, req, running: List[Tuple[int, object, int]],
                       shortfall: int, gain: Callable[[int], int],
                       limit: Optional[int] = None) -> List[int]:
        """Pick slots to preempt so ``req`` becomes admissible.

        ``running`` is ``[(slot, request, _)]`` for live slots eligible for
        preemption (the server pre-filters e.g. host-tier room);
        ``shortfall`` is the page deficit after normal reclaim;
        ``gain(slot)`` the device pages a preemption of that slot would
        actually recover (refcount-1 pages + released reservation);
        ``limit`` the admission cycle's REMAINING victim budget (capped by
        ``max_preempt_per_admit`` either way). Only STRICTLY less urgent
        victims are eligible, least urgent first, and the empty list is
        returned unless the accumulated gain covers the shortfall — half a
        preemption buys nothing but churn.
        """
        if not self.policy.preempt:
            return []
        cap = self.policy.max_preempt_per_admit
        if limit is not None:
            cap = min(cap, limit)
        rk = request_key(req)
        eligible = [(slot, r) for slot, r, _ in running
                    if request_key(r) > rk]
        eligible.sort(key=lambda sr: request_key(sr[1]), reverse=True)
        victims, got = [], 0
        for slot, _ in eligible[:cap]:
            victims.append(slot)
            got += gain(slot)
            if got >= shortfall:
                return victims
        return []


# ---------------------------------------------------------------------------
# Telemetry-driven deadline-miss prediction (the PR-9 control loop)
# ---------------------------------------------------------------------------
class DeadlineMissPredictor:
    """Online logistic model over live SLO telemetry, consulted every
    admission cycle to throttle SPECULATIVE work before pressure turns
    into deadline misses.

    Features (normalized to ~[0, 1]; all but the last live on the
    deterministic decode-step clock, and the wall-clock TPOT slowdown is
    pre-clipped small by ``SLOMonitor.tpot_slowdown`` so scheduling
    decisions replay identically run to run):

    * ``queue``    — deadlined requests waiting, / 2·batch
    * ``arrivals`` — per-step arrival-rate EWMA vs batch capacity
    * ``pressure`` — 1 − free-page headroom fraction (after reservations)
    * ``debt``     — queued deadlined prompt tokens vs one cycle's
      prefill capacity (batch · bucket)
    * ``occupancy``— live rows / batch
    * ``tpot``     — observed decode slowdown (wall, clipped ±0.25)

    The initial weights ARE a sensible threshold policy (risk crosses the
    gate once queue + arrival intensity + page pressure outweigh the
    bias), so the gate works from cycle 0; SGD on observed outcomes
    (label: the request retired past its ``deadline_step``) then adapts
    the threshold to the serving point's real capacity.

    The *gate decision* combines instantaneous risk with a peak-hold
    ``hazard`` (decayed per consultation): bursty arrivals cluster, so
    one observed overload episode keeps speculative admission throttled
    across the burst's inter-arrival gaps instead of re-admitting
    throughput traffic into the eye of the next wave. Deadlined requests
    are NEVER gated — the predictor only resizes the speculative share
    of the batch (no-deadline / paused-free rows), which costs those
    requests nothing: they carry no deadline, so goodput counts them
    whenever they finish.
    """

    FEATURES = ("bias", "queue", "arrivals", "pressure", "debt",
                "occupancy", "tpot")

    # registry-backed counters
    updates = metric_attr("sched.predictor_updates")
    gated = metric_attr("sched.predictor_gated")

    def __init__(self, metrics: Optional[MetricsRegistry] = None, *,
                 lr: float = 0.05, gate_at: float = 0.5,
                 hazard_decay: float = 0.98):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.lr = lr
        self.gate_at = gate_at
        self.hazard_decay = hazard_decay
        self.w = [-3.0, 2.5, 2.5, 2.0, 1.0, 1.0, 1.0]
        self.hazard = 0.0
        self.updates = 0
        self.gated = 0
        self._g_risk = self.metrics.gauge("sched.miss_risk")
        self._g_hazard = self.metrics.gauge("sched.miss_hazard")

    def features(self, *, queue_deadlined: int, batch: int,
                 free_frac: float, prefill_debt: int, debt_cap: int,
                 live_frac: float, arrival_ewma: float,
                 tpot_slowdown: float = 0.0) -> List[float]:
        b = max(1, batch)
        return [1.0,
                min(1.0, queue_deadlined / (2.0 * b)),
                min(1.0, 2.0 * arrival_ewma / b),
                min(1.0, max(0.0, 1.0 - free_frac)),
                min(1.0, prefill_debt / max(1, debt_cap)),
                min(1.0, max(0.0, live_frac)),
                max(-0.25, min(0.25, tpot_slowdown))]

    def risk(self, x: List[float]) -> float:
        z = sum(wi * xi for wi, xi in zip(self.w, x))
        return 1.0 / (1.0 + math.exp(-max(-30.0, min(30.0, z))))

    def consult(self, x: List[float]) -> float:
        """Per-cycle entry point: score ``x``, fold into the peak-hold
        hazard, publish both gauges, return the instantaneous risk."""
        r = self.risk(x)
        self.hazard = max(self.hazard * self.hazard_decay, r)
        self._g_risk.set(r)
        self._g_hazard.set(self.hazard)
        return r

    def spec_budget(self, batch: int) -> int:
        """How many NEW speculative (no-deadline) admissions this cycle
        may make — the predictor's batch-resize lever. Full batch below
        the gate, one row in the warning band, zero when the (peak-held)
        hazard says an overload is in progress or imminent."""
        h = max(self.hazard, 0.0)
        if h < self.gate_at:
            return batch
        if h < (1.0 + self.gate_at) / 2.0:
            return 1
        return 0

    def observe(self, x: List[float], missed: bool) -> None:
        """One SGD step on a retired deadlined request's admission-time
        features (label 1 = it missed its deadline)."""
        p = self.risk(x)
        g = (1.0 if missed else 0.0) - p
        self.w = [wi + self.lr * g * xi for wi, xi in zip(self.w, x)]
        self.updates += 1
