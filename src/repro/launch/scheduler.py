"""Priority + SLO-aware admission scheduling and preemption policy.

The FIFO ``_admit`` loop of :class:`repro.launch.serve.BatchedServer` has a
head-of-line problem: one request whose page demand cannot be met right now
blocks every request behind it, even ones that would fit immediately. This
module is the POLICY side of the replacement — pure decision functions over
request metadata, no server state mutated — and the server is the MECHANISM
(it executes admissions, demotions and preemptions through the tiered page
store).

Ordering is (priority, deadline, arrival): higher ``Request.priority``
first, then earliest ``deadline_step`` (EDF inside a priority class; a
request without a deadline sorts after every deadlined one), then arrival
order. On top of the ordering:

* **bounded out-of-order admission** — when the queue head must defer for
  pages, up to ``admit_window`` requests past it may still be examined and
  admitted if they fit, so the head blocks the *pages* it is waiting for,
  not the whole queue;
* **preemption** — a queued request strictly more urgent than a running one
  may evict it: the victim's written pages demote to the host tier, the
  victim re-queues (its position in the order is unchanged — it is less
  urgent by construction, so it cannot immediately preempt back), and on
  re-admission its pages promote back and decoding resumes bitwise
  identically (no re-prefill). Victims are chosen least-urgent-first and
  only when the freed pages actually make the preemptor admissible.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from ..runtime.telemetry import MetricsRegistry, metric_attr

_NO_DEADLINE = float("inf")


@dataclasses.dataclass(frozen=True)
class SchedPolicy:
    """Knobs for the SLO scheduler.

    ``admit_window``: how many requests past a deferred head admission may
    examine per cycle (0 = strict FIFO order, just priority-sorted).
    ``preempt``: allow evicting running requests for strictly more urgent
    queued ones (needs the host-memory tier to park victim pages).
    ``max_preempt_per_admit``: cap on victims per admission cycle — bounds
    demotion burst latency under adversarial priority traffic.
    """

    admit_window: int = 4
    preempt: bool = True
    max_preempt_per_admit: int = 2


def request_key(req) -> Tuple[int, float, int, int]:
    """Total urgency order: smaller sorts first (more urgent)."""
    deadline = (_NO_DEADLINE if req.deadline_step is None
                else float(req.deadline_step))
    return (-req.priority, deadline, req.arrive_step, req.rid)


class SLOScheduler:
    """Stateless-ish policy object (holds only the knobs + counters)."""

    # registry-backed legacy attribute (see runtime.telemetry.metric_attr)
    ooo_admissions = metric_attr("sched.ooo_admissions")

    def __init__(self, policy: Optional[SchedPolicy] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.policy = policy or SchedPolicy()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.ooo_admissions = 0   # admissions past a deferred head

    def sort_queue(self, queue: List) -> None:
        """Stable-sort the queue most-urgent-first (priority, EDF,
        arrival)."""
        queue.sort(key=request_key)

    def choose_victims(self, req, running: List[Tuple[int, object, int]],
                       shortfall: int, gain: Callable[[int], int],
                       limit: Optional[int] = None) -> List[int]:
        """Pick slots to preempt so ``req`` becomes admissible.

        ``running`` is ``[(slot, request, _)]`` for live slots eligible for
        preemption (the server pre-filters e.g. host-tier room);
        ``shortfall`` is the page deficit after normal reclaim;
        ``gain(slot)`` the device pages a preemption of that slot would
        actually recover (refcount-1 pages + released reservation);
        ``limit`` the admission cycle's REMAINING victim budget (capped by
        ``max_preempt_per_admit`` either way). Only STRICTLY less urgent
        victims are eligible, least urgent first, and the empty list is
        returned unless the accumulated gain covers the shortfall — half a
        preemption buys nothing but churn.
        """
        if not self.policy.preempt:
            return []
        cap = self.policy.max_preempt_per_admit
        if limit is not None:
            cap = min(cap, limit)
        rk = request_key(req)
        eligible = [(slot, r) for slot, r, _ in running
                    if request_key(r) > rk]
        eligible.sort(key=lambda sr: request_key(sr[1]), reverse=True)
        victims, got = [], 0
        for slot, _ in eligible[:cap]:
            victims.append(slot)
            got += gain(slot)
            if got >= shortfall:
                return victims
        return []
