"""Production mesh construction (dry-run target: TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py forces
512 host devices via XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model single pod; (2,16,16) pod x data x model for the
    2-pod = 512-chip configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has, as a 1-D data mesh (real training on
    this container: 1 CPU device)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small fake mesh for subprocess-based distribution tests."""
    return jax.make_mesh(shape, axes)


def make_serving_mesh(tp: int = 1):
    """Mesh for one tensor-parallel serving replica: ("data", "model") =
    (n_devices // tp, tp). The serving path shards attention heads and the
    paged KV pool over "model" only (parallel.sharding.paged_pool_shardings);
    "data" stays size n//tp so the same plan_for_mesh rules apply. CI runs
    this on virtual host devices via XLA_FLAGS=--xla_force_host_platform_
    device_count — on the real pod, tp divides the chips of one replica."""
    n = len(jax.devices())
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if n % tp != 0:
        raise ValueError(f"tp={tp} does not divide {n} visible devices")
    return jax.make_mesh((n // tp, tp), ("data", "model"))
