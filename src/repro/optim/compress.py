"""Gradient compression: int8 fixed-point ring all-reduce + error feedback.

The paper cites Seide et al. (2014) 1-bit SGD as the communication-side
motivation for reduced precision; this module is that idea built on JAX
collectives so the wire dtype is REALLY int8 (visible in the lowered HLO and
priced by the roofline's collective term):

* ``quantized_allreduce(x, axis_name)`` — inside ``shard_map``: a
  reduce-scatter ring over ``lax.ppermute`` whose hops carry int8 payloads
  (fp32 accumulation, re-quantized per hop), then an int8 all-gather ring.
  N-1 + N-1 hops of (elems/N) int8 — 4x less ICI traffic than an fp32 ring.
* error feedback: the quantization residual of each step is carried in the
  train state and added back before the next compression (bounds the bias;
  standard EF-SGD result).

``compress_gradients`` is the drop-in used by the explicit-DP trainer; the
pjit trainer keeps XLA's native all-reduce (see DESIGN.md §4: compression is
an opt-in feature flag, ``--grad-compress``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    bits: int = 8
    error_feedback: bool = True


def _q_encode(x, bits: int):
    """Symmetric per-tensor absmax fixed-point; returns (int8/int16, scale)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / qmax
    dtype = jnp.int8 if bits <= 8 else jnp.int16
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(dtype)
    return q, scale


def _q_decode(q, scale):
    return q.astype(jnp.float32) * scale


def quantized_allreduce(x, axis_name: str, *, bits: int = 8,
                        mean: bool = True):
    """Ring all-reduce with int-quantized hops. Call inside shard_map.

    x: identically-shaped per-device fp32 array (leading dim divisible by the
    axis size). Returns the (approximately) all-reduced array.
    """
    # psum of a python scalar folds to the static axis size on every jax we
    # support (lax.axis_size only exists in newer releases)
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    if n == 1:
        return x
    orig_shape = x.shape
    pad = (-x.size) % n
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(n, -1)

    fwd = [(i, (i + 1) % n) for i in range(n)]

    # --- reduce-scatter ring: after n-1 hops, device d owns the full sum of
    # chunk (d+1) % n.  Hop payloads are quantized.
    def rs_body(i, acc):
        # send chunk (idx - i) mod n, receive into chunk (idx - i - 1) mod n
        send_c = (idx - i) % n
        recv_c = (idx - i - 1) % n
        payload = jnp.take(acc, send_c, axis=0)
        q, s = _q_encode(payload, bits)
        q_r = jax.lax.ppermute(q, axis_name, fwd)
        s_r = jax.lax.ppermute(s, axis_name, fwd)
        contrib = _q_decode(q_r, s_r)
        return acc.at[recv_c].add(contrib)

    acc = jax.lax.fori_loop(0, n - 1, rs_body, xf)
    own = (idx + 1) % n  # fully-reduced chunk this device owns

    # --- all-gather ring: circulate the owned (quantized) chunk.
    def ag_body(i, st):
        out, q, s = st
        q = jax.lax.ppermute(q, axis_name, fwd)
        s = jax.lax.ppermute(s, axis_name, fwd)
        src = (own - i - 1) % n   # whose chunk just arrived
        out = out.at[src].set(_q_decode(q, s))
        return out, q, s

    q0, s0 = _q_encode(jnp.take(acc, own, axis=0), bits)
    out0 = jnp.zeros_like(xf).at[own].set(_q_decode(q0, s0))
    out, _, _ = jax.lax.fori_loop(0, n - 1, ag_body, (out0, q0, s0))

    res = out.reshape(-1)
    if pad:
        res = res[:-pad]
    res = res.reshape(orig_shape)
    return res / n if mean else res


# ---------------------------------------------------------------------------
# Error feedback (per-leaf residual carried in the train state)
# ---------------------------------------------------------------------------
def error_feedback_init(grads_like):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress_gradients(grads, residual, cfg: CompressionConfig):
    """Simulated-wire compression for the pjit path: quantize (grad +
    residual), keep the quantization error as the next residual.

    Returns (compressed_grads fp32-valued-on-grid, new_residual). The wire
    quantization here is the same Q used by ``quantized_allreduce``; in the
    pjit trainer XLA still all-reduces fp32 values that lie ON the int grid,
    so accuracy effects are faithful while staying a single-jit program.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + (r if cfg.error_feedback else 0.0)
        q, s = _q_encode(gf, cfg.bits)
        deq = _q_decode(q, s)
        return deq.astype(g.dtype), (gf - deq)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_res = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return comp, new_res
