from .adamw import AdamWConfig, adamw_init, adamw_update
from .schedule import constant_lr, cosine_warmup, linear_warmup
from .compress import (CompressionConfig, error_feedback_init,
                       quantized_allreduce, compress_gradients)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "constant_lr", "cosine_warmup", "linear_warmup",
           "CompressionConfig", "error_feedback_init",
           "quantized_allreduce", "compress_gradients"]
