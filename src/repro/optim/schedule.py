"""LR schedules as pure functions of the (traced) step."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.float32(lr)


def linear_warmup(lr: float, warmup: int):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        return jnp.float32(lr) * jnp.minimum(1.0, (s + 1.0) / max(warmup, 1))
    return f


def cosine_warmup(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = jnp.minimum(1.0, (s + 1.0) / max(warmup, 1))
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * warm * cos
    return f
