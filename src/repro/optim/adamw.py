"""AdamW with optionally int8-quantized moment state ("bounded memory" for
the optimizer, the paper's idea applied to training state).

Moment quantization (beyond-paper, motivated by DESIGN.md §3 table):
  * first moment m: signed int8 grid, per-row absmax scale,
  * second moment v: non-negative; stored as int8 of sqrt(v) (halves the
    dynamic range the grid must cover), per-row absmax scale.
Scales live on the last-but-one axes (one scale per row of the last dim);
1-D leaves get a single per-tensor scale. All updates compute in fp32.

With ``quantize_moments=False`` this is a plain fp32 AdamW — the default for
accuracy-sensitive runs; the quantized variant trades a bounded (~1e-3
relative) moment error for 4x optimizer-state footprint.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    quantize_moments: bool = False


# ---------------------------------------------------------------------------
# int8 moment container
# ---------------------------------------------------------------------------
def _q8_encode(x):
    """fp32 -> (int8 q, fp32 scale). Per-row absmax over the last dim."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _q8_decode(q, scale):
    return q.astype(jnp.float32) * scale


def _encode_moment(x, signed_sqrt: bool):
    if signed_sqrt:
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    return _q8_encode(x)


def _decode_moment(q, scale, signed_sqrt: bool):
    x = _q8_decode(q, scale)
    if signed_sqrt:
        x = x * x
    return x


def adamw_init(params, cfg: AdamWConfig):
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    if not cfg.quantize_moments:
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(zeros_like_f32, params),
            "v": jax.tree_util.tree_map(zeros_like_f32, params),
        }

    def zq(p):
        q, s = _q8_encode(jnp.zeros(p.shape, jnp.float32))
        return {"q": q, "scale": s}

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zq, params),
        "v": jax.tree_util.tree_map(zq, params),
    }


def _global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, lr, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-12))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m_c, v_c):
        g = g.astype(jnp.float32) * clip
        if cfg.quantize_moments:
            m = _decode_moment(m_c["q"], m_c["scale"], False)
            v = _decode_moment(v_c["q"], v_c["scale"], True)
        else:
            m, v = m_c, v_c
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = (p.astype(jnp.float32) - lr * (delta + wd)).astype(p.dtype)
        if cfg.quantize_moments:
            mq, ms = _encode_moment(m, False)
            vq, vs = _encode_moment(v, True)
            return newp, {"q": mq, "scale": ms}, {"q": vq, "scale": vs}
        return newp, m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "clip": clip}
