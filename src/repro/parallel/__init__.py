from .sharding import (MeshPlan, auto_batch_sharding, cache_shardings,
                       param_shardings, plan_for_mesh)

__all__ = ["MeshPlan", "auto_batch_sharding", "cache_shardings",
           "param_shardings", "plan_for_mesh"]
