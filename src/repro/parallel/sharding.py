"""Logical-axis sharding rules (MaxText-style) -> NamedSharding per leaf.

Production mesh axes (launch.mesh):
  single pod : ("data", "model") = (16, 16)
  multi-pod  : ("pod", "data", "model") = (2, 16, 16)

Logical plan (DESIGN.md §4):
  * params: FSDP over "data" on the embed/reduction dim, TP over "model" on
    heads/ffn/vocab dims; experts EP over "model". Replicated over "pod"
    (cross-pod traffic = gradient all-reduce only, the classic multi-pod DP
    design — DCN-friendly).
  * batch dims of activations/inputs: ("pod", "data").
  * KV/state caches: heads (or latent/head_dim fallback) over "model",
    batch over ("pod", "data") when divisible.

Every assignment is divisibility-checked: a dim that doesn't divide by the
mesh axis stays unsharded rather than failing to lower (e.g. hubert's
vocab=504 head). Rules are ordered regex -> logical axes for the TRAILING
dims; leading stacked dims (scan: (periods, ...)) are never sharded.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    mesh: Mesh
    batch_axes: Tuple[str, ...]     # ("pod", "data") or ("data",)
    fsdp_axis: Optional[str]        # "data"
    model_axis: Optional[str]       # "model"

    @property
    def batch_size_divisor(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def axis_size(self, logical: Optional[str]) -> int:
        if logical is None:
            return 1
        if logical == "batch":
            return self.batch_size_divisor
        return int(self.mesh.shape[logical])

    def mesh_axes(self, logical: Optional[str]):
        if logical == "batch":
            return self.batch_axes
        return logical


def plan_for_mesh(mesh: Mesh) -> MeshPlan:
    names = mesh.axis_names
    model = "model" if "model" in names else None
    if "pod" in names:
        return MeshPlan(mesh, ("pod", "data"), "data", model)
    if "data" in names:
        return MeshPlan(mesh, ("data",), "data", model)
    # single-axis test meshes
    ax = names[0]
    return MeshPlan(mesh, (ax,), None, None)


# ---------------------------------------------------------------------------
# Param rules: (path regex, logical axes for trailing dims).
# logical: "fsdp" -> data, "tp" -> model, "ep" -> model (expert dim), None.
# ---------------------------------------------------------------------------
_PARAM_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    (r"embed/table$",            ("fsdp", "tp")),
    (r"head/kernel$",            ("fsdp", "tp")),
    (r"(mixer|block)/w[qkv]$",   ("fsdp", "tp")),
    (r"(mixer|block)/b[qkv]$",   ("tp",)),
    (r"(mixer|block)/wo$",       ("tp", "fsdp")),
    (r"wq_a$",                   ("fsdp", "tp")),
    (r"wq_b$",                   ("fsdp", "tp")),
    (r"wkv_a$",                  ("fsdp", "tp")),
    (r"wkv_b$",                  ("fsdp", "tp")),
    (r"ffn/router$",             ("fsdp", None)),
    # routed experts: EP over model x ZeRO-3 over data on the F dim. The
    # shard_map dispatch (models.moe._sharded_dispatch) all-gathers each
    # layer's F-shards over "data" right before use (transient, freed after
    # the layer) — storage is E/tp x F/dp per device, compute is local.
    (r"experts/w_gate$",         ("ep", None, "fsdp")),
    (r"experts/w_up$",           ("ep", None, "fsdp")),
    (r"experts/w_down$",         ("ep", "fsdp", None)),
    # shared expert / dense mlp (2-D)
    (r"(shared|ffn)/w_gate$",    ("fsdp", "tp")),
    (r"(shared|ffn)/w_up$",      ("fsdp", "tp")),
    (r"(shared|ffn)/w_down$",    ("tp", "fsdp")),
    (r"ffn/w_in$",               ("fsdp", "tp")),
    (r"ffn/w_out$",              ("tp", "fsdp")),
    (r"ffn/b_in$",               ("tp",)),
    (r"ffn/b_out$",              (None,)),
    # SSM / recurrent
    (r"mixer/in_proj$",          ("fsdp", "tp")),
    (r"mixer/out_proj$",         ("tp", "fsdp")),
    (r"mixer/up_proj$",          ("fsdp", "tp")),
    (r"mixer/down_proj$",        ("tp", "fsdp")),
    (r"mixer/conv_w$",           (None, "tp")),
    (r"mixer/w_[if]$",           ("fsdp", None)),
    (r"mixer/r$",                (None, None, "tp")),
    (r"mixer/w_in$",             ("fsdp", "tp")),
    (r"mtp/proj$",               ("fsdp", "tp")),
)

_LOGICAL_TO_KIND = {"fsdp": "fsdp", "tp": "model", "ep": "model"}


def _leaf_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_for_leaf(path_str: str, shape, plan: MeshPlan,
                   *, inference: bool = False) -> P:
    ndim = len(shape)
    trailing = None
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path_str):
            trailing = axes
            break
    if trailing is None:
        # generic fallback: 2-D+ leaves get FSDP x TP, 1-D replicated
        trailing = ("fsdp", "tp") if ndim >= 2 else (None,)
    if inference:
        # decode-serving mode: weights TP-only (resident, model-sharded),
        # replicated over "data" — FSDP weight-gathers per decoded token
        # would dominate the step (see EXPERIMENTS.md §Perf).
        trailing = tuple(None if t == "fsdp" else t for t in trailing)
    k = min(len(trailing), ndim)
    trailing = trailing[-k:]
    lead = ndim - k
    spec = [None] * lead
    used = set()
    for dim_axis, logical in zip(range(lead, ndim), trailing):
        if logical is None:
            spec.append(None)
            continue
        mesh_axis = (plan.fsdp_axis if logical == "fsdp" else plan.model_axis)
        if mesh_axis is None or mesh_axis in used:
            spec.append(None)
            continue
        if shape[dim_axis] % plan.mesh.shape[mesh_axis] != 0:
            spec.append(None)   # divisibility fallback: replicate this dim
            continue
        used.add(mesh_axis)
        spec.append(mesh_axis)
    return P(*spec)


def param_shardings(params, plan: MeshPlan, *, inference: bool = False):
    """Pytree of NamedSharding matching ``params`` (works on ShapeDtypeStructs
    or concrete arrays). ``inference=True`` = TP-only (no FSDP gathers)."""
    def f(path, leaf):
        ps = _leaf_path_str(path)
        return NamedSharding(plan.mesh, _spec_for_leaf(ps, leaf.shape, plan,
                                                       inference=inference))
    return jax.tree_util.tree_map_with_path(f, params)


def state_shardings(state, plan: MeshPlan):
    """Shardings for a full train state {params, opt{step,m,v}, ...}.

    Optimizer moments mirror the param tree (the path rules match through the
    ``opt/m/...`` prefix since rules anchor on suffixes). Quantized moments
    ({q, scale}) shard ``q`` like the param and ``scale`` like the param with
    its last dim replicated (scale shape (..., 1) never divides anyway)."""
    def f(path, leaf):
        ps = _leaf_path_str(path)
        if ps.endswith("/q"):
            ps = ps[:-2]
        elif ps.endswith("/scale") and not ps.endswith("norm/scale"):
            ps = ps[:-6]
        return NamedSharding(plan.mesh, _spec_for_leaf(ps, leaf.shape, plan))
    return jax.tree_util.tree_map_with_path(f, state)


# ---------------------------------------------------------------------------
# Batch / activation shardings
# ---------------------------------------------------------------------------
def _batch_axes_for(plan: MeshPlan, size: int):
    """Largest prefix/suffix combination of batch axes that divides size."""
    if size % plan.batch_size_divisor == 0:
        return plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
    for ax in plan.batch_axes[::-1]:     # try "data" alone, then "pod"
        if size % plan.mesh.shape[ax] == 0:
            return ax
    return None


def auto_batch_sharding(batch, plan: MeshPlan):
    """Inputs: dim 0 = batch -> ("pod","data") (divisibility-checked);
    scalars replicated. Used for tokens/labels/embeds/positions."""
    def f(leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return NamedSharding(plan.mesh, P())
        spec = [None] * len(leaf.shape)
        spec[0] = _batch_axes_for(plan, leaf.shape[0])
        return NamedSharding(plan.mesh, P(*spec))
    return jax.tree_util.tree_map(f, batch)


def cache_shardings(caches, plan: MeshPlan, *, lead: int = 1):
    """KV / SSM-state cache shardings.

    ``lead`` = number of stacked scan dims before the batch dim (init_cache
    stacks each pattern position's cache as (periods, B, ...), so lead=1).

    Core-shape patterns after the lead dims:
      kv     : (B, T, KV, hd)    -> batch dp, KV over model (fallback: hd)
      latent : (B, T, W)         -> batch dp, W over model
      ssm    : (B, nh, N, P)     -> batch dp, nh over model (fallback: N/P)
      conv   : (B, k-1, di)      -> batch dp, di over model
      mlstm C: (B, nh, dk, dv+1) -> batch dp, nh over model (fallback: dk)
      m/n/h  : (B, nh[, hd])     -> batch dp, nh over model
    Structural rule: batch dim (index ``lead``) over dp axes, then the first
    dim from index lead+2 onward divisible by "model" (skipping the time/seq
    dim right after batch, which dynamic_update_slice writes into); fall back
    to the time dim last.
    """
    model = plan.model_axis
    msize = plan.mesh.shape[model] if model else 1

    # recurrent-state leaves have a heads dim right after batch (no time dim)
    _STATE_KEYS = {"ssm", "C", "h", "c", "n", "m"}

    def f(path, leaf):
        shape = leaf.shape
        ndim = len(shape)
        if ndim <= lead:
            return NamedSharding(plan.mesh, P())
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        spec = [None] * ndim
        b_idx = lead
        spec[b_idx] = _batch_axes_for(plan, shape[b_idx])
        if model is not None:
            if key in _STATE_KEYS:
                cand = list(range(b_idx + 1, ndim))      # nh first
            else:
                cand = list(range(b_idx + 2, ndim)) + \
                    ([b_idx + 1] if b_idx + 1 < ndim else [])
            for i in cand:
                if spec[i] is None and shape[i] % msize == 0 \
                        and shape[i] >= msize:
                    spec[i] = model
                    break
        return NamedSharding(plan.mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, caches)


def paged_pool_shardings(caches, plan: MeshPlan):
    """Shardings for a PAGED serving cache (core.paged_kv.init_paged_pool).

    Per-layer pools are dicts ``{k_pages, v_pages, k_scale, v_scale}``;
    page grids are ``(NP, ps, KV, hdw)`` or scan-stacked
    ``(periods, NP, ps, KV, hdw)``. The KV-heads axis (always ndim-2)
    shards over "model" — tensor-parallel attention heads, matching the
    TP-only inference weight plan. This covers every container uniformly:
    int4 lane-packing runs along the last (head_dim) axis, so a head-axis
    shard keeps each page's packed lanes whole, and per-head page bytes
    stay shard-local so host extract/inject round-trips remain byte-exact.
    Nothing shards over the data axes — pages are shared by all slots, and
    replicas are separate pools addressed by (replica, page) handles, not
    dp shards of one pool. Per-page scales ``(NP,)`` (and any non-pool
    leaf) replicate; non-dividing head counts fall back to replication
    like every other rule in this module."""
    model = plan.model_axis
    msize = plan.mesh.shape[model] if model else 1

    def f(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        spec = [None] * len(shape)
        if key in ("k_pages", "v_pages") and len(shape) >= 4 \
                and model is not None and shape[-2] % msize == 0 \
                and shape[-2] >= msize:
            spec[len(shape) - 2] = model
        return NamedSharding(plan.mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, caches)
