"""Activation-sharding hints: model code declares LOGICAL axes; a launcher
activates a mesh mapping and the hints become with_sharding_constraint.

With no active mapping (unit tests, single-CPU training) ``constrain`` is an
exact no-op, so model code stays mesh-free.

Logical names:
  "dp" -> the batch axes ("pod","data");  "tp"/"ep" -> "model";  None -> skip.
Every assignment is divisibility-checked like parallel.sharding.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .sharding import MeshPlan

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_hints", default=None)


@contextlib.contextmanager
def activation_hints(plan: MeshPlan):
    token = _ACTIVE.set(plan)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def _resolve(plan: MeshPlan, logical: Optional[str], dim: int):
    if logical is None:
        return None
    if logical == "dp":
        axes = plan.batch_axes
        size = plan.batch_size_divisor
        if dim % size == 0:
            return axes if len(axes) > 1 else axes[0]
        # fall back to the inner data axis alone
        for ax in axes[::-1]:
            if dim % plan.mesh.shape[ax] == 0:
                return ax
        return None
    ax = plan.model_axis if logical in ("tp", "ep") else None
    if ax is None:
        return None
    return ax if dim % plan.mesh.shape[ax] == 0 else None


def active_plan() -> Optional[MeshPlan]:
    """The MeshPlan installed by activation_hints, or None (no mesh)."""
    return _ACTIVE.get()


def model_shards(dim: int) -> int:
    """How many ways ``dim`` is sharded over the model axis under the active
    plan (1 when no plan / not divisible). Used by MoE dispatch to pick
    block-local cumsum granularity."""
    plan = _ACTIVE.get()
    if plan is None or plan.model_axis is None:
        return 1
    n = plan.mesh.shape[plan.model_axis]
    return n if dim % n == 0 else 1


def constrain(x, *logical):
    """x: array; logical: one entry per dim ("dp" | "tp" | "ep" | None)."""
    plan: Optional[MeshPlan] = _ACTIVE.get()
    if plan is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    used, spec = set(), []
    for dim, name in zip(x.shape, logical):
        ax = _resolve(plan, name, dim)
        key = tuple(ax) if isinstance(ax, (tuple, list)) else ax
        if ax is None or key in used:
            spec.append(None)
        else:
            used.add(key)
            spec.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(*spec)))
