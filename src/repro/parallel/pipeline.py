"""Pipeline parallelism: GPipe schedule over a "stage" mesh axis.

Optional at the default production mesh (2-axis DP x TP suits v5e's 2-D
torus); provided for clusters where an extra "stage" axis wins — e.g. very
deep dense models on elongated slices — and as the PP building block the
assignment asks for.

Implementation: ``shard_map`` over ("stage",); stage s holds the stacked
params of its layer range. The classic GPipe loop runs T = M + S - 1 ticks;
at tick t, stage s computes microbatch (t - s) if 0 <= t - s < M, then the
activation ring advances one hop via ``lax.ppermute``. Bubble fraction =
(S-1)/(M+S-1), reported by ``pipeline_bubble``.

The loop is a ``lax.scan`` over ticks; per-tick activations are a single
(microbatch, ...) block, so the HLO stays O(1) in both S and M. Collective
cost: (S-1+M-1) hops x activation bytes — priced in the roofline's
collective term when enabled.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_bubble(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def gpipe_apply(stage_fn: Callable, stage_params, x_mb, *, mesh: Mesh,
                axis: str = "stage"):
    """Run x through S stages of ``stage_fn`` with the GPipe schedule.

    stage_fn(params_s, x) -> y, applied per stage (already vmapped over the
    stage's own layers if it holds several).
    stage_params: pytree with leading (S,) dim (stacked per-stage params).
    x_mb: (M, mb, ...) microbatched input, replicated across stages.
    Returns (M, mb, ...) outputs (as produced by the LAST stage).
    """
    S = mesh.shape[axis]
    M = x_mb.shape[0]
    T = M + S - 1

    def per_stage(params_s, x_all):
        # params_s: this stage's params (lead dim stripped by shard_map);
        # x_all: (M, mb, ...) full input (replicated); only stage 0 uses it.
        sid = jax.lax.axis_index(axis)
        params_s = jax.tree_util.tree_map(lambda a: a[0], params_s)
        mb_shape = x_all.shape[1:]
        buf = jnp.zeros((M,) + mb_shape, x_all.dtype)  # outputs of this stage

        def tick(carry, t):
            buf, inflight = carry
            # stage 0 ingests microbatch t; others take the ring payload
            mb_idx = t - sid
            x_in = jnp.where(
                sid == 0,
                x_all[jnp.clip(t, 0, M - 1)],
                inflight)
            active = (mb_idx >= 0) & (mb_idx < M)
            y = stage_fn(params_s, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            buf = jax.lax.cond(
                active,
                lambda b: jax.lax.dynamic_update_slice_in_dim(
                    b, y[None], jnp.clip(mb_idx, 0, M - 1), axis=0),
                lambda b: b, buf)
            # advance ring: stage s -> s+1 (last stage's output drops off)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            return (buf, nxt), None

        inflight0 = jnp.zeros(mb_shape, x_all.dtype)
        (buf, _), _ = jax.lax.scan(tick, (buf, inflight0), jnp.arange(T))
        # only the LAST stage's buffer is the model output; broadcast it
        out = jax.lax.psum(
            jnp.where(sid == S - 1, buf, jnp.zeros_like(buf)), axis)
        return out

    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(P(axis), P()),
                   out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_mb)
