"""Procedural image datasets (offline container: no MNIST/CIFAR files).

``digits_dataset`` renders 10 digit glyphs (7x5 bitmaps) onto 28x28 canvases
with per-sample affine jitter (shift/scale) + pixel noise — a MNIST stand-in
with a real accuracy signal (LeNet reaches >95% top-1 in ~1 min on CPU).

``shapes32_dataset`` renders 10 colored-shape classes on textured 32x32x3
canvases — the CIFAR10 stand-in for Convnet/AlexNet-small.

Pure numpy, fully determined by ``seed``.
"""
from __future__ import annotations

import numpy as np

_GLYPHS = [
    # 7 rows x 5 cols, digits 0-9
    ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],  # 0
    ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],  # 1
    ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],  # 2
    ["01110", "10001", "00001", "00110", "00001", "10001", "01110"],  # 3
    ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],  # 4
    ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],  # 5
    ["01110", "10000", "10000", "11110", "10001", "10001", "01110"],  # 6
    ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],  # 7
    ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],  # 8
    ["01110", "10001", "10001", "01111", "00001", "00001", "01110"],  # 9
]


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _GLYPHS[d]], np.float32)


def _render_digit(rng: np.random.Generator, d: int, size: int = 28):
    g = _glyph_array(d)  # (7,5)
    scale = rng.uniform(2.2, 3.2)
    gh, gw = int(7 * scale), int(5 * scale)
    ys = (np.arange(gh) / scale).astype(int).clip(0, 6)
    xs = (np.arange(gw) / scale).astype(int).clip(0, 4)
    big = g[np.ix_(ys, xs)]
    canvas = np.zeros((size, size), np.float32)
    oy = rng.integers(1, size - gh - 1) if size - gh - 2 > 1 else 1
    ox = rng.integers(1, size - gw - 1) if size - gw - 2 > 1 else 1
    canvas[oy:oy + gh, ox:ox + gw] = big
    # stroke-intensity jitter + blur-ish neighborhood + noise
    canvas *= rng.uniform(0.7, 1.0)
    canvas += rng.normal(0.0, 0.08, canvas.shape).astype(np.float32)
    return canvas.clip(0.0, 1.0)


def digits_dataset(n: int, seed: int = 0):
    """Returns (images (n,28,28,1) f32 in [0,1], labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.int32)
    imgs = np.stack([_render_digit(rng, int(d)) for d in labels])
    return imgs[..., None].astype(np.float32), labels


# ---------------------------------------------------------------------------
# 32x32x3 shapes (CIFAR stand-in): class = (shape kind, hue family)
# ---------------------------------------------------------------------------
def _draw_shape(rng, kind: int, size: int = 32):
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    cy = rng.uniform(10, size - 10)
    cx = rng.uniform(10, size - 10)
    r = rng.uniform(5, 9)
    if kind == 0:      # disk
        m = ((yy - cy) ** 2 + (xx - cx) ** 2) < r * r
    elif kind == 1:    # square
        m = (np.abs(yy - cy) < r) & (np.abs(xx - cx) < r)
    elif kind == 2:    # diamond
        m = (np.abs(yy - cy) + np.abs(xx - cx)) < r * 1.3
    elif kind == 3:    # ring
        d2 = (yy - cy) ** 2 + (xx - cx) ** 2
        m = (d2 < r * r) & (d2 > (0.55 * r) ** 2)
    else:              # cross
        m = ((np.abs(yy - cy) < r * 0.35) & (np.abs(xx - cx) < r)) | \
            ((np.abs(xx - cx) < r * 0.35) & (np.abs(yy - cy) < r))
    return m.astype(np.float32)


_HUES = [(1.0, 0.2, 0.2), (0.2, 0.6, 1.0)]  # warm / cold


def shapes32_dataset(n: int, seed: int = 0):
    """10 classes = 5 shapes x 2 hue families. Returns ((n,32,32,3), (n,))."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.int32)
    imgs = np.empty((n, 32, 32, 3), np.float32)
    for i, lab in enumerate(labels):
        kind, hue = int(lab) % 5, int(lab) // 5
        bg = rng.uniform(0.0, 0.35) + \
            rng.normal(0, 0.06, (32, 32, 3)).astype(np.float32)
        m = _draw_shape(rng, kind)
        col = np.array(_HUES[hue], np.float32) * rng.uniform(0.7, 1.0)
        img = bg + m[..., None] * col[None, None, :]
        imgs[i] = img.clip(0.0, 1.0)
    return imgs, labels
