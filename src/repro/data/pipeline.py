"""Device-feeding data pipeline: sharded, prefetching, checkpointable.

Design points for pod scale (DESIGN.md §4):

* **Stateless batches**: a batch is a pure function of (config, step)
  (see data.lm). The pipeline's full state is ONE integer — the step — so
  checkpoint/restore and elastic re-sharding are exact and free. A real
  corpus reader drops in by implementing ``batch_fn(step)`` with the same
  contract (e.g. deterministic shuffle + skip).
* **Sharding**: batches are placed with the train step's input sharding
  (batch axis over ("pod","data")) before dispatch, so host->device transfer
  overlaps the previous step's compute.
* **Prefetch**: a depth-``prefetch`` queue of already-placed batches.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Optional

import jax


@dataclasses.dataclass
class PipelineConfig:
    prefetch: int = 2


class DataPipeline:
    def __init__(self, batch_fn: Callable[[int], dict],
                 *, sharding=None, cfg: Optional[PipelineConfig] = None,
                 start_step: int = 0):
        self._batch_fn = batch_fn
        self._sharding = sharding
        self._cfg = cfg or PipelineConfig()
        self._step = start_step
        self._queue: collections.deque = collections.deque()

    # -- checkpointable state -------------------------------------------------
    @property
    def state(self) -> dict:
        return {"step": self._step}

    def restore(self, state: dict):
        self._step = int(state["step"])
        self._queue.clear()

    # -- iteration --------------------------------------------------------------
    def _produce(self):
        batch = self._batch_fn(self._step)
        if self._sharding is not None:
            batch = jax.device_put(batch, self._sharding)
        self._queue.append(batch)
        self._step += 1

    def __next__(self):
        while len(self._queue) <= self._cfg.prefetch:
            self._produce()
        return self._queue.popleft()

    def __iter__(self):
        return self
