"""Synthetic LM corpus: a mixture of order-2 Markov chains over the vocab.

Gives a *learnable* next-token structure (per-mixture bigram->token tables
with Zipf-ish marginals), so a ~100M-param model shows a real, monotonically
falling loss curve — the end-to-end training example needs a true signal, not
uniform noise. Entirely procedural and seed-deterministic; batches are a pure
function of (config, step), which is what makes the data pipeline trivially
checkpointable and elastic (see data.pipeline).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    num_mixtures: int = 4
    branching: int = 32     # candidate next-tokens per (prev, cur) state
    seed: int = 1234


def _tables(cfg: LMDataConfig):
    """Per-mixture transition tables, built once (numpy, deterministic)."""
    rng = np.random.default_rng(cfg.seed)
    V, K, B = cfg.vocab_size, cfg.num_mixtures, cfg.branching
    # hash-based sparse successor sets: state -> B candidate tokens
    a = rng.integers(1, 2**31 - 1, size=(K,), dtype=np.int64)
    b = rng.integers(1, 2**31 - 1, size=(K,), dtype=np.int64)
    probs = rng.dirichlet(np.full(B, 0.5), size=K).astype(np.float32)
    return a, b, probs


@partial(jax.jit, static_argnums=(0,))
def lm_batch(cfg: LMDataConfig, step):
    """Batch for ``step``: {"tokens": (B,S), "labels": (B,S)} int32.

    labels[t] = tokens[t+1]; final label -100 (ignored by cross_entropy).
    """
    a_np, b_np, probs_np = _tables(cfg)
    a = jnp.asarray(a_np)
    bmix = jnp.asarray(b_np)
    probs = jnp.asarray(probs_np)
    V, B, S = cfg.vocab_size, cfg.batch_size, cfg.seq_len
    K, Br = cfg.num_mixtures, cfg.branching

    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    kmix, kinit, kseq = jax.random.split(key, 3)
    mix = jax.random.randint(kmix, (B,), 0, K)                  # (B,)
    init = jax.random.randint(kinit, (B, 2), 0, V)

    def succ(m, prev, cur, choice):
        """Candidate token ``choice`` of state (prev, cur) in mixture m."""
        h = (a[m] * (prev * jnp.int64(V) + cur + 1)
             + bmix[m] * (choice + 1)) % jnp.int64(2**31 - 1)
        return (h % V).astype(jnp.int32)

    def step_fn(carry, k):
        prev, cur = carry
        # sample a branch index from the mixture's branch distribution
        ch = jax.random.categorical(k, jnp.log(probs[mix] + 1e-9), axis=-1)
        nxt = succ(mix, prev.astype(jnp.int64), cur.astype(jnp.int64),
                   ch.astype(jnp.int64))
        return (cur, nxt), nxt

    keys = jax.random.split(kseq, S)
    (_, _), toks = jax.lax.scan(step_fn, (init[:, 0], init[:, 1]), keys)
    tokens = jnp.moveaxis(toks, 0, 1)                            # (B, S)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((B, 1), -100, jnp.int32)], axis=1)
    return {"tokens": tokens, "labels": labels}


def lm_eval_stream(cfg: LMDataConfig, num_batches: int, start_step: int = 10**6):
    """Held-out batches (disjoint step range from training)."""
    for i in range(num_batches):
        yield lm_batch(cfg, start_step + i)
