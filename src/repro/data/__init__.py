from .synthetic import digits_dataset, shapes32_dataset
from .lm import LMDataConfig, lm_batch, lm_eval_stream
from .pipeline import DataPipeline, PipelineConfig

__all__ = ["digits_dataset", "shapes32_dataset", "LMDataConfig", "lm_batch",
           "lm_eval_stream", "DataPipeline", "PipelineConfig"]
