"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
llama-arch GQA. [arXiv:2403.04652]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5e6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke", family="dense",
        num_layers=4, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=256, rope_theta=5e6,
        dtype="float32", attn_chunk=64)
