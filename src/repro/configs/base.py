"""ModelConfig: one dataclass covering every assigned architecture family.

Each ``configs/<arch>.py`` exports ``CONFIG`` (full size, dry-run only) and
``smoke_config()`` (reduced same-family config for CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention ---
    attention_type: str = "gqa"      # gqa | mla
    attention_bias: bool = False     # Qwen-style QKV bias
    causal: bool = True              # False for encoder-only
    rope_theta: float = 1e4
    mrope: bool = False              # Qwen2-VL multimodal RoPE
    attn_chunk: int = 1024           # online-softmax KV chunk
    attn_bf16: bool = False          # bf16 q/k/v chunk operands (fp32
                                     # softmax state); halves score-matmul
                                     # operand traffic + K/V gathers

    # --- MLA (DeepSeek-V3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorbed: bool = False       # beyond-paper decode optimization

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0           # DeepSeek: leading dense layers
    moe_every: int = 1               # Jamba: MoE on every n-th block
    moe_offset: int = 0              # Jamba: expert_layer_offset
    moe_mode: str = "scatter"        # scatter | eval_all
    moe_capacity_factor: float = 1.25
    moe_sigmoid_router: bool = False # DeepSeek-V3 scoring
    moe_a2a_bits: int = 0            # int-quantized dispatch wire (0 = off):
                                     # the paper's reduced-precision "data"
                                     # applied to the EP all-to-all payload

    # --- block pattern (hybrid / recurrent) ---
    # cycled to num_layers; entries: "attn" | "mamba" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- SSM / recurrent dims ---
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128

    # --- embeddings / head / misc ---
    tie_embeddings: bool = False
    embedding_onehot: bool = False   # matmul-style lookup for sharded vocab
    norm_eps: float = 1e-5
    mtp_depth: int = 0               # DeepSeek-V3 multi-token prediction
    frontend: Optional[str] = None   # "audio" | "vision" stubs (inputs = embeds)

    # --- numerics ---
    dtype: str = "bfloat16"          # activations/compute
    param_dtype: str = "float32"
    loss_chunk: int = 0              # seq-chunked CE (0 = off); bounds the
                                     # fp32 logits transient at pod shapes

    # --- distribution defaults (overridable per arch) ---
    shard_heads: bool = True         # heads -> model axis (padded if needed)
    remat: str = "block"             # none | block | full

    # -------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))

    @property
    def compute_jnp_dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def param_jnp_dtype(self):
        return {"float32": jnp.float32,
                "bfloat16": jnp.bfloat16}[self.param_dtype]

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, the cycled pattern (+ DeepSeek dense head)."""
        kinds = []
        for i in range(self.num_layers):
            kind = self.block_pattern[i % len(self.block_pattern)]
            kinds.append(kind)
        return tuple(kinds)

    def is_moe_layer(self, idx: int) -> bool:
        if not self.num_experts:
            return False
        if idx < self.first_k_dense:
            return False
        return (idx - self.first_k_dense - self.moe_offset) % self.moe_every == 0

    # --- parameter counting (roofline MODEL_FLOPS uses these) -------------
    def param_count(self) -> int:
        from .counting import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from .counting import count_params
        return count_params(self, active_only=True)
