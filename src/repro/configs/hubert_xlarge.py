"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504 (unit
targets), encoder-only (bidirectional), w2v2-style backbone.
[arXiv:2106.07447]

Modality frontend (conv feature extractor) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, S, 1280); the
backbone transformer is fully real. Decode shapes are skipped (no
autoregressive decode for an encoder)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    frontend="audio",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="encoder",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=32, causal=False, frontend="audio",
        dtype="float32", attn_chunk=64)
