"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
vocab=65536. Mamba:attention 7:1 interleave (attn at offset 4, period 8),
MoE 16 experts top-2 on every other layer (offset 1). [arXiv:2403.19887]

Hybrid: only 4 attention layers hold a KV cache; the 28 Mamba layers carry
O(1) SSM state — so this arch RUNS the long_500k cell."""
from .base import ModelConfig

_PERIOD = ("mamba", "mamba", "mamba", "mamba",
           "attn", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=_PERIOD,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=14336,
    moe_every=2,
    moe_offset=1,
    ssm_state_dim=16,
    ssm_conv_dim=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        block_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        num_experts=4, experts_per_token=2, moe_d_ff=96,
        moe_every=2, moe_offset=1, moe_mode="eval_all",
        ssm_state_dim=8, ssm_conv_dim=4, ssm_expand=2, ssm_head_dim=16,
        ssm_chunk=32, dtype="float32", attn_chunk=64)
