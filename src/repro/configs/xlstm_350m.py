"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 (no separate FFN; the
mLSTM/sLSTM blocks carry their own up/down projections) vocab=50304.
xLSTM[7:1] layer mix: one sLSTM block per 8 layers. [arXiv:2405.04517]

O(1)-in-sequence recurrent state, so this arch RUNS the long_500k cell."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm",
                   "mlstm", "mlstm", "mlstm", "slstm"),
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke", family="ssm",
        num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=128,
        block_pattern=("mlstm", "slstm"),
        ssm_expand=2, ssm_chunk=32, tie_embeddings=True,
        dtype="float32")
