"""Analytic parameter / FLOP counting — feeds the roofline MODEL_FLOPS terms
(6·N·D dense, 6·N_active·D MoE) and the transformer traffic model."""
from __future__ import annotations

from typing import Dict


def _attn_params(cfg) -> int:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.attention_type == "mla":
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        return (D * qr + qr * H * (dn + dr) + D * (kvr + dr)
                + kvr * H * (dn + dv) + H * dv * D)
    n = D * H * hd + 2 * D * KV * hd + H * hd * D
    if cfg.attention_bias:
        n += H * hd + 2 * KV * hd
    return n


def _mlp_params(cfg) -> int:
    if cfg.family == "encoder":
        return 2 * cfg.d_model * cfg.d_ff + cfg.d_ff + cfg.d_model
    return 3 * cfg.d_model * cfg.d_ff


def _moe_params(cfg, active_only: bool) -> int:
    D, F = cfg.d_model, cfg.moe_d_ff
    e = cfg.experts_per_token if active_only else cfg.num_experts
    n = cfg.d_model * cfg.num_experts          # router
    n += e * 3 * D * F                          # routed experts
    n += cfg.num_shared_experts * 3 * D * (F * cfg.num_shared_experts)
    return n


def _mamba_params(cfg) -> int:
    D = cfg.d_model
    di = cfg.ssm_expand * D
    nh = di // cfg.ssm_head_dim
    N = cfg.ssm_state_dim
    return (D * (2 * di + 2 * N + nh) + cfg.ssm_conv_dim * di + di
            + 2 * nh + di * D)


def _mlstm_params(cfg) -> int:
    D = cfg.d_model
    di = cfg.ssm_expand * D
    return D * 2 * di + 3 * di * di + 2 * di * cfg.num_heads + di * D


def _slstm_params(cfg) -> int:
    D = cfg.d_model
    nh = cfg.num_heads
    hd = D // nh
    return D * 4 * D + nh * hd * 4 * hd + D * D


def layer_param_count(cfg, idx: int, active_only: bool = False) -> int:
    from ..models.transformer import layer_signatures
    kind, ffn = layer_signatures(cfg)[idx]
    n = 2 * cfg.d_model  # norms
    if kind == "attn":
        n += _attn_params(cfg)
    elif kind == "mamba":
        n += _mamba_params(cfg)
    elif kind == "mlstm":
        n += _mlstm_params(cfg)
    elif kind == "slstm":
        n += _slstm_params(cfg)
    if ffn == "mlp":
        n += _mlp_params(cfg)
    elif ffn == "moe":
        n += _moe_params(cfg, active_only)
    return n


def count_params(cfg, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model           # embed
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab_size       # head
    n += cfg.d_model                            # final norm
    for i in range(cfg.num_layers):
        n += layer_param_count(cfg, i, active_only)
    return n


def kv_bytes_per_token(cfg, bytes_per_elem: float = 2.0) -> float:
    """KV/state bytes appended per generated token (decode traffic model)."""
    from ..models.transformer import layer_signatures
    total = 0.0
    for kind, _ in layer_signatures(cfg):
        if kind == "attn":
            if cfg.attention_type == "mla":
                total += (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            else:
                total += 2 * cfg.num_kv_heads * cfg.head_dim
    return total * bytes_per_elem
