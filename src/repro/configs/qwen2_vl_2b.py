"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE, tied embeddings. [arXiv:2409.12191]

Vision frontend (dynamic-resolution ViT patchifier) is a STUB per the
assignment: ``input_specs()`` supplies precomputed patch/token embeddings and
(B, S, 3) M-RoPE position ids; the LM backbone is fully real."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    attention_bias=True,
    mrope=True,
    rope_theta=1e6,
    tie_embeddings=True,
    frontend="vision",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke", family="vlm",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, attention_bias=True, mrope=True,
        rope_theta=1e6, tie_embeddings=True, frontend="vision",
        dtype="float32", attn_chunk=64)
