"""deepseek-v3-671b [moe]: 61L d_model=7168 128H, MLA attention
(q_lora 1536, kv_lora 512, nope 128 + rope 64 / v 128), MoE: first 3 layers
dense (d_ff=18432), then 256 routed experts (top-8, sigmoid router,
moe_d_ff=2048) + 1 shared expert, MTP depth 1, vocab=129280.
[arXiv:2412.19437]

The MLA latent (kv_lora_rank + rope_dim = 576/token) IS the KV cache — the
arch where the paper's per-layer "data" quantization bites hardest at decode.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,              # the 3 leading dense layers
    vocab_size=129280,
    attention_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=3,
    moe_sigmoid_router=True,
    mtp_depth=1,
    rope_theta=1e4,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=256,
        attention_type="mla", q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        num_experts=4, experts_per_token=2, num_shared_experts=1,
        moe_d_ff=48, first_k_dense=1, moe_sigmoid_router=True,
        mtp_depth=1, moe_mode="eval_all",
        dtype="float32", attn_chunk=64)
