"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Four shapes per LM arch (assignment):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> serve prefill (encoder fwd
                                                 for encoder-only archs)
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 new token, KV
                                                 cache holding seq_len)
  long_500k    seq 524288, global_batch 1     -> serve_step; only for archs
                                                 with sub-quadratic state
                                                 (ssm / hybrid)

``input_specs`` builds the exact pytree of jax.ShapeDtypeStruct the step
function is lowered against — weak-type-correct, no device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment skip rules (see DESIGN.md §Arch-applicability)."""
    if shape.kind == "decode" and cfg.family == "encoder":
        return False  # encoder-only: no autoregressive decode
    if shape.name == "long_500k":
        # needs sub-quadratic attention: only SSM / hybrid archs run it
        return cfg.family in ("ssm", "hybrid")
    return True


def applicable_shapes(cfg: ModelConfig):
    return [s for s in SHAPES.values() if applicable(cfg, s)]


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                *, batch_override: Optional[int] = None) -> Dict:
    """The data-batch pytree for a train/prefill forward pass."""
    B = batch_override if batch_override is not None else shape.global_batch
    S = shape.seq_len
    specs: Dict = {}
    if cfg.frontend is not None:
        # modality-frontend STUB: precomputed frame/patch embeddings
        specs["embeds"] = _sds((B, S, cfg.d_model), cfg.compute_jnp_dtype)
    else:
        specs["tokens"] = _sds((B, S), jnp.int32)
    if cfg.mrope:
        specs["mrope_positions"] = _sds((B, S, 3), jnp.int32)
    if shape.kind == "train" or cfg.family == "encoder":
        specs["labels"] = _sds((B, S), jnp.int32)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 *, batch_override: Optional[int] = None,
                 quant=None) -> Dict:
    """Inputs for serve_step: one new token per sequence + the KV/state cache
    preallocated at seq_len."""
    from ..models.transformer import init_cache

    B = batch_override if batch_override is not None else shape.global_batch
    S = shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, B, S, quant))
    specs: Dict = {
        "tokens": _sds((B,), jnp.int32),
        "pos": _sds((), jnp.int32),
        "caches": cache_shapes,
    }
    if cfg.frontend is not None:
        specs.pop("tokens")
        specs["embeds"] = _sds((B, 1, cfg.d_model), cfg.compute_jnp_dtype)
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, quant=None,
                batch_override: Optional[int] = None) -> Dict:
    """Dispatch on the shape kind; the thing dryrun lowers against."""
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg, shape, batch_override=batch_override)
    return decode_specs(cfg, shape, batch_override=batch_override, quant=quant)
