"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, QKV bias. [arXiv:2407.10671]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    attention_bias=True,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b-smoke", family="dense",
        num_layers=4, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=192, vocab_size=256, attention_bias=True, rope_theta=1e6,
        dtype="float32", attn_chunk=64)
