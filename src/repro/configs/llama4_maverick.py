"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (expert), vocab=202048, MoE 128 routed experts top-1 + 1 shared,
interleaved MoE every other layer (dense layers use d_ff=16384).
[hf:meta-llama/Llama-4-Maverick-17B-128E]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=16384,              # dense (non-MoE) layers
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    num_shared_experts=1,
    moe_d_ff=8192,           # per routed expert
    moe_every=2,             # interleaved: MoE on every other layer
    moe_offset=1,
    rope_theta=5e5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke", family="moe",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=256,
        num_experts=4, experts_per_token=1, num_shared_experts=1,
        moe_d_ff=96, moe_every=2, moe_offset=1, moe_mode="eval_all",
        dtype="float32", attn_chunk=64)
