"""Arch-id -> config registry (``--arch <id>`` on every launcher)."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import ModelConfig

_MODULES: Dict[str, str] = {
    "qwen1.5-32b": "qwen1_5_32b",
    "yi-34b": "yi_34b",
    "deepseek-7b": "deepseek_7b",
    "qwen2-72b": "qwen2_72b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "xlstm-350m": "xlstm_350m",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "deepseek-v3-671b": "deepseek_v3",
    "jamba-v0.1-52b": "jamba_52b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCH_IDS)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()
