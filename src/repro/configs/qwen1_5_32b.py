"""qwen1.5-32b [dense]: 64L d_model=5120 40H (GQA kv=40 = MHA) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-32B]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    attention_bias=True,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-smoke", family="dense",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=256, attention_bias=True, rope_theta=1e6,
        dtype="float32", attn_chunk=64)
