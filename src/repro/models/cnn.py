"""Paper-faithful CNNs (LeNet / Convnet / AlexNet-small) with per-layer
precision boundaries.

Layer grouping follows the paper's Appendix A: a "layer" is the main
conv/fc stage plus its activation/pool stages, and carries ONE (weight, data)
format pair — the paper found stages within a layer share tolerance (Fig. 1).

``cnn_forward(params, x, spec, policy)`` applies the paper's §2.1 conversion:
weights are fake-quantized before use, each layer's output data (and the
network input) is fake-quantized at the memory boundary. Compute stays fp32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fixedpoint import fake_quant
from ..core.policy import PrecisionPolicy
from ..core.traffic import LayerTraffic, TrafficModel


@dataclasses.dataclass(frozen=True)
class CNNLayerSpec:
    name: str
    kind: str                 # "conv" | "fc"
    features: int             # out channels / out features
    kernel: int = 0           # conv kernel size (square)
    pool: int = 0             # maxpool window/stride after activation (0=off)
    relu: bool = True
    padding: str = "VALID"


@dataclasses.dataclass(frozen=True)
class CNNSpec:
    name: str
    input_shape: Tuple[int, int, int]     # (H, W, C)
    num_classes: int
    layers: Tuple[CNNLayerSpec, ...]

    @property
    def layer_names(self):
        return tuple(l.name for l in self.layers)


# ---------------------------------------------------------------------------
# The paper's three CPU-trainable networks (Appendix A structures; AlexNet is
# width/kernel-scaled to 32x32 synthetic data — see DESIGN.md §2).
# ---------------------------------------------------------------------------
LENET = CNNSpec(
    name="lenet", input_shape=(28, 28, 1), num_classes=10,
    layers=(
        CNNLayerSpec("layer1", "conv", 20, kernel=5, pool=2, relu=False),
        CNNLayerSpec("layer2", "conv", 50, kernel=5, pool=2, relu=False),
        CNNLayerSpec("layer3", "fc", 500, relu=True),
        CNNLayerSpec("layer4", "fc", 10, relu=False),
    ))

CONVNET = CNNSpec(
    name="convnet", input_shape=(32, 32, 3), num_classes=10,
    layers=(
        CNNLayerSpec("layer1", "conv", 32, kernel=5, pool=2, padding="SAME"),
        CNNLayerSpec("layer2", "conv", 32, kernel=5, pool=2, padding="SAME"),
        CNNLayerSpec("layer3", "conv", 64, kernel=5, pool=2, padding="SAME"),
        CNNLayerSpec("layer4", "fc", 64, relu=True),
        CNNLayerSpec("layer5", "fc", 10, relu=False),
    ))

ALEXNET_SMALL = CNNSpec(
    name="alexnet_small", input_shape=(32, 32, 3), num_classes=10,
    layers=(
        CNNLayerSpec("layer1", "conv", 48, kernel=3, pool=2, padding="SAME"),
        CNNLayerSpec("layer2", "conv", 96, kernel=3, pool=2, padding="SAME"),
        CNNLayerSpec("layer3", "conv", 128, kernel=3, padding="SAME"),
        CNNLayerSpec("layer4", "conv", 128, kernel=3, padding="SAME"),
        CNNLayerSpec("layer5", "conv", 96, kernel=3, pool=2, padding="SAME"),
        CNNLayerSpec("layer6", "fc", 256, relu=True),
        CNNLayerSpec("layer7", "fc", 256, relu=True),
        CNNLayerSpec("layer8", "fc", 10, relu=False),
    ))

SPECS = {"lenet": LENET, "convnet": CONVNET, "alexnet_small": ALEXNET_SMALL}


# ---------------------------------------------------------------------------
# Init / forward
# ---------------------------------------------------------------------------
def _shapes_through(spec: CNNSpec):
    """Activation shape after each layer (H, W, C) or (F,) — drives init and
    the traffic model."""
    h, w, c = spec.input_shape
    shapes = []
    flat = None
    for l in spec.layers:
        if l.kind == "conv":
            if l.padding == "VALID":
                h, w = h - l.kernel + 1, w - l.kernel + 1
            c = l.features
            if l.pool:
                h, w = h // l.pool, w // l.pool
            shapes.append((h, w, c))
        else:
            if flat is None:
                flat = h * w * c
            shapes.append((l.features,))
            flat = l.features
    return tuple(shapes)


def init_cnn(key, spec: CNNSpec, dtype=jnp.float32):
    params = {}
    h, w, c = spec.input_shape
    shapes = _shapes_through(spec)
    in_feat = None
    for i, l in enumerate(spec.layers):
        key, k = jax.random.split(key)
        if l.kind == "conv":
            fan_in = l.kernel * l.kernel * c
            wshape = (l.kernel, l.kernel, c, l.features)
            c = l.features
        else:
            if in_feat is None:
                ph, pw, pc = shapes[i - 1] if i else spec.input_shape
                in_feat = ph * pw * pc
            fan_in = in_feat
            wshape = (in_feat, l.features)
            in_feat = l.features
        std = np.sqrt(2.0 / fan_in)
        params[l.name] = {
            "w": (jax.random.truncated_normal(k, -2, 2, wshape, jnp.float32)
                  * std).astype(dtype),
            "b": jnp.zeros((l.features,), dtype),
        }
        if l.kind == "conv" and l.pool:
            pass
    return params


def _maybe_fq(x, fmt, rounding="nearest"):
    if fmt is None:
        return x
    return fake_quant(x, fmt.int_bits, fmt.frac_bits, rounding=rounding)


def cnn_forward(params, x, spec: CNNSpec,
                policy: Optional[PrecisionPolicy] = None):
    """x: (B, H, W, C) float32 in [0,1]. Returns logits (B, classes)."""
    pol = {n: policy[n] for n in spec.layer_names} if policy is not None \
        else {n: None for n in spec.layer_names}

    # network input is the first layer's input data (paper counts it as data)
    first = pol[spec.layers[0].name]
    if first is not None:
        x = _maybe_fq(x, first.data)

    for l in spec.layers:
        lp = pol[l.name]
        w = params[l.name]["w"]
        b = params[l.name]["b"]
        if lp is not None:
            w = _maybe_fq(w, lp.weight)
        if l.kind == "conv":
            x = jax.lax.conv_general_dilated(
                x, w, window_strides=(1, 1), padding=l.padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
        else:
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = x @ w + b
        if l.relu:
            x = jax.nn.relu(x)
        if l.kind == "conv" and l.pool:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, l.pool, l.pool, 1), (1, l.pool, l.pool, 1), "VALID")
        if lp is not None:
            x = _maybe_fq(x, lp.data)   # the paper's "data" boundary
    return x


@partial(jax.jit, static_argnums=(2,))
def cnn_loss(params, batch, spec: CNNSpec):
    logits = cnn_forward(params, batch["image"], spec)
    labels = batch["label"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


def policy_bit_arrays(spec: CNNSpec, policy: Optional[PrecisionPolicy]):
    """policy -> ((L,2) weight bits, (L,2) data bits) float32 arrays with a
    (-1,-1) sentinel for fp32 layers. Formats become TRACED values, so one
    jitted forward serves every policy (the search runs thousands of
    evaluations — recompiling per policy is 50x slower)."""
    L = len(spec.layers)
    wb = np.full((L, 2), -1.0, np.float32)
    db = np.full((L, 2), -1.0, np.float32)
    if policy is not None:
        for i, lp in enumerate(policy.layers):
            if lp.weight is not None:
                wb[i] = (lp.weight.int_bits, lp.weight.frac_bits)
            if lp.data is not None:
                db[i] = (lp.data.int_bits, lp.data.frac_bits)
    return jnp.asarray(wb), jnp.asarray(db)


def _maybe_fq_arr(x, bits2):
    """bits2: (2,) traced (I, F); (-1,-1) sentinel = no quantization."""
    y = fake_quant(x, jnp.maximum(bits2[0], 1), jnp.maximum(bits2[1], 0))
    return jnp.where(bits2[0] < 0, x, y.astype(x.dtype))


def cnn_forward_bits(params, x, spec: CNNSpec, wbits, dbits):
    """cnn_forward with traced per-layer bit arrays (see policy_bit_arrays)."""
    x = _maybe_fq_arr(x, dbits[0])
    for li, l in enumerate(spec.layers):
        w = _maybe_fq_arr(params[l.name]["w"], wbits[li])
        b = params[l.name]["b"]
        if l.kind == "conv":
            x = jax.lax.conv_general_dilated(
                x, w, window_strides=(1, 1), padding=l.padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
        else:
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = x @ w + b
        if l.relu:
            x = jax.nn.relu(x)
        if l.kind == "conv" and l.pool:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, l.pool, l.pool, 1), (1, l.pool, l.pool, 1), "VALID")
        x = _maybe_fq_arr(x, dbits[li])
    return x


@partial(jax.jit, static_argnums=(3,))
def _acc_kernel(params, images, labels, spec, wbits, dbits):
    logits = cnn_forward_bits(params, images, spec, wbits, dbits)
    return jnp.sum(jnp.argmax(logits, -1) == labels)


def cnn_accuracy(params, images, labels, spec: CNNSpec,
                 policy: Optional[PrecisionPolicy] = None,
                 batch: int = 1024) -> float:
    """Top-1 accuracy under a policy (the search's eval_fn). One compile
    per spec/shape; policies ride in as traced bit arrays."""
    n = images.shape[0]
    wbits, dbits = policy_bit_arrays(spec, policy)
    correct = 0
    for i in range(0, n, batch):
        correct += int(_acc_kernel(params, images[i:i + batch],
                                   labels[i:i + batch], spec, wbits, dbits))
    return correct / n


# ---------------------------------------------------------------------------
# Traffic model (paper §2.4): each datum touched once per layer.
# ---------------------------------------------------------------------------
def cnn_traffic_model(spec: CNNSpec) -> TrafficModel:
    shapes = _shapes_through(spec)
    h, w, c = spec.input_shape
    in_elems = h * w * c
    layers = []
    prev_elems = in_elems
    in_feat = None
    ch = c
    for i, l in enumerate(spec.layers):
        if l.kind == "conv":
            wparams = l.kernel * l.kernel * ch * l.features + l.features
            ch = l.features
        else:
            if in_feat is None:
                ph, pw, pc = shapes[i - 1] if i else spec.input_shape
                in_feat = ph * pw * pc
            wparams = in_feat * l.features + l.features
            in_feat = l.features
        out_elems = int(np.prod(shapes[i]))
        layers.append(LayerTraffic(l.name, wparams, prev_elems, out_elems))
        prev_elems = out_elems
    return TrafficModel(tuple(layers))
