"""Shared model components: norms, RoPE (incl. M-RoPE), embeddings, init.

Functional style throughout: ``init_*`` builds a params pytree (no leading
layer dim — stacking over layers happens in ``transformer.py`` via vmap),
``*_apply`` is pure. Compute dtype and param dtype are decoupled so the same
code serves fp32 unit tests and bf16 pod-scale dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (what most LLM stacks use)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings. ``positions`` is (B, S) int32; M-RoPE takes
# (B, S, 3) — temporal/height/width ids (Qwen2-VL) — and splits the head dim
# into three bands rotated by each id stream.
# ---------------------------------------------------------------------------
def rope_angles(head_dim: int, theta: float):
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    return jnp.asarray(inv)  # (half,)


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, D); positions: (B, S) int32."""
    dt = x.dtype
    half = x.shape[-1] // 2
    inv = rope_angles(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(dt)


MROPE_SECTIONS = (0.25, 0.375, 0.375)  # temporal / height / width band split


def apply_mrope(x, positions3, theta: float = 1e6):
    """Qwen2-VL multimodal RoPE. positions3: (B, S, 3)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    inv = rope_angles(x.shape[-1], theta)  # (half,)
    # band boundaries over the half-dim frequency axis
    b0 = int(half * MROPE_SECTIONS[0])
    b1 = b0 + int(half * MROPE_SECTIONS[1])
    sel = jnp.zeros((half,), jnp.int32).at[b0:b1].set(1).at[b1:].set(2)
    pos = jnp.take(positions3.astype(jnp.float32), sel, axis=-1)  # (B, S, half)
    ang = pos * inv
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / LM head. ``onehot`` mode expresses lookup as a matmul so SPMD
# partitioning over the vocab axis produces a clean psum instead of a gather
# of a sharded table (see DESIGN.md §4 / parallel.sharding).
# ---------------------------------------------------------------------------
def init_embedding(key, vocab, d_model, dtype):
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed_tokens(params, ids, *, onehot: bool = False, compute_dtype=None):
    table = params["table"]
    if compute_dtype is not None:
        table = table.astype(compute_dtype)
    if onehot:
        oh = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
        return oh @ table
    return jnp.take(table, ids, axis=0)


def init_lm_head(key, d_model, vocab, dtype):
    return {"kernel": dense_init(key, (d_model, vocab), dtype)}


def lm_head(params, x, *, tied_table=None):
    if tied_table is not None:
        return x @ tied_table.T.astype(x.dtype)
    return x @ params["kernel"].astype(x.dtype)


def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy in fp32; labels < 0 are ignored."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    nll = lse - ll
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & (mask > 0)
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def chunked_ce_loss(hidden, head_w, labels, *, chunk: int, mask=None):
    """Fused head-matmul + CE, scanned over seq chunks with rematerialized
    logits — the (B, S, V) fp32 logits tensor never exists; peak transient is
    (B, chunk, V). hidden: (B, S, D); head_w: (D, V); labels: (B, S)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    nc = S // chunk
    h_c = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    l_c = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    m_c = (jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)
           if mask is not None else jnp.zeros((nc, 0)))

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, n_valid = carry
        h, lab = inp[0], inp[1]
        logits = (h @ head_w.astype(h.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(lab, 0)[..., None],
                                 axis=-1)[..., 0]
        valid = (lab >= 0)
        if mask is not None:
            valid = valid & (inp[2] > 0)
        v = valid.astype(jnp.float32)
        return (nll_sum + jnp.sum((lse - ll) * v), n_valid + jnp.sum(v)), None

    xs = (h_c, l_c, m_c) if mask is not None else (h_c, l_c)
    (nll, nv), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs)
    return nll / jnp.maximum(nv, 1.0)
