"""Mixture-of-Experts FFN with top-k routing.

Two dispatch modes:

* ``eval_all`` — every expert runs on every token, outputs combined by router
  weight. Exact (no capacity drops); used for reduced-config smoke tests and
  as the oracle for the dispatch path.
* ``scatter`` — capacity-bounded slot dispatch: for each of the k routing
  slots, tokens are scattered into an (E, C, D) buffer (position-in-expert via
  a one-hot cumsum, overflow dropped), a grouped SwiGLU runs per expert, and
  results gather back. Expert-parallel sharding puts E on the ``model`` mesh
  axis; XLA turns the scatter/gather resharding into the EP all-to-all
  (inspected in the dry-run HLO — see EXPERIMENTS.md §Roofline).

Router: softmax over top-k logits (Mixtral/Jamba style); optional
normalized-sigmoid scoring (DeepSeek-V3 style) via ``cfg.moe_sigmoid_router``.
Aux: Switch-style load-balance loss + router z-loss, returned for the trainer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.hints import active_plan, constrain
from .common import dense_init
from .mlp import init_swiglu, swiglu_apply


def init_moe(key, cfg):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    dt = cfg.param_jnp_dtype
    p = {
        "router": dense_init(ks[0], (D, E), dt, scale=0.02),
        # nested under "experts/" so sharding rules can EP-shard the E dim
        # without colliding with dense-MLP w_gate/w_up/w_down paths
        "experts": {
            "w_gate": dense_init(ks[1], (E, D, F), dt),
            "w_up": dense_init(ks[2], (E, D, F), dt),
            "w_down": dense_init(ks[3], (E, F, D), dt,
                                 scale=1.0 / np.sqrt(F)),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = init_swiglu(ks[4], D,
                                  F * cfg.num_shared_experts, dt)
    return p


def _route(params, x, cfg):
    """x: (..., D) -> (weights (..., k), idx (..., k), aux dict).

    Stays at the input rank: reshaping (B, S, D) -> (B*S, D) would merge a
    dp-sharded dim with a tp-sharded dim and force GSPMD to all-gather the
    sequence dim (§Perf deepseek-v3 iteration)."""
    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    k = cfg.experts_per_token
    if cfg.moe_sigmoid_router:
        scores = jax.nn.sigmoid(logits)
        top_w, top_i = jax.lax.top_k(scores, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    else:
        top_l, top_i = jax.lax.top_k(logits, k)
        top_w = jax.nn.softmax(top_l, axis=-1)

    # Switch load-balance loss: E * sum_e (frac tokens to e) * (mean prob e)
    probs = jax.nn.softmax(logits, axis=-1)
    E = cfg.num_experts
    flat_i = top_i.reshape(-1, k)
    assign = jax.nn.one_hot(flat_i[:, 0], E, dtype=jnp.float32)
    lb = E * jnp.sum(assign.mean(0) * probs.reshape(-1, E).mean(0))
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_w, top_i, {"moe_lb_loss": lb, "moe_z_loss": z}


def _local_dispatch_ffn(experts, x_loc, idx_loc, w_loc, cfg, *,
                        ep_axes=None):
    """Capacity-bounded top-k dispatch + expert FFN on LOCAL tokens.

    x_loc: (T, D) tokens local to this device (or the whole batch when no
    mesh is active); idx_loc/w_loc: (T, k). experts: {w_gate (E,D,F), ...}
    with the FULL E dim when ep_axes is None, or this device's E/ep slice
    inside shard_map (ep_axes = mesh axis name for the all-to-all).

    Position-in-expert = one-hot cumsum over LOCAL assignments only — the
    global-cumsum-over-sharded-tokens trap (DESIGN.md §4) never appears.
    Capacity C = ceil(T*k/E * cf) is per token shard, the production
    per-device-capacity convention.
    """
    T, D = x_loc.shape
    cd = x_loc.dtype
    E, k = cfg.num_experts, cfg.experts_per_token
    A = T * k
    C = max(1, int(np.ceil(T * k / E * cfg.moe_capacity_factor)))

    e_a = idx_loc.reshape(A)                              # (A,)
    oh = jax.nn.one_hot(e_a, E, dtype=jnp.int32)          # (A, E)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0), e_a[:, None],
                              axis=1)[:, 0] - 1           # (A,)
    keep = pos < C
    pos_s = jnp.where(keep, pos, C - 1)
    x_a = jnp.repeat(x_loc, k, axis=0)                    # (A, D)

    buf = jnp.zeros((E, C, D), cd).at[e_a, pos_s].add(
        x_a * keep[:, None].astype(cd), mode="drop")

    def _a2a(t, split, concat):
        """EP all-to-all, optionally with an int8/int16 wire format — the
        paper's reduced-precision data applied to the dispatch payload
        (per (expert,slot)-row absmax scale rides alongside, fp32)."""
        bits = cfg.moe_a2a_bits
        if not bits:
            return jax.lax.all_to_all(t, ep_axes, split_axis=split,
                                      concat_axis=concat, tiled=True)
        qmax = 2.0 ** (bits - 1) - 1
        scale = jnp.max(jnp.abs(t), axis=-1, keepdims=True) \
            .astype(jnp.float32) / qmax
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -qmax, qmax) \
            .astype(jnp.int8 if bits <= 8 else jnp.int16)
        q = jax.lax.all_to_all(q, ep_axes, split_axis=split,
                               concat_axis=concat, tiled=True)
        scale = jax.lax.all_to_all(scale, ep_axes, split_axis=split,
                                   concat_axis=concat, tiled=True)
        return (q.astype(jnp.float32) * scale).astype(t.dtype)

    if ep_axes is not None:
        # THE MoE all-to-all: expert rows leave for their owner shard;
        # (E, C, D) -> (E/ep, C*ep, D) on each device.
        buf = _a2a(buf, 0, 1)

    g = jnp.einsum("ecd,edf->ecf", buf, experts["w_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, experts["w_up"].astype(cd))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    y = jnp.einsum("ecf,efd->ecd", h, experts["w_down"].astype(cd))

    if ep_axes is not None:
        y = _a2a(y, 1, 0)                                 # back to (E, C, D)

    y_a = y[e_a, pos_s] * keep[:, None].astype(cd)        # (A, D)
    out = (y_a.reshape(T, k, D)
           * w_loc.reshape(T, k)[..., None].astype(cd)).sum(axis=1)
    return out


def _sharded_dispatch(params, x, idx, w, cfg, plan):
    """shard_map EP dispatch (DESIGN.md §4):
      * tokens stay on their (dp x tp) shard; position-in-expert is local,
      * expert weights live E/tp (EP) x F/dp (ZeRO-3); the F-shards are
        all-gathered over "data" per layer (transient) before compute,
      * the exchange is an explicit lax.all_to_all over the model axis.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    dp = plan.batch_axes if B % plan.batch_size_divisor == 0 else None
    if isinstance(dp, tuple) and len(dp) == 1:
        dp = dp[0]
    tp = plan.model_axis if S % plan.mesh.shape[plan.model_axis] == 0 else None
    x_spec = P(dp, tp, None)
    fsdp = plan.fsdp_axis
    shard_f = fsdp is not None and cfg.moe_d_ff % plan.mesh.shape[fsdp] == 0
    f_ax = fsdp if shard_f else None
    ex_specs = {"w_gate": P(plan.model_axis, None, f_ax),
                "w_up": P(plan.model_axis, None, f_ax),
                "w_down": P(plan.model_axis, f_ax, None)}

    def body(ex_loc, x_loc, idx_loc, w_loc):
        if shard_f:
            # ZeRO-3 gather of this layer's expert F-shards (freed after use)
            ex_loc = {
                "w_gate": jax.lax.all_gather(ex_loc["w_gate"], fsdp, axis=2,
                                             tiled=True),
                "w_up": jax.lax.all_gather(ex_loc["w_up"], fsdp, axis=2,
                                           tiled=True),
                "w_down": jax.lax.all_gather(ex_loc["w_down"], fsdp, axis=1,
                                             tiled=True),
            }
        Bl, Sl, _ = x_loc.shape
        out = _local_dispatch_ffn(ex_loc, x_loc.reshape(Bl * Sl, D),
                                  idx_loc.reshape(Bl * Sl, -1),
                                  w_loc.reshape(Bl * Sl, -1), cfg,
                                  ep_axes=plan.model_axis)
        return out.reshape(Bl, Sl, D)

    fn = shard_map(body, mesh=plan.mesh,
                   in_specs=(ex_specs, x_spec, x_spec, x_spec),
                   out_specs=x_spec,
                   check_rep=False)
    return fn(params["experts"], x, idx, w)


def moe_apply(params, x, *, cfg, mode: Optional[str] = None):
    """x: (B, S, D). Returns (y, aux). All paths keep the (B, S, ...) rank —
    see _route's sharding note."""
    B, S, D = x.shape
    cd = x.dtype
    E, k = cfg.num_experts, cfg.experts_per_token
    mode = mode or cfg.moe_mode
    T = B * S
    w, idx, aux = _route(params, x, cfg)      # (B, S, k)

    if mode == "eval_all":
        ex = params["experts"]
        x2 = x.reshape(T, D)
        w2, idx2 = w.reshape(T, k), idx.reshape(T, k)
        g = jnp.einsum("td,edf->etf", x2, ex["w_gate"].astype(cd))
        u = jnp.einsum("td,edf->etf", x2, ex["w_up"].astype(cd))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
        y_all = jnp.einsum("etf,efd->etd", h, ex["w_down"].astype(cd))
        # combine top-k
        out = jnp.zeros((T, D), cd)
        for j in range(k):
            yj = jnp.take_along_axis(
                y_all, idx2[:, j][None, :, None], axis=0)[0]
            out = out + w2[:, j, None].astype(cd) * yj
        out = out.reshape(B, S, D)
    elif mode == "scatter":
        plan = active_plan()
        E_ok = (plan is not None and plan.model_axis is not None
                and E % plan.mesh.shape[plan.model_axis] == 0)
        if E_ok:
            out = _sharded_dispatch(params, x, idx, w, cfg, plan)
        else:
            out = _local_dispatch_ffn(params["experts"], x.reshape(T, D),
                                      idx.reshape(T, k), w.reshape(T, k),
                                      cfg).reshape(B, S, D)
    else:
        raise ValueError(f"unknown moe mode {mode!r}")

    if cfg.num_shared_experts:
        out = out + swiglu_apply(params["shared"], x)
    return out, aux
