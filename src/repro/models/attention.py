"""Attention: GQA (with optional QKV bias / M-RoPE) and MLA (DeepSeek-V3).

Three memory-relevant design points, all tied to the paper:

* **Chunked (online-softmax) attention** — scores never materialize beyond a
  (..., S, chunk) tile, so 32k prefill and 500k decode stay within HBM. This
  is the pure-JAX analogue of the Pallas ``kv_attention`` kernel and serves
  as its oracle at integration level.
* **Quantized KV cache** — the paper's per-layer "data" quantization applied
  to the tensor that dominates decode traffic. The cache stores an int8/int16
  integer grid; (scale, qmin, qmax) ride through ``lax.scan`` as per-layer
  scalars.
* Caches are preallocated to ``max_len`` and updated with dynamic slices, so
  decode steps compile once.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fixedpoint import format_params
from ..core.paged_kv import (PagedKVLayout, init_paged_pool, paged_gather,
                             paged_update)
from ..parallel.hints import constrain
from .common import apply_mrope, apply_rope, dense_init, init_rmsnorm, rmsnorm

NEG_INF = -1e30


def _len_col(kv_len, ndim):
    """kv_len (scalar or (B,)) -> broadcastable (B|1, 1, ..) column for
    masking a trailing KV-position axis."""
    return jnp.asarray(kv_len).reshape((-1,) + (1,) * (ndim - 1))


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KVQuantSpec:
    """Per-layer fixed-point spec for the KV cache (the paper's data bits).

    ``scale_mode`` (paged caches only): "static" stores on the layer's
    Q(I,F) grid; "page" calibrates a per-page max-abs scale at write time
    (see ``core.paged_kv.paged_update``).
    """

    int_bits: object  # python int or traced scalar (inside lax.scan)
    frac_bits: object
    container: str = "int8"  # static storage dtype
    scale_mode: str = "static"

    @property
    def dtype(self):
        if self.container == "int4":
            raise ValueError("int4 KV container requires a paged cache "
                             "(lane-packed pages); dense caches support "
                             "int8/int16")
        return {"int8": jnp.int8, "int16": jnp.int16}[self.container]


def init_kv_cache(batch, max_len, n_kv, head_dim, dtype,
                  quant: Optional[KVQuantSpec] = None):
    store = quant.dtype if quant is not None else dtype
    shape = (batch, max_len, n_kv, head_dim)
    return {"k": jnp.zeros(shape, store), "v": jnp.zeros(shape, store)}


def init_paged_kv_cache(num_pages, page_size, n_kv, head_dim, dtype,
                        quant: Optional[KVQuantSpec] = None):
    """Paged pool for one GQA layer (no batch dim — pages are shared)."""
    layout = PagedKVLayout(
        num_pages=num_pages, page_size=page_size, num_kv_heads=n_kv,
        head_dim=head_dim,
        container="fp" if quant is None else quant.container, dtype=dtype)
    return init_paged_pool(layout)


def _q_store(x, quant: Optional[KVQuantSpec]):
    if quant is None:
        return x
    scale, qmin, qmax = format_params(quant.int_bits, quant.frac_bits)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) * scale), qmin, qmax)
    return q.astype(quant.dtype)


def _q_load(x, quant: Optional[KVQuantSpec], dtype):
    if quant is None:
        return x.astype(dtype)
    scale, _, _ = format_params(quant.int_bits, quant.frac_bits)
    return (x.astype(jnp.float32) / scale).astype(dtype)


def seq_update(buf, new, pos):
    """Write ``new`` (B, S, ...) into ``buf`` (B, T, ...) at token offset
    ``pos`` — scalar (shared clock) or (B,) per-row offsets."""
    if jnp.ndim(pos) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, pos, 1)
    return jax.vmap(
        lambda b, n, p: jax.lax.dynamic_update_slice_in_dim(b, n, p, 0)
    )(buf, new, jnp.asarray(pos, jnp.int32))


def cache_update(cache, k_new, v_new, pos, quant=None):
    """Write S_new tokens at offset ``pos`` (scalar or (B,) int32)."""
    k_q = _q_store(k_new, quant)
    v_q = _q_store(v_new, quant)
    k = seq_update(cache["k"], k_q.astype(cache["k"].dtype), pos)
    v = seq_update(cache["v"], v_q.astype(cache["v"].dtype), pos)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Paged KV cache (page-table indirection; core.paged_kv holds the pool ops)
# ---------------------------------------------------------------------------
def _paged_container(cache) -> str:
    dt = cache["k_pages"].dtype
    if jnp.issubdtype(dt, jnp.floating):
        return "fp"
    return "int8" if dt == jnp.int8 else "int4"


def paged_cache_update(cache, k_new, v_new, page_table, pos,
                       quant: Optional[KVQuantSpec] = None, valid_len=None):
    """Append S new tokens through the page table (pos scalar or (B,)).

    ``valid_len`` marks trailing chunk tokens as padding (their writes go to
    the scratch page) — the bucketed-prefill contract (core.paged_kv).
    """
    container = _paged_container(cache)
    return paged_update(
        cache, k_new, v_new, page_table, pos,
        page_size=cache["k_pages"].shape[1], container=container,
        int_bits=None if quant is None else quant.int_bits,
        frac_bits=None if quant is None else quant.frac_bits,
        valid_len=valid_len,
        scale_mode="static" if quant is None else quant.scale_mode)


def paged_cache_view(cache, page_table, *, head_dim, dtype):
    """Logical dense (B, NP*ps, KV, hd) float view of a paged cache."""
    return paged_gather(cache, page_table, container=_paged_container(cache),
                        head_dim=head_dim, dtype=dtype)


def route_paged_attention(q, cache, page_table, positions, cache_pos, *,
                          cfg, attn_impl: str = "gather",
                          operand_dtype=jnp.float32):
    """Unified variable-length paged attention entry point.

    ONE routing layer for every paged attention read — chunked prefill
    (S > 1) and decode (S == 1) alike — keyed by (impl, chunk shape,
    container):

    * ``attn_impl="pallas"`` sends the chunk through
      ``kernels.paged_kv_attention`` (scalar-prefetch DMA over the page
      table, dequant in VMEM, per-row causal masking against absolute cache
      positions). S == 1 takes the kernel's single-query-row special case
      (the historical decode entry point). Per-page online softmax reorders
      accumulation, so pallas == gather only within float tolerance.
    * ``attn_impl="gather"`` reads the pool through the jnp gather path —
      identical chunk accumulation order to the dense cache, which keeps
      paged serving bitwise-equal to the dense layout (the reference mode
      the equivalence tests rely on). Non-causal configs also land here
      (the kernel's mask is causal by construction).

    ``q``: (B, S, H, hd) post-RoPE queries; ``cache``: the pool dict AFTER
    this chunk's ``paged_cache_update`` write; ``cache_pos``: scalar or (B,)
    position of the chunk's first token. Padded chunk tails need no special
    masking here: the causal bound of every REAL query is tighter than the
    padding positions, and padded queries' outputs are garbage nobody reads
    (their pool writes were scratch-redirected). Returns (B, S, H, hd) in
    q.dtype.
    """
    B, S, H, hd = q.shape
    base = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32).reshape(-1),
                            (B,))
    if attn_impl == "pallas" and cfg.causal:
        from ..kernels.ops import paged_kv_attention, paged_kv_attention_chunk
        bits = {"int8": 8, "int4": 4, "fp": 0}[_paged_container(cache)]
        args = (cache["k_pages"], cache["v_pages"], cache["k_scale"],
                cache["v_scale"], page_table)
        if S == 1:
            out = paged_kv_attention(q[:, 0], *args, base + 1, bits=bits)
            return out.reshape(B, 1, H, hd).astype(q.dtype)
        out = paged_kv_attention_chunk(q, *args, base, base + S, bits=bits)
        return out.astype(q.dtype)
    kd, vd = paged_cache_view(cache, page_table, head_dim=hd,
                              dtype=operand_dtype)
    return attend_chunked(q, kd, vd, positions, 0, causal=cfg.causal,
                          kv_len=base + S, chunk=cfg.attn_chunk,
                          operand_dtype=operand_dtype)


# ---------------------------------------------------------------------------
# Core attention math (grouped heads, online softmax over KV chunks)
# ---------------------------------------------------------------------------
def _split_heads(x, n, d):
    return x.reshape(*x.shape[:-1], n, d)


def attend_full(q, k, v, q_pos, kv_pos, *, causal=True, kv_len=None,
                scale=None):
    """Reference full-materialization attention (small shapes / oracle).

    q: (B,S,H,hd); k,v: (B,T,KV,hd); q_pos: (B,S); kv_pos: (T,)
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    vd = v.shape[-1]            # may differ from hd (MLA: dn+dr vs dv)
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((B, S, k.shape[1]), bool)
    if causal:
        mask &= kv_pos[None, None, :] <= q_pos[:, :, None]
    if kv_len is not None:
        mask &= kv_pos[None, None, :] < _len_col(kv_len, 3)
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, vd).astype(q.dtype)


def attend_chunked(q, k, v, q_pos, kv_start, *, causal=True, kv_len=None,
                   chunk=1024, kv_quant: Optional[KVQuantSpec] = None,
                   scale=None, operand_dtype=jnp.float32):
    """Flash-style online-softmax attention, scanning KV in chunks.

    k/v may be an integer-grid quantized cache; each chunk is dequantized in
    registers (the jnp analogue of the Pallas kernel's VMEM dequant).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    vd = v.shape[-1]            # may differ from hd (MLA: dn+dr vs dv)
    T = k.shape[1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    chunk = min(chunk, T)
    if T % chunk:
        pad = chunk - T % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        T = T + pad
        if kv_len is None:
            kv_len = T - pad
    nc = T // chunk
    if S == 1:
        # decode: grouped (KV, G) math — tensors are tiny at S=1 and the
        # G-fold K/V expansion of the training path would multiply the
        # dominant cache-read bytes by the group size (§Perf iteration)
        return _attend_chunked_grouped(q, k, v, q_pos, kv_start,
                                       causal=causal, kv_len=kv_len,
                                       chunk=chunk, kv_quant=kv_quant,
                                       scale=scale, nc=nc)
    # Work in EXPANDED H-head space, not (KV, G): H is divisible by the TP
    # degree when KV isn't (GQA kv=8 on a 16-way model axis), so all chunk
    # transients shard. K/V chunks expand on the fly (head h -> kv h // G).
    # ``operand_dtype=bf16`` (cfg.attn_bf16) halves the q/k/v chunk bytes +
    # gathers; softmax state and dot accumulation stay fp32.
    odt = operand_dtype
    qh = constrain((q.astype(jnp.float32) * scale).astype(odt),
                   "dp", None, "tp", None)

    k_c = jnp.moveaxis(k.reshape(B, nc, chunk, KV, hd), 1, 0)
    v_c = jnp.moveaxis(v.reshape(B, nc, chunk, KV, vd), 1, 0)

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, S, H, vd), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        idx, kc, vc = inp
        kc = _q_load(kc, kv_quant, odt)
        vc = _q_load(vc, kv_quant, odt)
        if G > 1:
            kc = jnp.repeat(kc, G, axis=2)
            vc = jnp.repeat(vc, G, axis=2)
        kc = constrain(kc, "dp", None, "tp", None)
        vc = constrain(vc, "dp", None, "tp", None)
        s = jnp.einsum("bshd,bthd->bhst", qh, kc,
                       preferred_element_type=jnp.float32)
        pos = kv_start + idx * chunk + jnp.arange(chunk)
        valid = jnp.ones((B, S, chunk), bool)
        if causal:
            valid &= pos[None, None, :] <= q_pos[:, :, None]
        if kv_len is not None:
            valid &= pos[None, None, :] < _len_col(kv_len, 3)
        s = jnp.where(valid[:, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhst,bthd->bshd", p, vc,
                        preferred_element_type=jnp.float32)
        corr_t = jnp.transpose(corr, (0, 2, 1))[..., None]   # (B,S,H,1)
        acc_new = acc * corr_t + pv
        return (m_new, l_new, acc_new), None

    idxs = jnp.arange(nc)
    # checkpoint the chunk body: backward recomputes s/p per chunk instead of
    # saving the stacked (nc, B, H, S, chunk) probabilities — the flash-
    # attention memory property at the jnp level
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (idxs, k_c, v_c))
    l_t = jnp.transpose(l, (0, 2, 1))[..., None]
    out = acc / jnp.maximum(l_t, 1e-30)
    return out.astype(q.dtype)


def _attend_chunked_grouped(q, k, v, q_pos, kv_start, *, causal, kv_len,
                            chunk, kv_quant, scale, nc):
    """Online-softmax decode attention in grouped (B,KV,G) layout; K/V are
    read chunk-by-chunk in their stored (possibly int8) form and never
    expanded across the group dim. S is 1 (a single new token)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    vd = v.shape[-1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * scale

    k_c = jnp.moveaxis(k.reshape(B, nc, chunk, KV, hd), 1, 0)
    v_c = jnp.moveaxis(v.reshape(B, nc, chunk, KV, vd), 1, 0)

    m0 = jnp.full((B, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G), jnp.float32)
    a0 = jnp.zeros((B, KV, G, vd), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        idx, kc, vc = inp
        kc = _q_load(kc, kv_quant, jnp.float32)
        vc = _q_load(vc, kv_quant, jnp.float32)
        s = jnp.einsum("bkgh,btkh->bkgt", qg, kc,
                       preferred_element_type=jnp.float32)
        pos = kv_start + idx * chunk + jnp.arange(chunk)
        valid = pos[None, :] <= q_pos[:, -1:]  # causal vs the new token
        if kv_len is not None:
            valid = valid & (pos[None, :] < _len_col(kv_len, 2))
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgt,btkh->bkgh", p, vc, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nc), k_c, v_c))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, 1, H, vd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------
def init_gqa(key, cfg):
    """cfg: ModelConfig (configs.base). One layer; no leading L dim."""
    ks = jax.random.split(key, 4)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.param_jnp_dtype
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dt),
        "wk": dense_init(ks[1], (D, KV * hd), dt),
        "wv": dense_init(ks[2], (D, KV * hd), dt),
        "wo": dense_init(ks[3], (H * hd, D), dt, scale=1.0 / np.sqrt(H * hd)),
    }
    if cfg.attention_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    return p


def gqa_apply(params, x, positions, *, cfg, cache=None, cache_pos=None,
              kv_quant: Optional[KVQuantSpec] = None, mrope_positions=None,
              chunked: Optional[bool] = None, page_table=None,
              attn_impl: str = "gather", kv_valid_len=None):
    """Returns (y, new_cache). ``positions``: (B, S) absolute positions.

    Train/prefill: cache=None -> attends within the sequence (causal per cfg),
    optionally returning a fresh cache when ``cache`` is a preallocated dict.
    Decode: cache given and S is the new-token count (usually 1);
    ``cache_pos`` is a scalar (shared clock) or (B,) per-row offsets. A paged
    cache (dict with "k_pages") additionally needs ``page_table`` (B, NP).

    ``attn_impl`` selects the paged attention backend for EVERY chunk shape
    (see ``route_paged_attention``): "gather" reads the pool through the jnp
    path (bitwise-reference mode, identical chunk order to the dense cache),
    "pallas" routes both chunked prefill (S > 1) and decode (S == 1) through
    the variable-length ``kernels.paged_kv_attention`` chunk kernel
    (scalar-prefetch DMA; per-page online softmax, so equal to gather only
    within float tolerance).
    ``kv_valid_len`` (scalar or (B,)) marks only the first tokens of a padded
    prefill chunk as real; padded tails scatter to the scratch page.
    """
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cd = x.dtype

    q = x @ params["wq"].astype(cd)
    k = x @ params["wk"].astype(cd)
    v = x @ params["wv"].astype(cd)
    if cfg.attention_bias:
        q = q + params["bq"].astype(cd)
        k = k + params["bk"].astype(cd)
        v = v + params["bv"].astype(cd)
    q = constrain(_split_heads(q, H, hd), "dp", None, "tp", None)
    k = constrain(_split_heads(k, KV, hd), "dp", None, "tp", None)
    v = constrain(_split_heads(v, KV, hd), "dp", None, "tp", None)

    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    use_chunked = (chunked if chunked is not None
                   else (S * max(S, 1) > cfg.attn_chunk ** 2 or cache is not None))

    odt = jnp.bfloat16 if cfg.attn_bf16 else jnp.float32
    if cache is not None and "k_pages" in cache:
        if page_table is None:
            raise ValueError("paged KV cache needs a page_table")
        if attn_impl not in ("gather", "pallas"):
            raise ValueError(f"attn_impl must be 'gather' or 'pallas', "
                             f"got {attn_impl!r}")
        new_cache = paged_cache_update(cache, k, v, page_table, cache_pos,
                                       kv_quant, valid_len=kv_valid_len)
        # ONE entry point for chunk prefill AND decode: the routing layer
        # picks the Pallas chunk kernel (S >= 1; per-page online softmax,
        # float-tolerance equal) or the jnp gather path (bitwise reference)
        o = route_paged_attention(q, new_cache, page_table, positions,
                                  cache_pos, cfg=cfg, attn_impl=attn_impl,
                                  operand_dtype=odt)
    elif cache is not None:
        pos = cache_pos
        new_cache = cache_update(cache, k, v, pos, kv_quant)
        kv_len = pos + S
        o = attend_chunked(q, new_cache["k"], new_cache["v"], positions, 0,
                           causal=cfg.causal, kv_len=kv_len,
                           chunk=cfg.attn_chunk, kv_quant=kv_quant,
                           operand_dtype=odt)
    else:
        new_cache = None
        if use_chunked:
            o = attend_chunked(q, k, v, positions, 0, causal=cfg.causal,
                               chunk=cfg.attn_chunk,
                               operand_dtype=jnp.bfloat16 if cfg.attn_bf16
                               else jnp.float32)
        else:
            o = attend_full(q, k, v, positions, jnp.arange(S),
                            causal=cfg.causal)

    y = o.reshape(B, S, H * hd) @ params["wo"].astype(cd)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): low-rank latent KV — the cache holds only
# (kv_lora_rank + rope_dim) per token, and that latent is what we quantize.
# ---------------------------------------------------------------------------
def init_mla(key, cfg):
    ks = jax.random.split(key, 6)
    D, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = cfg.param_jnp_dtype
    return {
        "wq_a": dense_init(ks[0], (D, qr), dt),
        "q_norm": init_rmsnorm(qr, dt),
        "wq_b": dense_init(ks[1], (qr, H * (dn + dr)), dt),
        "wkv_a": dense_init(ks[2], (D, kvr + dr), dt),
        "kv_norm": init_rmsnorm(kvr, dt),
        "wkv_b": dense_init(ks[3], (kvr, H * (dn + dv)), dt),
        "wo": dense_init(ks[4], (H * dv, D), dt, scale=1.0 / np.sqrt(H * dv)),
    }


def init_mla_cache(batch, max_len, cfg, dtype,
                   quant: Optional[KVQuantSpec] = None):
    store = quant.dtype if quant is not None else dtype
    width = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    return {"latent": jnp.zeros((batch, max_len, width), store)}


def mla_apply(params, x, positions, *, cfg, cache=None, cache_pos=None,
              kv_quant: Optional[KVQuantSpec] = None, absorbed: bool = False):
    """Returns (y, new_cache). Latent cache = [c_kv(kvr) ; k_rope(dr)].

    ``absorbed=False`` (baseline) expands the latent to per-head K/V at use.
    ``absorbed=True`` folds W_uk into the query and W_uv into the output
    projection so decode attends directly in latent space — the beyond-paper
    perf option (see EXPERIMENTS.md §Perf).
    """
    B, S, D = x.shape
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    cd = x.dtype
    sm_scale = 1.0 / np.sqrt(dn + dr)

    # --- queries ---
    cq = rmsnorm(params["q_norm"], x @ params["wq_a"].astype(cd), cfg.norm_eps)
    q = (cq @ params["wq_b"].astype(cd)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # --- latent KV ---
    kv_a = x @ params["wkv_a"].astype(cd)
    c_kv = rmsnorm(params["kv_norm"], kv_a[..., :kvr], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., kvr:][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    latent = jnp.concatenate([c_kv, k_rope], axis=-1)  # (B, S, kvr+dr)

    if cache is not None:
        lat_q = _q_store(latent, kv_quant)
        new_cache = {"latent": seq_update(
            cache["latent"], lat_q.astype(cache["latent"].dtype), cache_pos)}
        lat_all = _q_load(new_cache["latent"], kv_quant, cd)
        kv_len = cache_pos + S
        T = lat_all.shape[1]
    else:
        new_cache = None
        lat_all, kv_len, T = latent, None, S

    c_all, kr_all = lat_all[..., :kvr], lat_all[..., kvr:]

    wkv_b = params["wkv_b"].astype(cd).reshape(kvr, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]  # (kvr, H, dn), (kvr, H, dv)

    if absorbed:
        # fold W_uk into q: q_lat = q_nope @ W_uk^T per head -> (B,S,H,kvr)
        q_lat = jnp.einsum("bshd,khd->bshk", q_nope, w_uk)
        # scores over latent + rope parts; latent plays the role of K
        k_lat = c_all  # (B,T,kvr) shared across heads
        s = (jnp.einsum("bshk,btk->bhst", q_lat.astype(jnp.float32),
                        k_lat.astype(jnp.float32))
             + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                          kr_all.astype(jnp.float32))) * sm_scale
        mask = jnp.ones((B, S, T), bool)
        if cfg.causal:
            mask &= jnp.arange(T)[None, None, :] <= positions[:, :, None]
        if kv_len is not None:
            mask &= jnp.arange(T)[None, None, :] < _len_col(kv_len, 3)
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btk->bshk", p, c_all.astype(jnp.float32))
        o = jnp.einsum("bshk,khd->bshd", o_lat, w_uv.astype(jnp.float32))
        o = o.astype(cd)
    else:
        # expand latent to per-head K/V (baseline; memory-heavier at decode)
        # pin head sharding at the source: without it GSPMD all-gathers the
        # (B,T,H,dn+dr) expansion to FULL H around the attention chunk scan
        # (§Perf deepseek-v3 iteration)
        k_nope = jnp.einsum("btk,khd->bthd", c_all, w_uk)
        vv = constrain(jnp.einsum("btk,khd->bthd", c_all, w_uv),
                       "dp", None, "tp", None)
        k_full = constrain(jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :], (B, T, H, dr))],
            axis=-1), "dp", None, "tp", None)
        q_full = constrain(jnp.concatenate([q_nope, q_rope], axis=-1),
                           "dp", None, "tp", None)
        o = attend_chunked(q_full, k_full, vv, positions, 0, causal=cfg.causal,
                           kv_len=kv_len, chunk=cfg.attn_chunk, scale=sm_scale,
                           operand_dtype=jnp.bfloat16 if cfg.attn_bf16
                           else jnp.float32)

    y = o.reshape(B, S, H * dv) @ params["wo"].astype(cd)
    return y, new_cache
