"""State-space / recurrent blocks: Mamba (Jamba's SSM) and xLSTM (mLSTM+sLSTM).

Hardware adaptation (DESIGN.md §3): a naive Mamba-1 associative scan
materializes (B, S, d_inner, d_state) — tens of TB at pod shapes. We use the
chunked SSD formulation (Mamba-2, arXiv:2405.21060): scalar decay per *head*,
within-chunk attention-like einsums, cross-chunk state recurrence via a short
``lax.scan``. The recurrent state (B, nh, N, P) is O(1) in sequence length,
which is what makes the ``long_500k`` cell runnable for xlstm/jamba.

The mLSTM uses the same chunked machinery (it *is* gated linear attention
with a normalizer); the sLSTM is inherently sequential and runs a time-step
``lax.scan`` (exact, used at small scale / decode).

Both SSM states are quantizable "data" in the paper's sense: ``state_quant``
applies Q(I,F) at chunk boundaries, mirroring KV-cache quantization.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fixedpoint import format_params
from ..parallel.hints import constrain
from .common import dense_init, init_rmsnorm, rmsnorm


def _maybe_fake_quant(x, quant):
    """quant: None or (int_bits, frac_bits) possibly traced scalars."""
    if quant is None:
        return x
    scale, qmin, qmax = format_params(*quant)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) * scale), qmin, qmax)
    return (q / scale).astype(x.dtype)


# ===========================================================================
# Mamba (SSD / Mamba-2 style, ngroups=1)
# ===========================================================================
def init_mamba(key, cfg):
    D = cfg.d_model
    di = cfg.ssm_expand * D
    nh = di // cfg.ssm_head_dim
    N = cfg.ssm_state_dim
    ck = cfg.ssm_conv_dim
    ks = jax.random.split(key, 4)
    dt = cfg.param_jnp_dtype
    # in_proj emits [x(di), z(di), B(N), C(N), dt(nh)]
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di + 2 * N + nh), dt),
        "conv_w": dense_init(ks[1], (ck, di), dt, scale=0.5),
        "conv_b": jnp.zeros((di,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, float(nh), nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "norm": init_rmsnorm(di, dt),
        "out_proj": dense_init(ks[2], (di, D), dt, scale=1.0 / np.sqrt(di)),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv over time. x: (B,S,di); w: (k,di).

    state: (B, k-1, di) trailing inputs from the previous segment (decode).
    Returns (y, new_state).
    """
    B, S, di = x.shape
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((B, k - 1, di), x.dtype)
    xx = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+k-1, di)
    y = jnp.zeros((B, S, di), x.dtype)
    for i in range(k):  # k is 4; unrolled adds are cheaper than conv on TPU
        y = y + xx[:, i:i + S, :] * w[i][None, None, :].astype(x.dtype)
    y = y + b.astype(x.dtype)
    new_state = xx[:, S:, :] if k > 1 else state
    return y, new_state


def _mamba_project(params, u, cfg):
    B, S, D = u.shape
    di = cfg.ssm_expand * D
    nh = di // cfg.ssm_head_dim
    N = cfg.ssm_state_dim
    cd = u.dtype
    proj = u @ params["in_proj"].astype(cd)
    x, z, Bmat, Cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return x, z, Bmat, Cmat, dt, (di, nh, N)


def mamba_apply(params, u, *, cfg, state=None, state_quant=None):
    """u: (B, S, D). state: None (train/prefill from zero) or
    {"conv": (B,k-1,di), "ssm": (B,nh,N,P)} for decode continuation.
    Returns (y, new_state)."""
    B, S, D = u.shape
    cd = u.dtype
    x, z, Bm, Cm, dt, (di, nh, N) = _mamba_project(params, u, cfg)
    P = cfg.ssm_head_dim

    conv_state = state["conv"] if state is not None else None
    x, new_conv = _causal_conv(x, params["conv_w"], params["conv_b"],
                               conv_state)
    x = jax.nn.silu(x.astype(jnp.float32))
    Bm = Bm.astype(jnp.float32)  # (B,S,N)
    Cm = Cm.astype(jnp.float32)  # (B,S,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(params["A_log"])  # (nh,) negative decay rates

    xh = x.reshape(B, S, nh, P)
    ssm0 = (state["ssm"].astype(jnp.float32) if state is not None
            else jnp.zeros((B, nh, N, P), jnp.float32))

    Lc = min(cfg.ssm_chunk, S)
    if S % Lc:
        raise ValueError(f"seq {S} not divisible by ssm chunk {Lc}")
    nc = S // Lc

    # chunked tensors: (nc, B, Lc, ...)
    def chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, Lc, *t.shape[2:]), 1, 0)

    xc, Bc, Cc, dtc = chunks(xh), chunks(Bm), chunks(Cm), chunks(dt)

    def body(h, inp):
        xj, Bj, Cj, dtj = inp  # (B,Lc,nh,P) (B,Lc,N) (B,Lc,N) (B,Lc,nh)
        a = dtj * A  # (B,Lc,nh) log-decay per step (negative)
        Sa = jnp.cumsum(a, axis=1)  # inclusive cumsum
        # intra-chunk: W[t,s] = exp(Sa_t - Sa_s) * (C_t . B_s), s <= t
        G = jnp.einsum("btn,bsn->bts", Cj, Bj)  # (B,Lc,Lc)
        Mlog = Sa[:, :, None, :] - Sa[:, None, :, :]  # (B,Lc,Lc,nh) t,s
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        # mask the EXPONENT (not the exp) — exp overflows in the upper
        # triangle and where(tri, inf, 0) back-propagates NaN cotangents
        Mlog = jnp.where(tri[None, :, :, None], Mlog, -jnp.inf)
        W = jnp.exp(Mlog) * G[..., None]  # (B,Lc,Lc,nh)
        xdt = xj * dtj[..., None]  # (B,Lc,nh,P)
        y_intra = jnp.einsum("btsh,bshp->bthp", W, xdt)
        # inter-chunk: contribution of h (carry): y_inter = C_t exp(Sa_t) h
        # (2-operand einsums with gates pre-folded — see mlstm note)
        hC = jnp.einsum("btn,bhnp->bthp", Cj, h)
        y_inter = hC * jnp.exp(Sa)[..., None]
        # update carry: h' = exp(sum a) h + sum_s exp(Sa_last - Sa_s) dt B x
        decay_all = jnp.exp(Sa[:, -1, :])  # (B,nh)
        w_s = jnp.exp(Sa[:, -1:, :] - Sa)  # (B,Lc,nh)
        dh = jnp.einsum("bsn,bshp->bhnp", Bj, xdt * w_s[..., None])
        h_new = h * decay_all[:, :, None, None] + dh
        h_new = _maybe_fake_quant(h_new, state_quant)
        return h_new, y_intra + y_inter

    h_final, yc = jax.lax.scan(body, ssm0, (xc, Bc, Cc, dtc))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, nh, P)
    y = y + xh * params["D_skip"][None, None, :, None]
    y = y.reshape(B, S, di)
    # gated norm + output
    y = rmsnorm(params["norm"], y.astype(cd), cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    out = y @ params["out_proj"].astype(cd)
    new_state = {"conv": new_conv, "ssm": h_final}
    return out, new_state


def init_mamba_state(batch, cfg, dtype):
    D = cfg.d_model
    di = cfg.ssm_expand * D
    nh = di // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, di), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_state_dim, cfg.ssm_head_dim),
                         jnp.float32),
    }


# ===========================================================================
# xLSTM — mLSTM (chunked matrix memory) and sLSTM (sequential scalar memory)
# ===========================================================================
def init_mlstm(key, cfg):
    D = cfg.d_model
    di = cfg.ssm_expand * D
    nh = cfg.num_heads
    ks = jax.random.split(key, 7)
    dt = cfg.param_jnp_dtype
    return {
        "up_proj": dense_init(ks[0], (D, 2 * di), dt),        # x, z-gate
        "wq": dense_init(ks[1], (di, di), dt),
        "wk": dense_init(ks[2], (di, di), dt),
        "wv": dense_init(ks[3], (di, di), dt),
        "w_i": dense_init(ks[4], (di, nh), dt, scale=0.02),
        "w_f": dense_init(ks[5], (di, nh), dt, scale=0.02),
        "f_bias": jnp.full((nh,), 3.0, jnp.float32),  # open forget gates
        "norm": init_rmsnorm(di, dt),
        "down_proj": dense_init(ks[6], (di, D), dt, scale=1.0 / np.sqrt(di)),
    }


def mlstm_apply(params, u, *, cfg, state=None, state_quant=None):
    """Chunked mLSTM: linear attention with per-step scalar decay + normalizer.

    state: {"C": (B,nh,dk,dv+1), "m": (B,nh)} matrix memory (the +1 column is
    the normalizer n). Returns (y, new_state).
    """
    B, S, D = u.shape
    cd = u.dtype
    di = cfg.ssm_expand * D
    nh = cfg.num_heads
    hd = di // nh

    proj = u @ params["up_proj"].astype(cd)
    x, z = jnp.split(proj, 2, axis=-1)
    q = (x @ params["wq"].astype(cd)).reshape(B, S, nh, hd)
    k = (x @ params["wk"].astype(cd)).reshape(B, S, nh, hd)
    v = (x @ params["wv"].astype(cd)).reshape(B, S, nh, hd)
    # gates (log-space): log f in (-inf, 0] via logsigmoid; log i unconstrained
    logf = jax.nn.log_sigmoid(
        (x @ params["w_f"].astype(cd)).astype(jnp.float32) + params["f_bias"])
    logi = (x @ params["w_i"].astype(cd)).astype(jnp.float32)  # (B,S,nh)

    qf = q.astype(jnp.float32) / np.sqrt(hd)
    kf = k.astype(jnp.float32)
    # augment v with ones to carry the normalizer through the same memory
    vf = jnp.concatenate([v.astype(jnp.float32),
                          jnp.ones((B, S, nh, 1), jnp.float32)], axis=-1)

    C0 = (state["C"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, nh, hd, hd + 1), jnp.float32))
    m0 = (state["m"] if state is not None
          else jnp.full((B, nh), 0.0, jnp.float32))

    Lc = min(cfg.ssm_chunk, S)
    if S % Lc:
        raise ValueError(f"seq {S} not divisible by chunk {Lc}")
    nc = S // Lc

    def chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, Lc, *t.shape[2:]), 1, 0)

    qc, kc, vc, fc, ic = map(chunks, (qf, kf, vf, logf, logi))

    def body(carry, inp):
        C, m = carry  # (B,nh,hd,hd+1), (B,nh)
        qj, kj, vj, lfj, lij = inp
        Sa = jnp.cumsum(lfj, axis=1)  # (B,Lc,nh) cumulative log-forget
        # stabilizer: max over (input-gate adjusted) magnitudes in this chunk
        # intra weights: exp(Sa_t - Sa_s + li_s)
        Wlog = Sa[:, :, None, :] - Sa[:, None, :, :] + lij[:, None, :, :]
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))[None, :, :, None]
        # mask the EXPONENT before exp (where(tri, exp, 0) leaks NaN grads)
        Wlog = jnp.where(tri, Wlog, -jnp.inf)
        # inter weights for carry memory: exp(Sa_t + m)
        inter_log = Sa + m[:, None, :]  # (B,Lc,nh)
        m_new_t = jnp.maximum(jnp.max(Wlog, axis=2),
                              inter_log)  # (B,Lc,nh) running stabilizer
        Wn = jnp.exp(Wlog - m_new_t[:, :, None, :])
        # NOTE all einsums below are 2-operand with scalar gates pre-folded
        # into one operand: 3-operand forms made XLA materialize rank-4
        # (B,Lc,hd,hd+1)-sized broadcast intermediates at fusion boundaries
        # (§Perf xlstm iteration 1 — 'memory' term was 100x the ideal).
        G = jnp.einsum("bthd,bshd->bhts", qj, kj)  # (B,nh,Lc,Lc)
        GW = G * jnp.moveaxis(Wn, 3, 1)            # (B,nh,Lc,Lc)
        y_intra = jnp.einsum("bhts,bshp->bthp", GW, vj)
        inter_w = jnp.exp(inter_log - m_new_t)  # (B,Lc,nh)
        y_inter = jnp.einsum("bthd,bhdp->bthp", qj * inter_w[..., None], C)
        y = y_intra + y_inter  # (B,Lc,nh,hd+1)
        # chunk-final memory update, restabilized to m_last
        m_last = m_new_t[:, -1, :]
        decay = jnp.exp(Sa[:, -1:, :] + m[:, None, :] - m_last[:, None, :])[:, 0]
        w_s = jnp.exp(Sa[:, -1:, :] - Sa + lij - m_last[:, None, :])  # (B,Lc,nh)
        dC = jnp.einsum("bshd,bshp->bhdp", kj * w_s[..., None], vj)
        C_new = C * decay[:, :, None, None] + dC
        C_new = _maybe_fake_quant(C_new, state_quant)
        return (C_new, m_last), y

    (C_f, m_f), yc = jax.lax.scan(body, (C0, m0), (qc, kc, vc, fc, ic))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, nh, hd + 1)
    num, den = y[..., :hd], y[..., hd:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(B, S, di).astype(cd)
    h = rmsnorm(params["norm"], h, cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    out = h @ params["down_proj"].astype(cd)
    return out, {"C": C_f, "m": m_f}


def init_mlstm_state(batch, cfg, dtype):
    di = cfg.ssm_expand * cfg.d_model
    nh = cfg.num_heads
    hd = di // nh
    return {"C": jnp.zeros((batch, nh, hd, hd + 1), jnp.float32),
            "m": jnp.zeros((batch, nh), jnp.float32)}


def init_slstm(key, cfg):
    D = cfg.d_model
    nh = cfg.num_heads
    hd = D // nh
    ks = jax.random.split(key, 3)
    dt = cfg.param_jnp_dtype
    return {
        "w_in": dense_init(ks[0], (D, 4 * D), dt),     # i, f, z, o pre-acts
        "r": dense_init(ks[1], (nh, hd, 4 * hd), dt, scale=1.0 / np.sqrt(hd)),
        "b": jnp.zeros((4 * D,), jnp.float32),
        "norm": init_rmsnorm(D, dt),
        "out_proj": dense_init(ks[2], (D, D), dt, scale=1.0 / np.sqrt(D)),
    }


def slstm_apply(params, u, *, cfg, state=None, state_quant=None):
    """Sequential sLSTM (exact scan over time). state: {h,c,n,m} each
    (B, nh, hd) (m,n: stabilizer/normalizer). Returns (y, new_state)."""
    B, S, D = u.shape
    cd = u.dtype
    nh = cfg.num_heads
    hd = D // nh

    pre = (u @ params["w_in"].astype(cd)).astype(jnp.float32) + params["b"]
    pre = pre.reshape(B, S, 4, nh, hd)

    if state is None:
        z0 = jnp.zeros((B, nh, hd), jnp.float32)
        state = {"h": z0, "c": z0, "n": z0, "m": jnp.full((B, nh, hd), -1e30)}

    r = params["r"].astype(jnp.float32)  # (nh, hd, 4*hd)

    def step(carry, x_t):
        h, c, n, m = carry["h"], carry["c"], carry["n"], carry["m"]
        rec = jnp.einsum("bnh,nhk->bnk", h, r).reshape(B, nh, 4, hd)
        zi = x_t[:, 0] + rec[:, :, 0]
        zf = x_t[:, 1] + rec[:, :, 1]
        zz = x_t[:, 2] + rec[:, :, 2]
        zo = x_t[:, 3] + rec[:, :, 3]
        # exponential gating with stabilizer (xLSTM eq. 15-17)
        log_i, log_f = zi, jax.nn.log_sigmoid(zf)
        m_new = jnp.maximum(log_f + m, log_i)
        i_g = jnp.exp(log_i - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(zz)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1.0)
        new = {"h": h_new, "c": c_new, "n": n_new, "m": m_new}
        return new, h_new

    xs = jnp.moveaxis(pre, 1, 0)  # (S, B, 4, nh, hd)
    # NEVER shard the scanned TIME dim: a per-step dynamic-slice over a
    # model-sharded S forces XLA to replicate the whole stacked buffer every
    # step (§Perf xlstm iteration — 2 GiB x 4096 steps). Shard hd instead.
    xs = constrain(xs, None, "dp", None, None, "tp")
    final, hs = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(cd)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    out = y @ params["out_proj"].astype(cd)
    return out, final


def init_slstm_state(batch, cfg, dtype):
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    z0 = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"h": z0, "c": z0, "n": z0,
            "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}
