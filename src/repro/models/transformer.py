"""Model assembly: blocks, segmented scan-over-layers, train/serve steps.

Layers are grouped into **segments** of identical structure (e.g. DeepSeek-V3:
3 dense layers then 58 MoE layers; Jamba: 4 periods of the 8-layer
mamba/attn/MoE pattern). Each segment is a single ``lax.scan`` over stacked
parameters, so an 80-layer model compiles one block body — essential for the
1-CPU-core 512-fake-device dry-run, and it is also how per-layer precision
stays free: the per-layer Q(I,F) scale/bound vectors are just more scanned
operands (DESIGN.md §3).

Per-layer quantization hooks (all optional, driven by ``ModelQuant``):
  * weights: fake-quant of >=2-D block params before use (paper "weights"),
  * residual stream: fake-quant of each block's output (paper "data"),
  * KV/SSM state: integer-grid storage via KVQuantSpec / state_quant.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fixedpoint import fake_quant
from ..core.paged_kv import PagedCacheSpec
from ..parallel.hints import constrain
from .attention import (KVQuantSpec, gqa_apply, init_gqa, init_kv_cache,
                        init_mla, init_mla_cache, init_paged_kv_cache,
                        mla_apply)
from .common import (chunked_ce_loss, cross_entropy, dense_init, embed_tokens,
                     init_embedding, init_lm_head, init_rmsnorm, lm_head,
                     rmsnorm)
from .mlp import gelu_mlp_apply, init_gelu_mlp, init_swiglu, swiglu_apply
from .moe import init_moe, moe_apply
from .ssm import (init_mamba, init_mamba_state, init_mlstm, init_mlstm_state,
                  init_slstm, init_slstm_state, mamba_apply, mlstm_apply,
                  slstm_apply)


# ---------------------------------------------------------------------------
# Quantization plumbing
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelQuant:
    """Stacked per-layer Q(I,F) parameters; (L,) float32 arrays (or None).

    Built from a PrecisionPolicy by ``repro.quant.apply.build_model_quant``.

    ``kv_container`` is the uniform storage container; ``kv_containers``
    (optional, static tuple of one container name per layer, "fp" marking an
    unquantized layer) switches the paged serving cache to **per-layer KV
    precision profiles**: contiguous same-container layer runs are grouped
    into scanned sub-segments (``_segment_scan_grouped``) so a realistic
    profile still compiles O(distinct runs) block bodies; only length-1
    runs — pathological alternating profiles — unroll. ``kv_unroll=True``
    forces the fully unrolled reference path (``_segment_unrolled``,
    per-period pools) for debugging and identity tests. ``kv_scale_mode``
    ("static" | "page") picks the paged dequant scale calibration (see
    ``core.paged_kv.paged_update``).
    """

    w_int: Optional[jnp.ndarray] = None
    w_frac: Optional[jnp.ndarray] = None
    a_int: Optional[jnp.ndarray] = None
    a_frac: Optional[jnp.ndarray] = None
    kv_int: Optional[jnp.ndarray] = None
    kv_frac: Optional[jnp.ndarray] = None
    kv_container: str = "int8"
    kv_containers: Optional[Tuple[str, ...]] = None  # per-layer (static)
    kv_scale_mode: str = "static"
    kv_unroll: bool = False       # force the fully unrolled profile path

    def layer_slice(self, sl):
        """Slice all stacked arrays with ``sl`` (layer indices).

        Only valid on uniform-container quants: per-layer containers are
        static python strings and cannot ride a scan — the profile paths
        slice with :meth:`layer_static` / ``_run_quant`` instead."""
        assert self.kv_containers is None, \
            "per-layer KV containers require the unrolled (layer_static) path"
        f = lambda a: None if a is None else a[sl]
        return ModelQuant(f(self.w_int), f(self.w_frac), f(self.a_int),
                          f(self.a_frac), f(self.kv_int), f(self.kv_frac),
                          self.kv_container,
                          kv_scale_mode=self.kv_scale_mode,
                          kv_unroll=self.kv_unroll)

    def layer_static(self, li: int) -> "ModelQuant":
        """Static single-layer view for the unrolled segment path: scalars
        plus THIS layer's concrete container ("fp" layers drop the KV quant
        entirely, so their pools store float pages)."""
        cont = (self.kv_containers[li] if self.kv_containers is not None
                else self.kv_container)
        f = lambda a: None if a is None else a[li]
        kv_i, kv_f = f(self.kv_int), f(self.kv_frac)
        if cont == "fp":
            kv_i = kv_f = None
            cont = self.kv_container
        return ModelQuant(f(self.w_int), f(self.w_frac), f(self.a_int),
                          f(self.a_frac), kv_i, kv_f, cont,
                          kv_scale_mode=self.kv_scale_mode,
                          kv_unroll=self.kv_unroll)


def _mq_flatten(mq):
    return ((mq.w_int, mq.w_frac, mq.a_int, mq.a_frac, mq.kv_int,
             mq.kv_frac),
            (mq.kv_container, mq.kv_containers, mq.kv_scale_mode,
             mq.kv_unroll))


def _mq_unflatten(aux, children):
    return ModelQuant(*children, kv_container=aux[0], kv_containers=aux[1],
                      kv_scale_mode=aux[2], kv_unroll=aux[3])


jax.tree_util.register_pytree_node(ModelQuant, _mq_flatten, _mq_unflatten)


def _quant_weights(params, w_int, w_frac):
    """Fake-quant all >=2-D float leaves (the paper's weight quantization;
    1-D leaves — biases, norm scales, SSM log-decays — stay full precision)."""
    if w_int is None:
        return params

    def q(leaf):
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return fake_quant(leaf, w_int, w_frac)
        return leaf

    return jax.tree_util.tree_map(q, params)


# ---------------------------------------------------------------------------
# Segment structure
# ---------------------------------------------------------------------------
def layer_signatures(cfg) -> Tuple[Tuple[str, str], ...]:
    """Per-layer (kind, ffn) with ffn in {mlp, moe, none}."""
    sigs = []
    kinds = cfg.layer_kinds
    for i in range(cfg.num_layers):
        kind = kinds[i]
        if kind in ("mlstm", "slstm"):
            ffn = "none"
        elif cfg.is_moe_layer(i):
            ffn = "moe"
        else:
            ffn = "mlp"
        sigs.append((kind, ffn))
    return tuple(sigs)


def layer_segments(cfg):
    """Split layers into (pattern, periods, start_idx) segments where
    ``pattern`` repeats exactly ``periods`` times."""
    sigs = layer_signatures(cfg)
    bounds = [0]
    if 0 < cfg.first_k_dense < cfg.num_layers:
        bounds.append(cfg.first_k_dense)
    bounds.append(cfg.num_layers)
    segments = []
    for b0, b1 in zip(bounds[:-1], bounds[1:]):
        seg = sigs[b0:b1]
        n = len(seg)
        period = n
        for p in range(1, n + 1):
            if n % p == 0 and all(seg[i] == seg[i % p] for i in range(n)):
                period = p
                break
        segments.append((tuple(seg[:period]), n // period, b0))
    return segments


# ---------------------------------------------------------------------------
# Single block (pre-norm residual): x += mixer(norm(x)); x += ffn(norm(x))
# ---------------------------------------------------------------------------
def init_block(key, cfg, sig):
    kind, ffn = sig
    ks = jax.random.split(key, 4)
    dt = cfg.param_jnp_dtype
    p = {"norm1": init_rmsnorm(cfg.d_model, dt)}
    if kind == "attn":
        p["mixer"] = (init_mla(ks[0], cfg) if cfg.attention_type == "mla"
                      else init_gqa(ks[0], cfg))
    elif kind == "mamba":
        p["mixer"] = init_mamba(ks[0], cfg)
    elif kind == "mlstm":
        p["mixer"] = init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["mixer"] = init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    if ffn != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, dt)
        if ffn == "moe":
            p["ffn"] = init_moe(ks[1], cfg)
        elif cfg.family == "encoder":
            p["ffn"] = init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
        else:
            p["ffn"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt)
    return p


def block_apply(params, x, positions, *, cfg, sig, cache=None, cache_pos=None,
                quant: Optional[ModelQuant] = None, mrope_positions=None,
                page_table=None, attn_impl: str = "gather",
                kv_valid_len=None):
    """Returns (x, new_cache, aux). ``quant`` holds per-THIS-layer scalars.

    ``attn_impl``/``kv_valid_len`` only affect paged GQA attention: kernel
    vs gather routing (one variable-length path for chunk prefill AND
    decode) and padded-chunk masking (see ``attention.gqa_apply``).
    """
    kind, ffn = sig
    aux = {}
    if quant is not None:
        params = _quant_weights(params, quant.w_int, quant.w_frac)
        kv_quant = (KVQuantSpec(quant.kv_int, quant.kv_frac,
                                quant.kv_container,
                                scale_mode=quant.kv_scale_mode)
                    if quant.kv_int is not None else None)
        state_quant = ((quant.kv_int, quant.kv_frac)
                       if quant.kv_int is not None else None)
    else:
        kv_quant = state_quant = None

    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        if cfg.attention_type == "mla":
            y, new_cache = mla_apply(params["mixer"], h, positions, cfg=cfg,
                                     cache=cache, cache_pos=cache_pos,
                                     kv_quant=kv_quant,
                                     absorbed=cfg.mla_absorbed)
        else:
            y, new_cache = gqa_apply(params["mixer"], h, positions, cfg=cfg,
                                     cache=cache, cache_pos=cache_pos,
                                     kv_quant=kv_quant,
                                     mrope_positions=mrope_positions,
                                     page_table=page_table,
                                     attn_impl=attn_impl,
                                     kv_valid_len=kv_valid_len)
    elif kind == "mamba":
        y, new_cache = mamba_apply(params["mixer"], h, cfg=cfg, state=cache,
                                   state_quant=state_quant)
    elif kind == "mlstm":
        y, new_cache = mlstm_apply(params["mixer"], h, cfg=cfg, state=cache,
                                   state_quant=state_quant)
    elif kind == "slstm":
        y, new_cache = slstm_apply(params["mixer"], h, cfg=cfg, state=cache,
                                   state_quant=state_quant)
    else:
        raise ValueError(kind)
    x = x + y

    if ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, aux = moe_apply(params["ffn"], h, cfg=cfg)
        elif cfg.family == "encoder":
            y = gelu_mlp_apply(params["ffn"], h)
        else:
            y = swiglu_apply(params["ffn"], h)
        x = x + y

    if quant is not None and quant.a_int is not None:
        x = fake_quant(x, quant.a_int, quant.a_frac)  # paper's "data" bits
    # SP: the residual carried between blocks (== the remat-saved tensor) is
    # sequence-sharded over "model"; compute inside the block re-gathers.
    # Cuts saved-activation HBM by the TP degree (16x on the prod mesh).
    x = constrain(x, "dp", "tp", None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Cache construction (stacked per segment/position)
# ---------------------------------------------------------------------------
def init_block_cache(cfg, sig, batch, max_len, dtype, kv_quant=None,
                     paged: Optional[PagedCacheSpec] = None):
    kind, _ = sig
    if kind == "attn":
        if cfg.attention_type == "mla":
            if paged is not None:
                raise NotImplementedError(
                    "paged KV cache supports GQA attention; MLA latent "
                    "paging is future work")
            return init_mla_cache(batch, max_len, cfg, dtype, kv_quant)
        if paged is not None:
            return init_paged_kv_cache(paged.num_pages, paged.page_size,
                                       cfg.num_kv_heads, cfg.head_dim,
                                       dtype, kv_quant)
        return init_kv_cache(batch, max_len, cfg.num_kv_heads, cfg.head_dim,
                             dtype, kv_quant)
    if kind == "mamba":
        return init_mamba_state(batch, cfg, dtype)
    if kind == "mlstm":
        return init_mlstm_state(batch, cfg, dtype)
    if kind == "slstm":
        return init_slstm_state(batch, cfg, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch, max_len, quant: Optional[ModelQuant] = None,
               paged: Optional[PagedCacheSpec] = None):
    """Full-model cache: list per segment of tuple per pattern position of
    stacked (periods, ...) block caches.

    ``paged`` switches attention layers to page-table pools (see
    core.paged_kv): each attention layer gets a (num_pages, page_size, KV,
    hd) pool instead of a (batch, max_len, KV, hd) slab, so HBM scales with
    allocated pages, not worst-case request length. SSM states are O(batch)
    and stay dense.

    With a **per-layer precision profile** (``quant.kv_containers``), pools
    cannot be broadcast-stacked across the whole segment — an int4 layer's
    pool has a different store dtype/shape than an int8 layer's — so each
    (segment, position) entry becomes a LIST of pools per contiguous
    same-container period RUN (each run's pools stacked ``(run_len, ...)``)
    and the forward scans run-by-run (``_segment_scan_grouped``). With
    ``quant.kv_unroll`` the entry degenerates to one UNSTACKED pool per
    period and the forward fully unrolls (``_segment_unrolled``). Requires
    a paged cache."""
    per_layer = quant is not None and quant.kv_containers is not None
    if per_layer and paged is None:
        raise ValueError("per-layer KV containers require a paged cache "
                         "(--page-size > 0)")
    kv_quant = None
    if quant is not None and quant.kv_int is not None:
        kv_quant = KVQuantSpec(8, 0, quant.kv_container)  # container only
    caches = []
    for pattern, periods, start in layer_segments(cfg):
        seg = []
        npos = len(pattern)
        if per_layer:
            runs, _ = _container_runs(quant.kv_containers, start, periods,
                                      npos)
            if quant.kv_unroll:
                runs = [(p, p + 1) for p in range(periods)]
            for pi, sig in enumerate(pattern):
                pools = []
                for p0, p1 in runs:
                    cont = quant.kv_containers[start + p0 * npos + pi]
                    kvq = (None if cont == "fp"
                           else KVQuantSpec(8, 0, cont))
                    one = init_block_cache(
                        cfg, sig, batch, max_len, cfg.compute_jnp_dtype,
                        kvq, paged)
                    if quant.kv_unroll:
                        pools.append(one)            # per-period, unstacked
                    else:
                        pools.append(jax.tree_util.tree_map(
                            lambda a: jnp.broadcast_to(
                                a[None], (p1 - p0,) + a.shape), one))
                seg.append(pools)
            caches.append(tuple(seg))
            continue
        for pi, sig in enumerate(pattern):
            one = init_block_cache(cfg, sig, batch, max_len,
                                   cfg.compute_jnp_dtype, kv_quant, paged)
            seg.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (periods,) + a.shape), one))
        caches.append(tuple(seg))
    return caches


# ---------------------------------------------------------------------------
# Model init / forward
# ---------------------------------------------------------------------------
def init_model(key, cfg):
    k_embed, k_head, k_mtp, k_layers = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    params["embed"] = init_embedding(k_embed, cfg.vocab_size, cfg.d_model,
                                     cfg.param_jnp_dtype)
    params["final_norm"] = init_rmsnorm(cfg.d_model, cfg.param_jnp_dtype)
    if not cfg.tie_embeddings:
        params["head"] = init_lm_head(k_head, cfg.d_model, cfg.vocab_size,
                                      cfg.param_jnp_dtype)
    segs = []
    for si, (pattern, periods, start) in enumerate(layer_segments(cfg)):
        seg_params = []
        for pi, sig in enumerate(pattern):
            keys = jax.random.split(
                jax.random.fold_in(k_layers, si * 64 + pi), periods)
            stacked = jax.vmap(lambda k: init_block(k, cfg, sig))(keys)
            seg_params.append(stacked)
        segs.append(tuple(seg_params))
    params["segments"] = segs
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "proj": dense_init(k_mtp, (2 * cfg.d_model, cfg.d_model),
                               cfg.param_jnp_dtype),
            "block": init_block(jax.random.fold_in(k_mtp, 1), cfg,
                                ("attn", "mlp")),
            "norm": init_rmsnorm(cfg.d_model, cfg.param_jnp_dtype),
        }
    return params


def _segment_scan(seg_params, x, positions, *, cfg, pattern, start, periods,
                  caches=None, cache_pos=None, quant=None,
                  mrope_positions=None, page_table=None,
                  attn_impl: str = "gather", kv_valid_len=None):
    """Scan one segment. Returns (x, new_caches, aux_sums)."""
    npos = len(pattern)
    layer_idx = start + jnp.arange(periods * npos).reshape(periods, npos)
    quant_x = (quant.layer_slice(layer_idx) if quant is not None else None)

    def body(carry, xs):
        x = carry
        seg_p, cache_p, q_p = xs
        new_caches, auxes = [], []
        for pi, sig in enumerate(pattern):
            q_i = (q_p.layer_slice(pi) if q_p is not None else None)
            c_i = cache_p[pi] if cache_p is not None else None
            x, nc, aux = block_apply(
                seg_p[pi], x, positions, cfg=cfg, sig=sig, cache=c_i,
                cache_pos=cache_pos, quant=q_i,
                mrope_positions=mrope_positions, page_table=page_table,
                attn_impl=attn_impl, kv_valid_len=kv_valid_len)
            new_caches.append(nc)
            auxes.append(aux.get("moe_lb_loss", jnp.zeros((), jnp.float32)))
        return x, (tuple(new_caches), jnp.stack(auxes).sum())

    body_fn = body
    if cfg.remat != "none":
        body_fn = jax.checkpoint(body,
                                 policy=jax.checkpoint_policies.nothing_saveable
                                 if cfg.remat == "full" else None)

    xs = (tuple(seg_params), caches, quant_x)
    x, (new_caches, aux_per) = jax.lax.scan(body_fn, x, xs)
    return x, new_caches, aux_per.sum()


def _segment_unrolled(seg_params, x, positions, *, cfg, pattern, start,
                      periods, caches=None, cache_pos=None, quant=None,
                      mrope_positions=None, page_table=None,
                      attn_impl: str = "gather", kv_valid_len=None):
    """Unrolled twin of ``_segment_scan`` for per-layer KV containers.

    A layer's storage container is static program structure (pool dtype,
    int4 lane-packing), so it cannot vary across a ``lax.scan`` — the
    serving path with a per-layer precision profile runs the segment as a
    python loop instead. Caches arrive/leave as per-period LISTS (see
    ``init_cache``); compile cost is O(layers), acceptable for the few-layer
    serving configs the profile path targets."""
    npos = len(pattern)
    new_caches: Tuple[list, ...] = tuple([] for _ in pattern)
    moe_aux = jnp.zeros((), jnp.float32)
    for p in range(periods):
        for pi, sig in enumerate(pattern):
            li = start + p * npos + pi
            q_i = quant.layer_static(li) if quant is not None else None
            c_i = caches[pi][p] if caches is not None else None
            seg_p = jax.tree_util.tree_map(lambda a: a[p], seg_params[pi])
            x, nc, aux = block_apply(
                seg_p, x, positions, cfg=cfg, sig=sig, cache=c_i,
                cache_pos=cache_pos, quant=q_i,
                mrope_positions=mrope_positions, page_table=page_table,
                attn_impl=attn_impl, kv_valid_len=kv_valid_len)
            new_caches[pi].append(nc)
            moe_aux = moe_aux + aux.get("moe_lb_loss",
                                        jnp.zeros((), jnp.float32))
    return x, tuple(list(c) for c in new_caches), moe_aux


def _container_runs(containers, start, periods, npos):
    """Group a segment's periods into contiguous RUNS with an identical
    per-position container signature. Each run can ride one ``lax.scan``
    (static program structure is uniform inside it); a pathological
    alternating profile degenerates to length-1 runs (full unroll).

    Returns ``(runs, sig)`` with ``runs`` a list of ``(p0, p1)`` period
    ranges and ``sig[p]`` the per-position container tuple of period p.
    """
    sig = [tuple(containers[start + p * npos + pi] for pi in range(npos))
           for p in range(periods)]
    runs = []
    p0 = 0
    for p in range(1, periods + 1):
        if p == periods or sig[p] != sig[p0]:
            runs.append((p0, p))
            p0 = p
    return runs, sig


def _run_quant(quant, *, start, npos, p0, p1, sig):
    """Per-position ModelQuant views for one same-container run: Q(I,F)
    arrays stacked ``(run_len,)`` (they ride the scan), containers STATIC
    per position ("fp" positions drop the KV quant — their pools store
    float pages)."""
    out = []
    for pi in range(npos):
        idx = jnp.asarray([start + p * npos + pi for p in range(p0, p1)])
        cont = sig[p0][pi]
        f = lambda a: None if a is None else a[idx]   # noqa: E731
        kv_i, kv_f = f(quant.kv_int), f(quant.kv_frac)
        if cont == "fp":
            kv_i = kv_f = None
            cont = quant.kv_container
        out.append(ModelQuant(f(quant.w_int), f(quant.w_frac),
                              f(quant.a_int), f(quant.a_frac), kv_i, kv_f,
                              cont, kv_scale_mode=quant.kv_scale_mode))
    return tuple(out)


def _segment_scan_grouped(seg_params, x, positions, *, cfg, pattern, start,
                          periods, caches=None, cache_pos=None, quant=None,
                          mrope_positions=None, page_table=None,
                          attn_impl: str = "gather", kv_valid_len=None):
    """Scan-over-layers for **per-layer KV containers**: contiguous
    same-container period runs are scanned (one compiled block body per
    run, so a realistic two-regime ``core.search`` profile costs ~2 bodies
    instead of O(layers)); length-1 runs inline. Caches arrive/leave as
    per-position LISTS of per-run stacked pools (see ``init_cache``).
    Token-identical to ``_segment_unrolled`` — the layer math is the same,
    only the loop structure differs (asserted in tests/test_serve_fast)."""
    npos = len(pattern)
    runs, sig = _container_runs(quant.kv_containers, start, periods, npos)
    new_caches: Tuple[list, ...] = tuple([] for _ in pattern)
    moe_aux = jnp.zeros((), jnp.float32)

    def body(carry, xs):
        x = carry
        seg_p, cache_p, q_p = xs
        new_cs, auxes = [], []
        for pi, bsig in enumerate(pattern):
            c_i = cache_p[pi] if cache_p is not None else None
            x, nc, aux = block_apply(
                seg_p[pi], x, positions, cfg=cfg, sig=bsig, cache=c_i,
                cache_pos=cache_pos, quant=q_p[pi],
                mrope_positions=mrope_positions, page_table=page_table,
                attn_impl=attn_impl, kv_valid_len=kv_valid_len)
            new_cs.append(nc)
            auxes.append(aux.get("moe_lb_loss", jnp.zeros((), jnp.float32)))
        return x, (tuple(new_cs), jnp.stack(auxes).sum())

    body_fn = body
    if cfg.remat != "none":
        body_fn = jax.checkpoint(body,
                                 policy=jax.checkpoint_policies.nothing_saveable
                                 if cfg.remat == "full" else None)

    for ri, (p0, p1) in enumerate(runs):
        q_pos = _run_quant(quant, start=start, npos=npos, p0=p0, p1=p1,
                           sig=sig)
        run_params = tuple(
            jax.tree_util.tree_map(lambda a: a[p0:p1], seg_params[pi])
            for pi in range(npos))
        run_caches = (tuple(caches[pi][ri] for pi in range(npos))
                      if caches is not None else None)
        if p1 - p0 == 1:
            # pathological alternating profile: inline the single period
            first = lambda t: jax.tree_util.tree_map(lambda a: a[0], t)
            x, (nc_run, aux_run) = body(
                x, (tuple(first(p) for p in run_params),
                    (None if run_caches is None
                     else tuple(first(c) for c in run_caches)),
                    tuple(first(q) for q in q_pos)))
            nc_run = tuple(jax.tree_util.tree_map(lambda a: a[None], nc)
                           for nc in nc_run)
            moe_aux = moe_aux + aux_run
        else:
            xs = (run_params, run_caches, q_pos)
            x, (nc_run, aux_per) = jax.lax.scan(body_fn, x, xs)
            moe_aux = moe_aux + aux_per.sum()
        for pi in range(npos):
            new_caches[pi].append(nc_run[pi])
    return x, tuple(list(c) for c in new_caches), moe_aux


def forward_hidden(params, batch, cfg, *, quant: Optional[ModelQuant] = None,
                   caches=None, cache_pos=None, page_table=None,
                   attn_impl: str = "gather", kv_valid_len=None):
    """Backbone only: returns (hidden_after_final_norm, aux); aux carries
    "caches" when caches were threaded.

    batch: {"tokens": (B,S)} or {"embeds": (B,S,D)} (stub frontends), plus
    optional "positions" (B,S), "mrope_positions" (B,S,3).
    ``cache_pos`` is a scalar (shared decode clock) or (B,) per-sequence
    offsets; ``page_table`` (B, NP) activates paged KV caches;
    ``attn_impl`` ("gather" | "pallas") picks the paged attention backend
    for EVERY chunk shape — decode and bucketed prefill share one routing
    layer (``models.attention.route_paged_attention``);
    ``kv_valid_len`` masks padded bucketed-prefill chunk tails.
    """
    cd = cfg.compute_jnp_dtype
    if "embeds" in batch:
        x = batch["embeds"].astype(cd)
    else:
        x = embed_tokens(params["embed"], batch["tokens"],
                         onehot=cfg.embedding_onehot, compute_dtype=cd)
    B, S = x.shape[0], x.shape[1]
    if "positions" in batch:
        positions = batch["positions"]
    else:
        base = cache_pos if cache_pos is not None else 0
        base = jnp.asarray(base, jnp.int32).reshape(-1, 1)  # scalar or (B,)
        positions = jnp.broadcast_to(base + jnp.arange(S)[None, :], (B, S))
    mrope_positions = batch.get("mrope_positions")

    if quant is not None and quant.a_int is not None:
        x = fake_quant(x, quant.a_int[0], quant.a_frac[0])  # embed output
    x = constrain(x, "dp", None, None)   # batch over ("pod","data")

    new_caches, moe_aux = [], jnp.zeros((), jnp.float32)
    if quant is not None and quant.kv_containers is not None:
        # per-layer KV containers: scan contiguous same-container runs
        # (kv_unroll forces the fully unrolled reference path)
        seg_fn = _segment_unrolled if quant.kv_unroll \
            else _segment_scan_grouped
    else:
        seg_fn = _segment_scan
    for si, (pattern, periods, start) in enumerate(layer_segments(cfg)):
        seg_cache = caches[si] if caches is not None else None
        x, nc, aux = seg_fn(
            params["segments"][si], x, positions, cfg=cfg, pattern=pattern,
            start=start, periods=periods, caches=seg_cache,
            cache_pos=cache_pos, quant=quant, mrope_positions=mrope_positions,
            page_table=page_table, attn_impl=attn_impl,
            kv_valid_len=kv_valid_len)
        new_caches.append(nc)
        moe_aux = moe_aux + aux

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"moe_lb_loss": moe_aux,
               "caches": (new_caches if caches is not None else None)}


def forward(params, batch, cfg, *, quant: Optional[ModelQuant] = None,
            caches=None, cache_pos=None, page_table=None,
            attn_impl: str = "gather", kv_valid_len=None):
    """Returns (hidden, logits, new_caches, aux)."""
    x, aux = forward_hidden(params, batch, cfg, quant=quant, caches=caches,
                            cache_pos=cache_pos, page_table=page_table,
                            attn_impl=attn_impl, kv_valid_len=kv_valid_len)
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    logits = lm_head(params.get("head"), x, tied_table=tied)
    return x, logits, aux.pop("caches"), aux


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------
def _head_weight(params, cfg):
    return (params["embed"]["table"].T if cfg.tie_embeddings
            else params["head"]["kernel"])


def train_loss(params, batch, cfg, *, quant=None, lb_coeff=0.01):
    if cfg.loss_chunk > 0:
        # fused head+CE over seq chunks: the (B,S,V) logits never materialize
        hidden, aux = forward_hidden(params, batch, cfg, quant=quant)
        loss = chunked_ce_loss(hidden, _head_weight(params, cfg),
                               batch["labels"], chunk=cfg.loss_chunk,
                               mask=batch.get("mask"))
    else:
        hidden, logits, _, aux = forward(params, batch, cfg, quant=quant)
        loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    metrics = {"ce_loss": loss, "moe_lb_loss": aux["moe_lb_loss"]}
    if cfg.num_experts:
        loss = loss + lb_coeff * aux["moe_lb_loss"]
    if cfg.mtp_depth > 0:
        mtp = params["mtp"]
        cd = cfg.compute_jnp_dtype
        nxt = embed_tokens(params["embed"], batch["tokens"][:, 1:],
                           onehot=cfg.embedding_onehot, compute_dtype=cd)
        h = jnp.concatenate([hidden[:, :-1], nxt], axis=-1) @ \
            mtp["proj"].astype(cd)
        pos = jnp.broadcast_to(jnp.arange(h.shape[1])[None, :], h.shape[:2])
        h, _, _ = block_apply(mtp["block"], h, pos, cfg=cfg,
                              sig=("attn", "mlp"))
        h = rmsnorm(mtp["norm"], h, cfg.norm_eps)
        if cfg.loss_chunk > 0:
            mtp_loss = chunked_ce_loss(h, _head_weight(params, cfg),
                                       batch["labels"][:, 1:],
                                       chunk=cfg.loss_chunk)
        else:
            tied = params["embed"]["table"] if cfg.tie_embeddings else None
            mtp_logits = lm_head(params.get("head"), h, tied_table=tied)
            mtp_loss = cross_entropy(mtp_logits, batch["labels"][:, 1:])
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def prefill(params, batch, cfg, *, quant=None, max_len):
    """Run the prompt through the model, building caches. Returns
    (logits_last, caches, next_pos)."""
    B, S = (batch["tokens"].shape if "tokens" in batch
            else batch["embeds"].shape[:2])
    caches = init_cache(cfg, B, max_len, quant)
    _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                   caches=caches, cache_pos=0)
    return logits[:, -1], caches, S


def decode_step(params, tokens, pos, caches, cfg, *, quant=None,
                page_table=None, attn_impl="gather"):
    """One decode step. tokens: (B,) int32; pos: scalar or (B,) int32
    current lengths. Returns (logits (B,V), new_caches)."""
    batch = {"tokens": tokens[:, None]}
    _, logits, caches, _ = forward(params, batch, cfg, quant=quant,
                                   caches=caches, cache_pos=pos,
                                   page_table=page_table, attn_impl=attn_impl)
    return logits[:, 0], caches
