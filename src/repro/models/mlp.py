"""Feed-forward layers: SwiGLU (LLaMA-family) and GELU (encoder family)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.hints import constrain
from .common import dense_init


def init_swiglu(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype,
                             scale=1.0 / np.sqrt(d_ff)),
    }


def swiglu_apply(params, x):
    cd = x.dtype
    hint = ("dp",) + (None,) * (x.ndim - 2) + ("tp",)
    g = constrain(x @ params["w_gate"].astype(cd), *hint)
    u = x @ params["w_up"].astype(cd)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    return h @ params["w_down"].astype(cd)


def init_gelu_mlp(key, d_model, d_ff, dtype):
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": dense_init(k1, (d_model, d_ff), dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, (d_ff, d_model), dtype,
                            scale=1.0 / np.sqrt(d_ff)),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp_apply(params, x):
    cd = x.dtype
    hint = ("dp",) + (None,) * (x.ndim - 2) + ("tp",)
    h = constrain(x @ params["w_in"].astype(cd) + params["b_in"].astype(cd),
                  *hint)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(cd)
    return h @ params["w_out"].astype(cd) + params["b_out"].astype(cd)
