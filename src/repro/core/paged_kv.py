"""Paged quantized KV-cache subsystem (vLLM-style block tables, paper bits).

The dense serving cache allocates ``batch x max_len`` up front, so HBM scales
with the worst-case request. Here the cache is a **pool of fixed-size pages**
shared by all sequences; a per-sequence **page table** maps logical token
positions to pool pages, and pages are allocated/freed per request by a
host-side free-list allocator. Combined with the paper's reduced-precision
storage, a page holds its tokens in the quantized container:

* ``container="int8"``  — int8 integer grid (Q(I,F) with I+F <= 8),
* ``container="int4"``  — 4-bit grid lane-packed into int32 words along the
  head dim via :func:`repro.core.qtensor.pack_bits` (true N/32 footprint),
* ``container="fp"``    — unquantized pages in the compute dtype (kv_bits=0).

Each page additionally carries a **per-page dequant scale** (value = grid *
scale). With a per-layer Q(I,F) policy the scale is uniform across pages of a
layer (2^-F), but the storage/kernels are per-page so calibrated or dynamic
per-page scaling drops in without a layout change.

Page 0 is **reserved as a scratch page**: idle batch slots keep writing their
stale token somewhere, and pointing their page-table rows at page 0 keeps
those writes off live data. The allocator therefore never hands out page 0.

Device-side ops here are pure jnp (scatter/gather) and serve as the oracle
for the Pallas kernel in ``repro.kernels.paged_kv_attention``, which gathers
pages via scalar-prefetch DMA and dequantizes in VMEM. The serving
integration (``models.attention.gqa_apply``) routes per ``attn_impl``:
``"gather"`` (default) attends through the jnp path — bitwise-identical to
the dense layout (same online-softmax chunk order), the reference mode the
equivalence tests rely on — while ``"pallas"`` sends S=1 decode through the
kernel (interpret-mode on CPU, compiled on TPU; per-page accumulation order,
so equal only to float tolerance).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .fixedpoint import format_params
from .qtensor import pack_bits, unpack_bits, values_per_word

SCRATCH_PAGE = 0

_CONTAINERS = ("int8", "int4", "fp")


class OutOfPagesError(RuntimeError):
    """A request's page demand cannot be backed by the pool.

    Raised *before* any page is handed out (admission preflight) or when the
    free list empties mid-run, always with the counts needed to size
    ``--num-pages`` correctly. ``reserved`` separates pages *promised* to
    live requests but not yet written (admission reservations) from
    ``written`` pages already holding live KV — under prefix sharing a
    request's demand is suffix-only, so deferral decisions need the split,
    not just the free count. ``evictable`` counts unreferenced prefix-cache
    pages that eviction could reclaim, ``requantizable`` the cold cached
    pages the quant-adaptation tier could narrow in place (freeing their
    device pages without a host round trip — why an adapt-enabled pool
    admits more), ``host_pages`` the pages currently parked in the
    host-memory tier (demoted prefixes + preempted requests) — together
    the full device/adapt/host/evictable inventory.
    """

    def __init__(self, *, needed: int, free: int, total: int,
                 rid: Optional[int] = None, reserved: int = 0,
                 written: int = 0, evictable: int = 0,
                 requantizable: int = 0, host_pages: int = 0):
        self.needed, self.free, self.total, self.rid = needed, free, total, rid
        self.reserved, self.written = reserved, written
        self.evictable = evictable
        self.requantizable = requantizable
        self.host_pages = host_pages
        who = f"request {rid}" if rid is not None else "allocation"
        extra = ""
        if reserved or written or evictable or requantizable or host_pages:
            extra = (f" [{written} written, {reserved} reserved-unwritten, "
                     f"{evictable} evictable-cached, "
                     f"{requantizable} requantizable, "
                     f"{host_pages} host-tier]")
        super().__init__(
            f"KV page pool cannot back {who}: needs {needed} page(s), "
            f"{free} free of {total} usable (page 0 is scratch){extra}; "
            f"raise --num-pages, shrink --max-new, or lower concurrency")


@dataclasses.dataclass(frozen=True)
class PagedCacheSpec:
    """What a model needs to know to build paged caches: pool geometry.

    ``num_pages`` includes the reserved scratch page 0. Every attention layer
    gets its own pool of this geometry (layers see the same page table, so
    one host-side allocator serves the whole model).
    """

    page_size: int
    num_pages: int

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")


@dataclasses.dataclass(frozen=True)
class PagedKVLayout:
    """Static shape/dtype description of one layer's paged KV pool."""

    num_pages: int          # pool pages, including the reserved scratch page
    page_size: int          # tokens per page
    num_kv_heads: int
    head_dim: int
    container: str = "int8"
    dtype: object = jnp.float32  # compute/storage dtype for container="fp"

    def __post_init__(self):
        if self.container not in _CONTAINERS:
            raise ValueError(f"container must be one of {_CONTAINERS}, "
                             f"got {self.container!r}")
        if self.container == "int4" and self.head_dim % values_per_word(4):
            raise ValueError("int4 packing needs head_dim % 8 == 0, got "
                             f"{self.head_dim}")
        if self.num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")

    @property
    def bits(self) -> int:
        return {"int8": 8, "int4": 4}.get(self.container, 0)

    @property
    def store_head_dim(self) -> int:
        """Last-dim extent of the stored page (packed for int4)."""
        if self.container == "int4":
            return self.head_dim // values_per_word(4)
        return self.head_dim

    @property
    def store_dtype(self):
        return {"int8": jnp.int8, "int4": jnp.int32,
                "fp": self.dtype}[self.container]

    @property
    def page_bytes(self) -> int:
        """Stored bytes of ONE page of ONE of k/v (scales excluded)."""
        itemsize = jnp.dtype(self.store_dtype).itemsize
        return (self.page_size * self.num_kv_heads * self.store_head_dim
                * itemsize)

    def tokens_to_pages(self, tokens: int) -> int:
        return -(-tokens // self.page_size)


def max_pages_per_seq(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


# ---------------------------------------------------------------------------
# Host-side page allocator
# ---------------------------------------------------------------------------
class PageAllocator:
    """Refcounted free-list allocator over pages 1..num_pages-1 (0: scratch).

    Pure host-side bookkeeping: the device pool is preallocated; "allocating"
    a page just hands out an index. Fragmentation is free — any page serves
    any (sequence, logical-block) slot via the page table.

    **Refcounts** are what make prefix sharing safe: ``alloc`` returns a page
    at refcount 1, ``incref`` adds a reference (a sharer's page table aliasing
    the page, or the prefix cache retaining it), and ``free`` RELEASES one
    reference per page — the page returns to the free list only when its
    count reaches zero, so no caller can ever free a page out from under a
    sharer, and releasing a page twice from the same owner raises.

    ``reclaim`` (optional callable ``n -> pages_freed``) is invoked when
    the free list empties mid-``alloc`` — the prefix cache registers its
    eviction here, which under a tiered page store DEMOTES unreferenced
    cached prefixes to host memory (destructive LRU drop otherwise), so
    pool pressure recycles pages instead of failing the allocation.
    ``pressure`` is a list of further callbacks (same ``n -> freed``
    contract, ``add_pressure``) tried in order after ``reclaim`` — an
    extension point for additional reclaimers (e.g. future async offload
    writeback); nothing in the serving stack registers one today.
    ``host_inventory`` (optional zero-arg callable -> page count) lets
    :class:`OutOfPagesError` report the host-tier inventory alongside the
    device counts; ``requant_inventory`` does the same for the pages the
    quant-adaptation tier could still narrow in place.

    ``metrics`` (optional :class:`repro.runtime.telemetry.MetricsRegistry`)
    counts allocations ("alloc.allocs") and pressure invocations
    ("alloc.reclaims"), and registers a live "alloc.free_pages" gauge —
    free-list occupancy readable from any snapshot.
    """

    def __init__(self, num_pages: int, *, metrics=None):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refs: Dict[int, int] = {}
        self.reclaim = None  # optional: n_pages -> n_freed (LRU eviction)
        self.pressure: List = []      # further n -> n_freed callbacks
        self.host_inventory = None    # optional: () -> host-tier page count
        self.requant_inventory = None  # optional: () -> requantizable pages
        if metrics is None:
            from ..runtime.telemetry import MetricsRegistry
            metrics = MetricsRegistry()
        self.metrics = metrics
        self._c_allocs = metrics.counter("alloc.allocs")
        self._c_reclaims = metrics.counter("alloc.reclaims")
        metrics.register_gauge("alloc.free_pages", lambda: len(self._free))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_usable(self) -> int:
        return self.num_pages - 1

    def refcount(self, page: int) -> int:
        """Live references on ``page`` (0 = free / never allocated)."""
        return self._refs.get(page, 0)

    def check(self, needed: int, *, rid: Optional[int] = None) -> None:
        """Preflight: raise OutOfPagesError unless ``needed`` pages are free.

        Deliberately CONSERVATIVE: only the free list is consulted, not the
        ``reclaim``/``pressure`` hooks — pages eviction could recover don't
        count here (the serving admission path does its own reclaim-aware
        accounting).
        """
        if needed > self.num_free:
            raise OutOfPagesError(needed=needed, free=self.num_free,
                                  total=self.num_usable, rid=rid,
                                  requantizable=self.requant_pages(),
                                  host_pages=self.host_pages())

    def host_pages(self) -> int:
        """Pages currently parked in the host tier (0 without a tier)."""
        return int(self.host_inventory()) if self.host_inventory else 0

    def requant_pages(self) -> int:
        """Cold cached pages the quant tier could narrow in place (0
        without an adaptation tier)."""
        return (int(self.requant_inventory())
                if self.requant_inventory else 0)

    def add_pressure(self, fn) -> None:
        """Register an ``n_pages -> n_freed`` pressure callback (tried after
        ``reclaim`` when the free list empties mid-``alloc``)."""
        self.pressure.append(fn)

    def _apply_pressure(self, needed: int) -> None:
        if self._free:
            return
        self._c_reclaims.inc()
        if self.reclaim is not None:
            self.reclaim(needed)
        for fn in self.pressure:
            if self._free:
                return
            fn(needed)

    def alloc(self) -> int:
        if not self._free:
            self._apply_pressure(1)
        if not self._free:
            raise OutOfPagesError(needed=1, free=0, total=self.num_usable,
                                  requantizable=self.requant_pages(),
                                  host_pages=self.host_pages())
        page = self._free.pop()
        self._refs[page] = 1
        self._c_allocs.inc()
        return page

    def incref(self, page: int) -> None:
        if self._refs.get(page, 0) <= 0:
            raise ValueError(f"incref of unallocated page {page}")
        self._refs[page] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Release ONE reference per page; recycle pages that hit zero."""
        for p in pages:
            if not (0 < p < self.num_pages):
                raise ValueError(f"freeing invalid page id {p}")
            refs = self._refs.get(p, 0)
            if refs <= 0:
                raise ValueError(f"double free of page {p}")
            if refs == 1:
                del self._refs[p]
                self._free.append(p)
            else:
                self._refs[p] = refs - 1


# ---------------------------------------------------------------------------
# Device-side pool ops (pure jnp; oracle for the Pallas kernel)
# ---------------------------------------------------------------------------
def init_paged_pool(layout: PagedKVLayout) -> Dict[str, jnp.ndarray]:
    """One layer's paged pool: k/v pages + per-page dequant scales."""
    shape = (layout.num_pages, layout.page_size, layout.num_kv_heads,
             layout.store_head_dim)
    return {
        "k_pages": jnp.zeros(shape, layout.store_dtype),
        "v_pages": jnp.zeros(shape, layout.store_dtype),
        "k_scale": jnp.ones((layout.num_pages,), jnp.float32),
        "v_scale": jnp.ones((layout.num_pages,), jnp.float32),
    }


def _quant_grid(x, int_bits, frac_bits):
    """float (..., hd) -> (integer grid float array, reciprocal scale)."""
    scale, qmin, qmax = format_params(int_bits, frac_bits)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) * scale), qmin, qmax)
    return q, 1.0 / scale


def _pack_grid(q, bits):
    packed, _ = pack_bits(q.astype(jnp.int32), bits)
    return packed


def paged_update(pool, k_new, v_new, page_table, pos, *, page_size: int,
                 container: str = "int8", int_bits=None, frac_bits=None,
                 valid_len=None, scale_mode: str = "static"):
    """Append S new tokens per sequence to the paged pool.

    k_new/v_new: (B, S, KV, hd) float; page_table: (B, NP) int32;
    pos: scalar or (B,) int32 — the logical position of the FIRST new token
    per sequence. ``valid_len`` (scalar or (B,) int32, optional) marks only
    the first ``valid_len`` of the S tokens as real: the rest are padding
    (bucketed prefill pads chunks up to a power-of-two) and their writes are
    redirected to the scratch page, so a padded chunk can never clobber live
    pages (a padded tail position can even alias back into the sequence's
    last page once ``pos + S`` exceeds the page-table span, because the
    block gather clamps). Returns the updated pool dict.

    ``scale_mode`` picks the dequant-scale calibration for int containers:

    * ``"static"`` (default) — the layer's Q(I,F) grid scale, uniform across
      pages (bitwise-reproducible; the reference mode).
    * ``"page"``  — **dynamic per-page max-abs calibration**: each touched
      page's scale is the running max-abs of the values written to it over
      the container's symmetric grid, so small-magnitude layers get a far
      finer step than the static Q(I,F) grid. When a write raises a page's
      scale, the page's existing grid values are requantized in place
      (gather -> rescale -> scatter of just the touched pages), so earlier
      tokens stay correct under the new scale.

    Distinct sequences must map to distinct pages (the allocator guarantees
    it; prefix-shared pages are never written by sharers), so the scatter is
    collision-free except on the shared scratch page, where any write order
    is acceptable.
    """
    B, S = k_new.shape[0], k_new.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    blocks = positions // page_size                       # (B, S)
    blocks = jnp.minimum(blocks, page_table.shape[1] - 1)
    offsets = positions % page_size                       # (B, S)
    pids = jnp.take_along_axis(page_table, blocks, axis=1)  # (B, S)
    valid = jnp.ones((B, S), bool)
    if valid_len is not None:
        vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32).reshape(-1),
                              (B,))
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < vl[:, None]
        pids = jnp.where(valid, pids, SCRATCH_PAGE)

    if container == "fp":
        # fp writes store raw floats under a UNIT page scale. A recycled
        # page can carry a stale non-unit scale from a quant-tier restore
        # (widen_blob keeps the parked grid + scale for fp pools), so the
        # page's first write (offset 0) resets its scale; writes at higher
        # offsets extend a page this owner already reset (or a CoW copy,
        # which copy_pool_pages folds to unit scale).
        first = jnp.where(offsets == 0, pids, SCRATCH_PAGE)
        return {
            "k_pages": pool["k_pages"].at[pids, offsets].set(
                k_new.astype(pool["k_pages"].dtype)),
            "v_pages": pool["v_pages"].at[pids, offsets].set(
                v_new.astype(pool["v_pages"].dtype)),
            "k_scale": pool["k_scale"].at[first].set(1.0),
            "v_scale": pool["v_scale"].at[first].set(1.0),
        }

    if scale_mode == "page":
        return _paged_update_page_scale(
            pool, k_new, v_new, page_table, pos, pids, offsets, valid,
            page_size=page_size, container=container)
    if scale_mode != "static":
        raise ValueError(f"scale_mode must be 'static' or 'page', "
                         f"got {scale_mode!r}")

    k_q, rscale = _quant_grid(k_new, int_bits, frac_bits)
    v_q, _ = _quant_grid(v_new, int_bits, frac_bits)
    if container == "int4":
        k_q, v_q = _pack_grid(k_q, 4), _pack_grid(v_q, 4)
    sc = jnp.broadcast_to(jnp.asarray(rscale, jnp.float32), pids.shape)
    return {
        "k_pages": pool["k_pages"].at[pids, offsets].set(
            k_q.astype(pool["k_pages"].dtype)),
        "v_pages": pool["v_pages"].at[pids, offsets].set(
            v_q.astype(pool["v_pages"].dtype)),
        "k_scale": pool["k_scale"].at[pids].set(sc),
        "v_scale": pool["v_scale"].at[pids].set(sc),
    }


_SCALE_EPS = 2.0 ** -20   # floor for all-zero chunks (avoids 0-division)


def _paged_update_page_scale(pool, k_new, v_new, page_table, pos, pids,
                             offsets, valid, *, page_size: int,
                             container: str):
    """Per-page max-abs calibrated write (``scale_mode="page"``).

    Touched pages form a contiguous block range per row (positions are
    contiguous), so at most ``ceil((S-1)/ps) + 2`` pages per row are
    gathered, requantized under the (possibly raised) new scale, scattered
    back, and only then receive the new tokens. Pages past the row's table
    span and fully-invalid slots redirect to the scratch page, whose content
    is never read un-masked — duplicate scratch scatters are don't-care.

    SHARING CONTRACT: a scale raise rewrites the touched pages' existing
    grids IN PLACE, which silently changes the dequant values any aliased
    reader sees — so every page in the written block range must be at
    refcount 1. The serving layer enforces this (in page-scale mode the
    prefix cache never retains a page the owner will keep writing, and
    ``BatchedServer._ensure_page`` asserts refcount 1 on the write-target
    block); static-scale mode has no such hazard because old grids are
    never rewritten.
    """
    B, S = k_new.shape[0], k_new.shape[1]
    ps, NP = page_size, page_table.shape[1]
    bits = {"int8": 8, "int4": 4}[container]
    qmax = float(2 ** (bits - 1) - 1)
    hd = k_new.shape[-1]

    nb = (S - 1) // ps + 2                      # static touched-block bound
    blk_first = pos // ps                       # (B,)
    # tokens past the page-table span clamp into the LAST page in static
    # mode (harmless there: uniform scale, stale rewrite). Under per-page
    # scales that rewrite would disagree with the page's stored scale, so
    # out-of-span tokens redirect to the scratch page instead.
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    in_tok = positions // ps < NP               # (B, S)
    pids = jnp.where(in_tok, pids, SCRATCH_PAGE)
    blocks_nb = blk_first[:, None] + jnp.arange(nb, dtype=jnp.int32)[None, :]
    in_span = blocks_nb < NP
    pids_nb = jnp.take_along_axis(page_table,
                                  jnp.minimum(blocks_nb, NP - 1), axis=1)
    pids_nb = jnp.where(in_span, pids_nb, SCRATCH_PAGE)   # (B, nb)

    # a block is "fresh" iff this chunk's first write to it lands at offset
    # 0 — its prior content (freed-page garbage) must not pin the old scale
    fresh = (jnp.arange(nb, dtype=jnp.int32)[None, :] > 0) | \
        ((pos % ps) == 0)[:, None]              # (B, nb)

    rows = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, S))
    lb = jnp.clip(positions // ps - blk_first[:, None], 0, nb - 1)  # (B, S)

    def _page_scales(x_new, old_scale):
        amax = jnp.max(jnp.abs(x_new.astype(jnp.float32)),
                       axis=(-2, -1))           # (B, S) per-token max-abs
        amax = jnp.where(valid & in_tok, amax, 0.0)
        need = jnp.zeros((B, nb), jnp.float32).at[rows, lb].max(amax) / qmax
        old = old_scale[pids_nb]                # (B, nb)
        new = jnp.maximum(jnp.where(fresh, 0.0, old),
                          jnp.maximum(need, _SCALE_EPS))
        ratio = jnp.where(fresh, 1.0, old / new)
        return new, ratio

    def _requant_and_write(pages, scale, x_new, new_scale, ratio):
        got = pages[pids_nb]                    # (B, nb, ps, KV, hdw)
        if container == "int4":
            got = unpack_bits(got, 4, hd)
        re = jnp.round(got.astype(jnp.float32)
                       * ratio[:, :, None, None, None])
        re = jnp.clip(re, -qmax, qmax)
        sc_tok = new_scale[rows, lb]            # (B, S) per-token page scale
        q = jnp.clip(jnp.round(x_new.astype(jnp.float32)
                               / sc_tok[:, :, None, None]), -qmax, qmax)
        if container == "int4":
            re = _pack_grid(re, 4)
            q = _pack_grid(q, 4)
        pages = pages.at[pids_nb].set(re.astype(pages.dtype))
        pages = pages.at[pids, offsets].set(q.astype(pages.dtype))
        return pages, scale.at[pids_nb].set(new_scale)

    k_ns, k_ratio = _page_scales(k_new, pool["k_scale"])
    v_ns, v_ratio = _page_scales(v_new, pool["v_scale"])
    k_pages, k_scale = _requant_and_write(pool["k_pages"], pool["k_scale"],
                                          k_new, k_ns, k_ratio)
    v_pages, v_scale = _requant_and_write(pool["v_pages"], pool["v_scale"],
                                          v_new, v_ns, v_ratio)
    return {"k_pages": k_pages, "v_pages": v_pages,
            "k_scale": k_scale, "v_scale": v_scale}


def paged_gather(pool, page_table, *, container: str = "int8",
                 head_dim: Optional[int] = None, dtype=jnp.float32):
    """Materialize the logical dense cache view (B, NP*ps, KV, hd) in float.

    Gathers each sequence's pages and dequantizes with the per-page scales.
    This is the oracle/integration path — the Pallas kernel does the same
    gather page-by-page in VMEM without ever materializing the dense view.
    """
    kg = pool["k_pages"][page_table]      # (B, NP, ps, KV, hdw)
    vg = pool["v_pages"][page_table]
    ks = pool["k_scale"][page_table]      # (B, NP)
    vs = pool["v_scale"][page_table]
    B, NP, ps, KV = kg.shape[:4]

    if container == "int4":
        assert head_dim is not None
        kg = unpack_bits(kg, 4, head_dim)
        vg = unpack_bits(vg, 4, head_dim)
    # per-page scales apply to every container; float-page writers keep
    # their scales at 1.0
    k = (kg.astype(jnp.float32) * ks[:, :, None, None, None]).astype(dtype)
    v = (vg.astype(jnp.float32) * vs[:, :, None, None, None]).astype(dtype)
    hd = k.shape[-1]
    return (k.reshape(B, NP * ps, KV, hd), v.reshape(B, NP * ps, KV, hd))


def copy_pool_pages(pool, src: int, dst: int, *, page_axis: int = 0):
    """Copy one page's stored bytes + scales ``src -> dst`` (copy-on-write).

    The prefix cache calls this when a request diverges *inside* a partially
    shared page: the sharer gets a private copy to extend while the cached
    source page stays byte-identical for its other readers. ``page_axis``
    is 0 for a single layer's pool and 1 for the (periods, NP, ...) stacked
    pools the segmented scan carries.

    For FP pools the source scale is folded into the copied floats and the
    copy gets a unit scale: the copier extends the page with fresh fp
    writes, which store raw floats under a unit page scale, while the
    source may be a quant-tier restore whose non-unit scale must keep
    applying to the untouched original (``page_store.widen_blob``). Int
    pools copy bytes + scales verbatim (extension writes there recalibrate
    against the page scale explicitly).
    """
    idx = (slice(None),) * page_axis

    def cp(a):
        return a.at[idx + (dst,)].set(a[idx + (src,)])

    if pool_container(pool) == "fp":
        def fold(pages, scale):
            s = scale[idx + (src,)]
            vals = (pages[idx + (src,)].astype(jnp.float32)
                    * s[..., None, None, None])
            return (pages.at[idx + (dst,)].set(vals.astype(pages.dtype)),
                    scale.at[idx + (dst,)].set(1.0))

        k_pages, k_scale = fold(pool["k_pages"], pool["k_scale"])
        v_pages, v_scale = fold(pool["v_pages"], pool["v_scale"])
        return {"k_pages": k_pages, "v_pages": v_pages,
                "k_scale": k_scale, "v_scale": v_scale}

    return {"k_pages": cp(pool["k_pages"]), "v_pages": cp(pool["v_pages"]),
            "k_scale": cp(pool["k_scale"]), "v_scale": cp(pool["v_scale"])}


def pool_bytes(pool) -> int:
    """True stored bytes of one layer's pool (pages + scales)."""
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.tree_util.tree_leaves(pool))


def pool_container(pool) -> str:
    """Container name of a pool dict, inferred from the stored dtype."""
    dt = pool["k_pages"].dtype
    if jnp.issubdtype(dt, jnp.floating):
        return "fp"
    return "int8" if dt == jnp.dtype(jnp.int8) else "int4"


# ---------------------------------------------------------------------------
# Full-model cache traversal (used by the tiered page store + benchmarks)
# ---------------------------------------------------------------------------
def iter_kv_pools(caches):
    """Yield ``(pool_dict, page_axis)`` for every paged attention pool in a
    full-model cache structure (``models.transformer.init_cache``), in a
    DETERMINISTIC traversal order.

    Handles all three layouts: stacked ``(periods, NP, ...)`` entries and the
    per-run stacked lists of the grouped per-layer-profile scan (both
    ``page_axis=1``), and the per-period unstacked dicts of the fully
    unrolled profile path (``page_axis=0``). Non-paged entries (dense KV
    slabs, SSM states) are skipped.
    """
    for seg in caches:
        for entry in seg:
            for d in (entry if isinstance(entry, list) else [entry]):
                if isinstance(d, dict) and "k_pages" in d:
                    yield d, (1 if d["k_pages"].ndim == 5 else 0)


def map_kv_pools(caches, fn):
    """Rebuild a full-model cache structure, replacing every paged pool dict
    with ``fn(pool, page_axis)``; non-pool entries pass through unchanged.
    Traversal order matches :func:`iter_kv_pools`."""

    def one(d):
        if isinstance(d, dict) and "k_pages" in d:
            return fn(d, 1 if d["k_pages"].ndim == 5 else 0)
        return d

    new_caches = []
    for seg in caches:
        seg_new = []
        for entry in seg:
            if isinstance(entry, list):
                seg_new.append([one(d) for d in entry])
            else:
                seg_new.append(one(entry))
        new_caches.append(tuple(seg_new))
    return new_caches


def caches_kv_bytes(caches) -> Dict[str, int]:
    """Device at-rest bytes of every paged pool, split per container — one
    half of the device/host inventory the tiered page store reports."""
    out: Dict[str, int] = {}
    for pool, _ in iter_kv_pools(caches):
        cont = pool_container(pool)
        out[cont] = out.get(cont, 0) + pool_bytes(pool)
    return out
