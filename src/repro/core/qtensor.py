"""Packed quantized tensors: real memory-footprint reduction.

``fake_quant`` models the paper's accuracy effects; ``QuantizedTensor`` makes
the footprint reduction real: integer grids live in the smallest byte-aligned
container (int8/int16), and sub-byte formats (<= 8 bits) can additionally be
lane-packed, k values per int32 word, matching how the TPU kernels in
``repro.kernels`` store weights/KV in HBM.

QuantizedTensor is a pytree, so it checkpoints, shards and jits like any
array. ``nbytes`` reports the true stored size, which is what the traffic
model and EXPERIMENTS.md footprint numbers are derived from.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .fixedpoint import FixedPointFormat, dequantize, quantize


# ---------------------------------------------------------------------------
# Bit packing: k N-bit two's-complement values per int32 word (little-endian
# within the word). Pure jnp so the Pallas kernels' unpack math has an oracle.
# ---------------------------------------------------------------------------
def values_per_word(bits: int) -> int:
    if not (1 <= bits <= 16):
        raise ValueError(f"pack supports 1..16 bit values, got {bits}")
    return 32 // bits


def pack_bits(q, bits: int):
    """Pack integer-grid values (any int/float dtype, already clipped to the
    N-bit two's-complement range) into int32 words along the last axis.

    Last axis is padded to a multiple of values_per_word(bits).
    Returns (packed int32 array, original last-dim size).
    """
    k = values_per_word(bits)
    q = jnp.asarray(q)
    n = q.shape[-1]
    pad = (-n) % k
    if pad:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    qi = jnp.asarray(q, jnp.int32) & ((1 << bits) - 1)  # two's complement field
    qi = qi.reshape(*qi.shape[:-1], -1, k)
    shifts = (jnp.arange(k, dtype=jnp.int32) * bits)[None, :]
    packed = jnp.sum(qi << shifts, axis=-1).astype(jnp.int32)  # disjoint fields
    return packed, n


def unpack_bits(packed, bits: int, n: int):
    """Inverse of :func:`pack_bits`; returns int32 sign-extended values."""
    k = values_per_word(bits)
    packed = jnp.asarray(packed, jnp.int32)
    shifts = (jnp.arange(k, dtype=jnp.int32) * bits)[None, :]
    fields = (packed[..., None] >> shifts) & ((1 << bits) - 1)
    # sign extend
    sign_bit = 1 << (bits - 1)
    vals = (fields ^ sign_bit) - sign_bit
    vals = vals.reshape(*packed.shape[:-1], packed.shape[-1] * k)
    return vals[..., :n]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Fixed-point tensor with explicit storage container.

    ``data`` is either a small-int container (int8/int16) holding the integer
    grid directly, or an int32 lane-packed buffer when ``packed`` is True.
    """

    data: jnp.ndarray
    int_bits: int
    frac_bits: int
    shape: tuple  # logical shape
    packed: bool = False

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.data,), (self.int_bits, self.frac_bits, self.shape, self.packed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (data,) = children
        int_bits, frac_bits, shape, packed = aux
        return cls(data, int_bits, frac_bits, shape, packed)

    # -- properties ----------------------------------------------------------
    @property
    def fmt(self) -> FixedPointFormat:
        return FixedPointFormat(self.int_bits, self.frac_bits)

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.data.shape)) * self.data.dtype.itemsize

    @property
    def logical_nbytes_fp32(self) -> int:
        return int(np.prod(self.shape)) * 4

    @property
    def footprint_ratio(self) -> float:
        """stored bytes / fp32 bytes — the paper's TR numerator per tensor."""
        return self.nbytes / max(self.logical_nbytes_fp32, 1)

    # -- construction / use ----------------------------------------------------
    @classmethod
    def from_float(cls, x, int_bits: int, frac_bits: int, *, pack: bool = False,
                   rounding="nearest", key=None) -> "QuantizedTensor":
        fmt = FixedPointFormat(int_bits, frac_bits)
        q = quantize(x, int_bits, frac_bits, rounding=rounding, key=key)
        shape = tuple(x.shape)
        if pack:
            if fmt.total_bits > 16:
                raise ValueError("packing supports <=16-bit formats")
            flat = q.reshape(-1) if q.ndim == 0 else q.reshape(*q.shape)
            packed, _ = pack_bits(flat, fmt.total_bits)
            return cls(packed, int_bits, frac_bits, shape, packed=True)
        return cls(q.astype(fmt.container_dtype()), int_bits, frac_bits, shape)

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        if self.packed:
            vals = unpack_bits(self.data, self.total_bits, self.shape[-1])
            vals = vals.reshape(self.shape)
        else:
            vals = self.data
        return dequantize(vals, self.int_bits, self.frac_bits).astype(dtype)
