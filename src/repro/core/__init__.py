"""Core per-layer reduced-precision library (the paper's contribution).

Public API re-exports; see DESIGN.md §1-3 for the mapping to the paper.
"""
from .fixedpoint import (FixedPointFormat, fake_quant, fake_quant_ste,
                         format_params, quantize, dequantize,
                         quantization_error, required_int_bits)
from .qtensor import QuantizedTensor, pack_bits, unpack_bits, values_per_word
from .policy import FIELDS, LayerPolicy, PrecisionPolicy
from .traffic import LayerTraffic, TrafficModel, BASELINE_BITS
from .calibrate import RangeStats, calibrated_policy, int_bits_for
from .search import (SearchPoint, SearchResult, greedy_pareto_search,
                     sensitivity_profile, sensitivity_search)

__all__ = [
    "FixedPointFormat", "fake_quant", "fake_quant_ste", "format_params",
    "quantize", "dequantize", "quantization_error", "required_int_bits",
    "QuantizedTensor", "pack_bits", "unpack_bits", "values_per_word",
    "FIELDS", "LayerPolicy", "PrecisionPolicy",
    "LayerTraffic", "TrafficModel", "BASELINE_BITS",
    "RangeStats", "calibrated_policy", "int_bits_for",
    "SearchPoint", "SearchResult", "greedy_pareto_search",
    "sensitivity_profile", "sensitivity_search",
]
