"""Per-layer precision policies (the paper's central object).

A network is a sequence of named layers; each layer carries independent
fixed-point formats for its **weights** and its output **data** (paper §2.1
"Values Studied").  ``PrecisionPolicy`` is the thing the search in
``core.search`` mutates, the traffic model prices, and ``quant.apply``
installs into a model.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .fixedpoint import FixedPointFormat

FIELDS = ("weight_int", "weight_frac", "data_int", "data_frac")


@dataclasses.dataclass(frozen=True)
class LayerPolicy:
    """Q(I,F) formats for one layer's weights and output data.

    ``None`` for the weight format marks a weight-less layer (e.g. a residual
    boundary or an activation-only stage).
    """

    weight: Optional[FixedPointFormat]
    data: Optional[FixedPointFormat]

    def with_field(self, field: str, value: int) -> "LayerPolicy":
        w, d = self.weight, self.data
        if field == "weight_int" and w:
            w = FixedPointFormat(value, w.frac_bits)
        elif field == "weight_frac" and w:
            w = FixedPointFormat(w.int_bits, value)
        elif field == "data_int" and d:
            d = FixedPointFormat(value, d.frac_bits)
        elif field == "data_frac" and d:
            d = FixedPointFormat(d.int_bits, value)
        return LayerPolicy(w, d)

    def get_field(self, field: str) -> Optional[int]:
        w, d = self.weight, self.data
        return {
            "weight_int": w.int_bits if w else None,
            "weight_frac": w.frac_bits if w else None,
            "data_int": d.int_bits if d else None,
            "data_frac": d.frac_bits if d else None,
        }[field]

    def short(self) -> str:
        ws = self.weight.short() if self.weight else "-"
        ds = self.data.short() if self.data else "-"
        return f"W:{ws}/D:{ds}"


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """An ordered mapping layer-name -> LayerPolicy."""

    names: tuple
    layers: tuple  # tuple[LayerPolicy]

    def __post_init__(self):
        assert len(self.names) == len(self.layers)

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def uniform(names: Sequence[str],
                weight: Optional[FixedPointFormat],
                data: Optional[FixedPointFormat]) -> "PrecisionPolicy":
        return PrecisionPolicy(tuple(names),
                               tuple(LayerPolicy(weight, data) for _ in names))

    @staticmethod
    def fp32_baseline(names: Sequence[str]) -> "PrecisionPolicy":
        """The 'no quantization' marker policy (None formats everywhere)."""
        return PrecisionPolicy(tuple(names),
                               tuple(LayerPolicy(None, None) for _ in names))

    # -- access / update ------------------------------------------------------
    def __len__(self):
        return len(self.names)

    def __getitem__(self, name: str) -> LayerPolicy:
        return self.layers[self.names.index(name)]

    def replace_layer(self, idx: int, lp: LayerPolicy) -> "PrecisionPolicy":
        layers = list(self.layers)
        layers[idx] = lp
        return PrecisionPolicy(self.names, tuple(layers))

    def with_field(self, idx: int, field: str, value: int) -> "PrecisionPolicy":
        return self.replace_layer(idx, self.layers[idx].with_field(field, value))

    def decrement(self, idx: int, field: str) -> Optional["PrecisionPolicy"]:
        """One step of the paper's search: remove one bit from (layer, field).

        Returns None if the field is absent or already at its floor
        (1 integer bit — the sign — or 0 fractional bits).
        """
        cur = self.layers[idx].get_field(field)
        if cur is None:
            return None
        floor = 1 if field.endswith("_int") else 0
        if cur <= floor:
            return None
        return self.with_field(idx, field, cur - 1)

    def candidate_moves(self, fields: Iterable[str] = FIELDS):
        """All single-bit decrements (the paper's 'delta configurations')."""
        out = []
        for i in range(len(self)):
            for f in fields:
                p = self.decrement(i, f)
                if p is not None:
                    out.append(((i, f), p))
        return out

    # -- vectorized views (for scan-over-layers models) ------------------------
    def stacked_arrays(self, field_prefix: str):
        """(int_bits, frac_bits) as (L,) float32 arrays for lax.scan bodies.

        Layers with a ``None`` format get a sentinel wide format (Q16.15) that
        is numerically a no-op at bf16/f32 ranges used here; the model also
        receives an ``enabled`` mask.
        """
        ints, fracs, enabled = [], [], []
        for lp in self.layers:
            fmt = lp.weight if field_prefix == "weight" else lp.data
            if fmt is None:
                ints.append(16)
                fracs.append(14)
                enabled.append(False)
            else:
                ints.append(fmt.int_bits)
                fracs.append(fmt.frac_bits)
                enabled.append(True)
        return (jnp.asarray(ints, jnp.float32), jnp.asarray(fracs, jnp.float32),
                jnp.asarray(enabled, jnp.bool_))

    # -- serialization ----------------------------------------------------------
    def to_json(self) -> str:
        def enc(fmt):
            return None if fmt is None else [fmt.int_bits, fmt.frac_bits]
        return json.dumps({
            "names": list(self.names),
            "layers": [{"weight": enc(lp.weight), "data": enc(lp.data)}
                       for lp in self.layers],
        })

    @staticmethod
    def from_json(s: str) -> "PrecisionPolicy":
        obj = json.loads(s)
        def dec(v):
            return None if v is None else FixedPointFormat(v[0], v[1])
        layers = tuple(LayerPolicy(dec(l["weight"]), dec(l["data"]))
                       for l in obj["layers"])
        return PrecisionPolicy(tuple(obj["names"]), layers)

    def short(self) -> str:
        return " | ".join(f"{n}={lp.short()}" for n, lp in zip(self.names, self.layers))

    def table(self) -> str:
        rows = ["layer            weight   data", "-" * 34]
        for n, lp in zip(self.names, self.layers):
            w = lp.weight.short() if lp.weight else "fp32"
            d = lp.data.short() if lp.data else "fp32"
            rows.append(f"{n:<16} {w:<8} {d}")
        return "\n".join(rows)
