"""Parameterized fixed-point Q(I,F) representation (paper §2.1).

The paper models reduced-precision *memory* with an N-bit fixed-point format
split into I integer bits (including sign) and F fractional bits.  Values are
quantized when they cross a memory boundary and converted back to float before
compute ("fake quant").  This module is the numerical core: everything is pure
jnp, jit/vmap/scan friendly, and format parameters may be Python ints *or*
traced arrays (so per-layer formats ride through ``lax.scan`` as stacked
(L,)-arrays of scales/bounds).

Conventions
-----------
* ``int_bits``  I >= 1, includes the sign bit.
* ``frac_bits`` F >= 0.
* integer grid: q in [-(2^(I+F-1)), 2^(I+F-1) - 1], value = q * 2^-F.
* representable range approx [-2^(I-1), 2^(I-1) - 2^-F].
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

RoundingMode = Literal["nearest", "stochastic", "floor"]

MAX_TOTAL_BITS = 30  # int32-safe integer grid


@dataclasses.dataclass(frozen=True)
class FixedPointFormat:
    """A Q(I,F) fixed-point format. ``I`` includes the sign bit."""

    int_bits: int
    frac_bits: int

    def __post_init__(self):
        if self.int_bits < 1:
            raise ValueError(f"int_bits must be >= 1 (sign), got {self.int_bits}")
        if self.frac_bits < 0:
            raise ValueError(f"frac_bits must be >= 0, got {self.frac_bits}")
        if self.total_bits > MAX_TOTAL_BITS:
            raise ValueError(f"total bits {self.total_bits} > {MAX_TOTAL_BITS}")

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        return float(2 ** self.frac_bits)

    @property
    def qmin(self) -> int:
        return -(2 ** (self.total_bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.total_bits - 1) - 1

    @property
    def max_value(self) -> float:
        return self.qmax / self.scale

    @property
    def min_value(self) -> float:
        return self.qmin / self.scale

    @property
    def resolution(self) -> float:
        return 1.0 / self.scale

    def container_dtype(self) -> jnp.dtype:
        """Smallest signed-int container that holds the integer grid."""
        if self.total_bits <= 8:
            return jnp.dtype(jnp.int8)
        if self.total_bits <= 16:
            return jnp.dtype(jnp.int16)
        return jnp.dtype(jnp.int32)

    def short(self) -> str:
        return f"Q{self.int_bits}.{self.frac_bits}"

    @staticmethod
    def parse(s: str) -> "FixedPointFormat":
        s = s.strip().lstrip("Qq")
        i, f = s.split(".")
        return FixedPointFormat(int(i), int(f))


def format_params(int_bits, frac_bits):
    """(scale, qmin, qmax) as float arrays; accepts ints or traced arrays.

    This is what lets per-layer formats flow through ``lax.scan``: stack
    per-layer (I, F) into (L,) arrays and compute elementwise.
    """
    int_bits = jnp.asarray(int_bits, jnp.float32)
    frac_bits = jnp.asarray(frac_bits, jnp.float32)
    one = jnp.float32(1.0)
    # ldexp gives exact powers of two; XLA's exp2 lowers to exp(x*ln2) and is
    # off by ~5e-4 at 2^13, which breaks grid idempotency.
    scale = jnp.ldexp(one, frac_bits.astype(jnp.int32))
    half = jnp.ldexp(one, (int_bits + frac_bits - 1.0).astype(jnp.int32))
    qmin = -half
    qmax = half - 1.0
    return scale, qmin, qmax


def _round(x, mode: RoundingMode, key):
    if mode == "nearest":
        # round-half-away-from-zero, the usual hardware convert behaviour
        return jnp.trunc(x + jnp.copysign(0.5, x))
    if mode == "floor":
        return jnp.floor(x)
    if mode == "stochastic":
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        noise = jax.random.uniform(key, jnp.shape(x), jnp.float32)
        return jnp.floor(x + noise)
    raise ValueError(f"unknown rounding mode {mode!r}")


def quantize(x, int_bits, frac_bits, *, rounding: RoundingMode = "nearest",
             key=None):
    """float -> integer grid (float-typed; cast to a container separately)."""
    x = jnp.asarray(x, jnp.float32)
    scale, qmin, qmax = format_params(int_bits, frac_bits)
    q = _round(x * scale, rounding, key)
    return jnp.clip(q, qmin, qmax)


def dequantize(q, int_bits, frac_bits):
    scale, _, _ = format_params(int_bits, frac_bits)
    return jnp.asarray(q, jnp.float32) / scale


def fake_quant(x, int_bits, frac_bits, *, rounding: RoundingMode = "nearest",
               key=None):
    """Quantize-then-dequantize: the paper's memory-boundary conversion.

    Output dtype follows the input dtype (bf16 stays bf16) but the value set
    is the Q(I,F) grid.
    """
    orig_dtype = jnp.result_type(x)
    q = quantize(x, int_bits, frac_bits, rounding=rounding, key=key)
    y = dequantize(q, int_bits, frac_bits)
    return y.astype(orig_dtype)


# ---------------------------------------------------------------------------
# Straight-through estimator variant: lets the same boundary op sit inside a
# training graph (quantization-aware training; beyond-paper but standard).
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=())
def fake_quant_ste(x, int_bits, frac_bits):
    return fake_quant(x, int_bits, frac_bits)


def _fq_fwd(x, int_bits, frac_bits):
    y = fake_quant(x, int_bits, frac_bits)
    # pass-through gradient only inside the representable range
    _, qmin, qmax = format_params(int_bits, frac_bits)
    scale, _, _ = format_params(int_bits, frac_bits)
    in_range = (x * scale >= qmin) & (x * scale <= qmax)
    return y, (in_range,)


def _fq_bwd(res, g):
    (in_range,) = res
    gx = jnp.where(in_range, g, 0.0).astype(g.dtype)
    return (gx, None, None)


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


def quantization_error(x, int_bits, frac_bits):
    """RMS error introduced by the format on a tensor (diagnostics)."""
    y = fake_quant(x, int_bits, frac_bits)
    d = (jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32))
    return jnp.sqrt(jnp.mean(d * d))


def required_int_bits(max_abs) -> jnp.ndarray:
    """Smallest I (incl. sign) whose range covers ``max_abs`` (calibration)."""
    max_abs = jnp.asarray(max_abs, jnp.float32)
    # need 2^(I-1) >= max_abs  =>  I >= log2(max_abs) + 1
    i = jnp.ceil(jnp.log2(jnp.maximum(max_abs, 1e-30))) + 1.0
    return jnp.maximum(i, 1.0).astype(jnp.int32)
