"""Tiered KV page store: host-memory offload + persistent prefix pages.

The device page pool (:mod:`repro.core.paged_kv`) enforces the paper's
*bounded memory* brutally: when it fills, requests defer and LRU-evicted
cached prefixes are destroyed. This module adds the second, cheaper tier the
bound can spill into and refill from:

* :func:`extract_page` / :func:`inject_page` move ONE logical page between
  the device pools and host memory. Bytes stay in their **packed storage
  containers** (int8 grids, int4 lane-packed int32 words, fp pages) plus the
  per-page dequant scales — so offload traffic scales with the searched
  precision policy (a 4-bit layer demotes at ~1/8 the fp32 cost), which is
  the paper's per-layer payoff made operational, and a demote→promote round
  trip is **byte-identical** (the preemption-resume bitwise contract).
* :class:`HostPageStore` is the bounded host tier: a handle-keyed dict of
  :class:`PageBlob` snapshots with page/byte accounting per container.
* :class:`TieredPager` binds an allocator + host store + the server's cache
  pytree into demote/promote primitives, and registers itself as an
  allocator ``pressure`` callback consumer (the prefix cache drives it).
* :func:`save_prefix_snapshot` / :func:`load_prefix_snapshot` persist host
  pages (token chains + blobs) across server restarts. The format is
  **profile-key-namespaced like the trie**: every chain carries the KV
  quantization profile key it was written under, so an int8 snapshot can
  never back an int4 server, and a geometry signature guards against arch
  mismatches.
* :func:`requantize_page` / :class:`QuantTierStore` add the ONLINE
  precision-adaptation tier (ROADMAP item 4): under pool pressure a cold
  page is repacked one container step narrower (fp -> int8 -> int4) with
  freshly calibrated per-page scales and parked on device — cheaper than
  the host round trip, bounded in bytes, lossy only by the narrower grid's
  rounding error (which the adapt bench's accuracy gate measures).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..runtime.telemetry import Ewma, MetricsRegistry, metric_attr
from .paged_kv import (_SCALE_EPS, iter_kv_pools, map_kv_pools,
                       pool_container)
from .qtensor import pack_bits, unpack_bits, values_per_word

__all__ = ["PageBlob", "PendingPageBlob", "HostPageStore", "TieredPager",
           "QuantTierStore", "extract_page", "extract_page_async",
           "inject_page", "requantize_page",
           "requantize_blob", "widen_blob", "narrower_container",
           "cache_geometry", "save_prefix_snapshot",
           "load_prefix_snapshot"]

_FIELDS = ("k", "v", "ks", "vs")


@dataclasses.dataclass
class PageBlob:
    """Host-side copy of ONE logical page across every attention pool.

    ``arrays[i]`` holds the page's k/v bytes and k/v scales for the i-th
    pool in :func:`repro.core.paged_kv.iter_kv_pools` traversal order —
    stacked pools contribute a leading layer dim, unstacked pools a single
    page. Arrays keep the pool's storage dtype (packed containers).
    """

    arrays: List[Dict[str, np.ndarray]]

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for rec in self.arrays
                   for a in rec.values())

    def bytes_by_container(self) -> Dict[str, int]:
        """Page (k+v) bytes per storage container; the per-page dequant
        scales are excluded here (they are counted in ``nbytes``)."""
        out: Dict[str, int] = {}
        for rec in self.arrays:
            dt = rec["k"].dtype
            if np.issubdtype(dt, np.floating):
                cont = "fp"
            else:
                cont = "int8" if dt == np.dtype(np.int8) else "int4"
            out[cont] = out.get(cont, 0) + int(rec["k"].nbytes
                                               + rec["v"].nbytes)
        return out


def extract_page(caches, page: int) -> PageBlob:
    """Copy logical ``page``'s stored bytes + scales out of every pool.

    Non-destructive (the device page keeps its content); the copy is forced
    to host numpy, so the blob stays valid after the page is freed and
    recycled.
    """
    arrays = []
    for pool, axis in iter_kv_pools(caches):
        idx = (slice(None), page) if axis == 1 else (page,)
        arrays.append({
            "k": np.asarray(pool["k_pages"][idx]),
            "v": np.asarray(pool["v_pages"][idx]),
            "ks": np.asarray(pool["k_scale"][idx]),
            "vs": np.asarray(pool["v_scale"][idx]),
        })
    return PageBlob(arrays)


class PendingPageBlob:
    """An in-flight device→host copy of one logical page.

    Holds the page's sliced device arrays with ``copy_to_host_async()``
    already issued, and materializes to a :class:`PageBlob` on first
    access (``resolve()``; idempotent). The slices are functional jax
    values computed against the pool buffers at extraction time, so the
    device page can be freed and rewritten immediately — the pending copy
    stays valid. ``nbytes``/``bytes_by_container`` are computable from
    dtypes+shapes without waiting, so host-tier accounting stays exact
    while the DMA runs behind decode.
    """

    __slots__ = ("_dev", "_blob")

    def __init__(self, device_arrays):
        self._dev = device_arrays
        self._blob: Optional[PageBlob] = None

    @property
    def resolved(self) -> bool:
        return self._blob is not None

    def resolve(self) -> PageBlob:
        if self._blob is None:
            self._blob = PageBlob([{f: np.asarray(rec[f]) for f in _FIELDS}
                                   for rec in self._dev])
            self._dev = None
        return self._blob

    @property
    def arrays(self):
        return self.resolve().arrays

    @property
    def nbytes(self) -> int:
        if self._blob is not None:
            return self._blob.nbytes
        return sum(int(a.nbytes) for rec in self._dev
                   for a in rec.values())

    def bytes_by_container(self) -> Dict[str, int]:
        if self._blob is not None:
            return self._blob.bytes_by_container()
        out: Dict[str, int] = {}
        for rec in self._dev:
            dt = np.dtype(rec["k"].dtype)
            if np.issubdtype(dt, np.floating):
                cont = "fp"
            else:
                cont = "int8" if dt == np.dtype(np.int8) else "int4"
            out[cont] = out.get(cont, 0) + int(rec["k"].nbytes
                                               + rec["v"].nbytes)
        return out


def extract_page_async(caches, page: int) -> PendingPageBlob:
    """Start copying logical ``page`` to the host without blocking.

    Slices every pool at ``page`` (functional jax values — subsequent
    pool writes cannot mutate them) and enqueues the device→host
    transfers; the returned :class:`PendingPageBlob` blocks only when
    someone actually reads it. Byte-identical to :func:`extract_page`
    once resolved.
    """
    dev = []
    for pool, axis in iter_kv_pools(caches):
        idx = (slice(None), page) if axis == 1 else (page,)
        rec = {"k": pool["k_pages"][idx], "v": pool["v_pages"][idx],
               "ks": pool["k_scale"][idx], "vs": pool["v_scale"][idx]}
        for a in rec.values():
            copy_async = getattr(a, "copy_to_host_async", None)
            if copy_async is not None:
                copy_async()
        dev.append(rec)
    return PendingPageBlob(dev)


def inject_page(caches, blob: PageBlob, page: int):
    """Write ``blob`` into logical ``page`` of every pool; returns the new
    cache structure (functional update — callers reassign their caches)."""
    it = iter(blob.arrays)

    def put(pool, axis):
        rec = next(it)
        idx = (slice(None), page) if axis == 1 else (page,)
        return {
            "k_pages": pool["k_pages"].at[idx].set(
                jnp.asarray(rec["k"], pool["k_pages"].dtype)),
            "v_pages": pool["v_pages"].at[idx].set(
                jnp.asarray(rec["v"], pool["v_pages"].dtype)),
            "k_scale": pool["k_scale"].at[idx].set(
                jnp.asarray(rec["ks"], pool["k_scale"].dtype)),
            "v_scale": pool["v_scale"].at[idx].set(
                jnp.asarray(rec["vs"], pool["v_scale"].dtype)),
        }

    new_caches = map_kv_pools(caches, put)
    try:
        next(it)
    except StopIteration:
        return new_caches
    raise ValueError("blob has more pool records than the cache structure")


def cache_geometry(caches) -> str:
    """Canonical signature of the paged-pool structure (shapes minus the
    page axis, dtypes, containers). Snapshot restore validates it so a blob
    is only ever injected into an identically shaped pool."""
    sig = []
    for pool, axis in iter_kv_pools(caches):
        shape = list(pool["k_pages"].shape)
        del shape[axis]            # page count may differ between servers
        sig.append([pool_container(pool), shape,
                    str(pool["k_pages"].dtype), int(axis)])
    return json.dumps(sig)


# ---------------------------------------------------------------------------
# Host tier
# ---------------------------------------------------------------------------
class HostPageStore:
    """Bounded host-memory (numpy) page tier.

    Pure storage + accounting: handles are opaque ints, policy (what to
    demote, what to drop when full) lives in the callers — the prefix cache
    manages its demoted nodes, the server its preempted requests. ``put``
    on a full store raises; callers check :meth:`has_room` first.
    """

    def __init__(self, max_pages: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if max_pages is not None and max_pages < 1:
            raise ValueError("max_pages must be >= 1 (or None = unbounded)")
        self.max_pages = max_pages
        self._blobs: Dict[int, PageBlob] = {}
        self._next = 0
        self.nbytes = 0
        # lifetime counters (benchmarks read these)
        self.puts = 0
        self.pops = 0
        self.drops = 0
        self.peak_pages = 0
        self.peak_bytes = 0
        if metrics is not None:
            metrics.register_gauge("host.bytes", lambda: self.nbytes)
            metrics.register_gauge("host.pages", lambda: self.num_pages)

    @property
    def num_pages(self) -> int:
        return len(self._blobs)

    def has_room(self, n: int = 1) -> bool:
        return (self.max_pages is None
                or self.num_pages + n <= self.max_pages)

    def put(self, blob: PageBlob) -> int:
        if not self.has_room(1):
            raise RuntimeError(
                f"host page tier full ({self.num_pages}/{self.max_pages} "
                f"pages); raise --host-pages or drop cold prefixes first")
        h = self._next
        self._next += 1
        self._blobs[h] = blob
        self.nbytes += blob.nbytes
        self.puts += 1
        self.peak_pages = max(self.peak_pages, self.num_pages)
        self.peak_bytes = max(self.peak_bytes, self.nbytes)
        return h

    def get(self, handle: int) -> PageBlob:
        blob = self._blobs[handle]
        if isinstance(blob, PendingPageBlob):
            # reading is the synchronization point for async demotes:
            # materialize in place (nbytes is unchanged by resolution)
            blob = self._blobs[handle] = blob.resolve()
        return blob

    def pop(self, handle: int) -> PageBlob:
        blob = self._blobs.pop(handle)
        self.nbytes -= blob.nbytes
        self.pops += 1
        if isinstance(blob, PendingPageBlob):
            blob = blob.resolve()
        return blob

    def drop(self, handle: int) -> None:
        blob = self._blobs.pop(handle)
        self.nbytes -= blob.nbytes
        self.drops += 1

    def bytes_by_container(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for blob in self._blobs.values():
            for cont, b in blob.bytes_by_container().items():
                out[cont] = out.get(cont, 0) + b
        return out


# ---------------------------------------------------------------------------
# Pager: moves pages between the tiers
# ---------------------------------------------------------------------------
class TieredPager:
    """Demote/promote primitives over (allocator, host store, cache pytree).

    The cache pytree is owned by the server and rebuilt functionally on
    every write, so the pager holds ``get_caches``/``set_caches`` closures
    instead of a reference. ``promote`` may recursively trigger allocator
    pressure (reclaim -> prefix-cache demotion), which is safe: eviction
    never touches pinned or non-resident nodes.

    ``async_mode=True`` turns demotes (and preemption offloads) into
    **double-buffered async transfers**: :func:`extract_page_async` slices
    the page and enqueues the D2H copy, the device page is freed
    immediately (the slices are functional values), and up to
    ``max_inflight`` transfers ride behind decode until :meth:`drain` —
    called by the serve loop at decode-span boundaries — materializes
    them. Reading a pending handle through the host store resolves it
    early, so correctness never depends on drain timing; a demote→promote
    round trip stays byte-identical either way. Completed transfers are
    recorded as retrospective ``pager.demote``/``pager.offload`` spans on
    the dedicated pager trace track — overlapping the engine's decode
    spans is exactly what the Chrome trace is meant to show.
    """

    # registry-backed legacy counters (see runtime.telemetry.metric_attr)
    demotions = metric_attr("pager.demotions")
    promotions = metric_attr("pager.promotions")
    prefetches = metric_attr("pager.prefetches")
    prefetch_hits = metric_attr("pager.prefetch_hits")

    def __init__(self, allocator, host: HostPageStore, get_caches,
                 set_caches, metrics: Optional[MetricsRegistry] = None,
                 *, async_mode: bool = False, max_inflight: int = 2,
                 max_staged: int = 8, tracer=None):
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1 transfer")
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.allocator = allocator
        self.host = host
        self._get = get_caches
        self._set = set_caches
        self.async_mode = bool(async_mode)
        self.max_inflight = max_inflight
        self.max_staged = max_staged
        self.tracer = tracer
        self._inflight = collections.deque()   # (pending, t0, kind)
        # promote-direction prefetch stage: host handle -> (device-resident
        # pool records with the H2D copies already dispatched, issue time)
        self._staged: Dict[int, tuple] = {}
        self.demotions = 0
        self.promotions = 0
        self.prefetches = 0
        self.prefetch_hits = 0
        # demote/promote wall latencies (exact p50/p99 via the registry)
        self._h_demote = self.metrics.histogram("pager.demote_s")
        self._h_promote = self.metrics.histogram("pager.promote_s")
        self._h_offload = self.metrics.histogram("pager.offload_s")
        self._ewma_demote = Ewma()
        self._ewma_promote = Ewma()
        self.metrics.register_gauge("pager.demote_ewma_s",
                                    self._ewma_demote.get)
        self.metrics.register_gauge("pager.promote_ewma_s",
                                    self._ewma_promote.get)
        self.metrics.register_gauge("pager.inflight",
                                    lambda: len(self._inflight))

    def host_room(self) -> float:
        """Host pages still available (inf when unbounded)."""
        if self.host.max_pages is None:
            return float("inf")
        return max(0, self.host.max_pages - self.host.num_pages)

    def extract(self, page: int) -> PageBlob:
        return extract_page(self._get(), page)

    def demote(self, page: int) -> int:
        """Copy ``page`` to the host tier, release the caller's device
        reference, return the host handle. The caller must hold the ONLY
        reference (refcount 1) or the page content could keep changing
        under other owners after the snapshot. In async mode the handle
        maps to a pending transfer that resolves at drain (or on first
        read)."""
        t0 = time.perf_counter()
        if self.async_mode:
            pending = extract_page_async(self._get(), page)
            h = self.host.put(pending)
            self.allocator.free([page])
            self.demotions += 1
            self._enqueue(pending, t0, "demote")
            return h
        blob = extract_page(self._get(), page)
        h = self.host.put(blob)
        self.allocator.free([page])
        self.demotions += 1
        dt = time.perf_counter() - t0
        self._h_demote.observe(dt)
        self._ewma_demote.update(dt)
        if self.tracer is not None:
            self.tracer.pager_span("pager.demote", t0, t0 + dt)
        return h

    def offload(self, page: int) -> int:
        """Host-park a page the CALLER still owns and frees (the
        preemption-victim path): the transfer rides the same async
        double-buffer as :meth:`demote`, but allocator bookkeeping and
        demotion counters stay with the caller."""
        if not self.async_mode:
            return self.host.put(extract_page(self._get(), page))
        t0 = time.perf_counter()
        pending = extract_page_async(self._get(), page)
        h = self.host.put(pending)
        self._enqueue(pending, t0, "offload")
        return h

    # -- promote-direction prefetch -----------------------------------------
    def stage_room(self) -> int:
        """Prefetch slots still free. Also prunes staged copies whose host
        handle disappeared (the prefix cache dropped the node before its
        predicted promote) so dead entries can't pin the stage full."""
        stale = [h for h in self._staged if h not in self.host._blobs]
        for h in stale:
            del self._staged[h]
        return self.max_staged - len(self._staged)

    def prefetch(self, handle: int) -> int:
        """Start the host->device copy for a parked page AHEAD of its
        promote (the serve loop calls this for pages the admission plan
        predicts will be promoted next cycle). ``jnp.asarray`` on the host
        blob dispatches the H2D transfers asynchronously — nothing blocks
        here — and :meth:`promote` consumes the staged device arrays
        instead of re-uploading. Pure staging: no allocation, no host
        accounting changes, so the prefetch can never affect tokens.
        Returns 1 when a copy was staged, 0 when skipped (sync mode,
        already staged, stage full, unknown handle, or the handle's own
        D2H demote is still in flight)."""
        if not self.async_mode or handle in self._staged \
                or self.stage_room() <= 0:
            return 0
        blob = self.host._blobs.get(handle)
        if blob is None:
            return 0
        if isinstance(blob, PendingPageBlob):
            if not blob.resolved:
                return 0   # its D2H is still riding behind a decode span
            blob = blob.resolve()
        dev = [{f: jnp.asarray(rec[f]) for f in _FIELDS}
               for rec in blob.arrays]
        self._staged[handle] = (dev, time.perf_counter())
        self.prefetches += 1
        return 1

    def promote(self, handle: int) -> int:
        """Allocate a device page (may trigger reclaim pressure), inject the
        host blob into it, release the host copy; returns the page id (at
        refcount 1, owned by the caller). The injection's H2D writes are
        dispatch-async under jax — the span records enqueue time, not a
        device sync. A prefetched handle injects its staged device arrays
        (byte-identical — they were uploaded from the same blob) and
        records a retrospective span from copy issue to consumption, which
        overlaps the decode span the transfer rode behind."""
        staged = self._staged.pop(handle, None)
        t0 = time.perf_counter()
        page = self.allocator.alloc()
        blob = self.host.pop(handle)
        if staged is not None:
            dev, t_issue = staged
            blob = PageBlob(dev)
            self.prefetch_hits += 1
        self._set(inject_page(self._get(), blob, page))
        self.promotions += 1
        t1 = time.perf_counter()
        dt = t1 - t0
        self._h_promote.observe(dt)
        self._ewma_promote.update(dt)
        if self.tracer is not None:
            if staged is not None:
                self.tracer.pager_span("pager.promote", t_issue, t1,
                                       args={"async": True,
                                             "prefetch": True})
            else:
                self.tracer.pager_span("pager.promote", t0, t0 + dt)
        return page

    # -- async double-buffer ------------------------------------------------
    def _enqueue(self, pending: PendingPageBlob, t0: float,
                 kind: str) -> None:
        self._inflight.append((pending, t0, kind))
        while len(self._inflight) > self.max_inflight:
            self._drain_one()

    def _drain_one(self) -> None:
        pending, t0, kind = self._inflight.popleft()
        pending.resolve()
        t1 = time.perf_counter()
        if kind == "demote":
            self._h_demote.observe(t1 - t0)
            self._ewma_demote.update(t1 - t0)
        else:
            self._h_offload.observe(t1 - t0)
        if self.tracer is not None:
            self.tracer.pager_span(f"pager.{kind}", t0, t1,
                                   args={"async": True})

    def drain(self) -> int:
        """Materialize every in-flight transfer; the serve loop calls this
        at decode-span boundaries so transfer time hides behind decode.
        Returns the number drained (0 in sync mode / when idle)."""
        n = len(self._inflight)
        while self._inflight:
            self._drain_one()
        return n


# ---------------------------------------------------------------------------
# Online requantization (ROADMAP item 4): fp -> int8 -> int4 in place
# ---------------------------------------------------------------------------
_QMAX = {"int8": 127.0, "int4": 7.0}
_NARROWER = {"fp": "int8", "int8": "int4", "int4": "int4"}


def _rec_container(rec) -> str:
    """Storage container of one blob record, inferred from the k dtype
    (float -> fp, int8 -> int8, packed int32 words -> int4)."""
    dt = np.dtype(rec["k"].dtype)
    if np.issubdtype(dt, np.floating):
        return "fp"
    return "int8" if dt == np.dtype(np.int8) else "int4"


def _rec_head_dim(rec) -> int:
    """Logical head dim of a record (int4 stores head_dim/8 packed words)."""
    hd = int(rec["k"].shape[-1])
    return hd * values_per_word(4) if _rec_container(rec) == "int4" else hd


def narrower_container(container: str, *, head_dim: int,
                       floor_bits: int = 4) -> str:
    """One step down the adaptation ladder fp -> int8 -> int4.

    Returns ``container`` unchanged at the floor. ``floor_bits=8`` stops
    the descent at int8; a head dim that int4 lane-packing cannot express
    (head_dim % 8 != 0) floors that pool at int8 regardless.
    """
    nxt = _NARROWER[container]
    if nxt == "int4" and (floor_bits > 4
                          or head_dim % values_per_word(4) != 0):
        return "int8" if container == "fp" else container
    return nxt


def _bcast_scale(s: np.ndarray) -> np.ndarray:
    """Per-(layer-)page scale broadcast over (page_size, KV, head_dim)."""
    s = np.asarray(s, np.float32)
    return s.reshape(s.shape + (1, 1, 1))


def _dequant_plane(q: np.ndarray, scale: np.ndarray, container: str,
                   head_dim: int) -> np.ndarray:
    if container == "int4":
        q = np.asarray(unpack_bits(jnp.asarray(q), 4, head_dim))
    return q.astype(np.float32) * _bcast_scale(scale)


def _quant_plane(vals: np.ndarray, container: str):
    """Freshly calibrated per-(layer-)page max-abs quantization."""
    qmax = _QMAX[container]
    amax = np.max(np.abs(vals), axis=(-3, -2, -1))
    scale = np.maximum(amax / qmax, _SCALE_EPS).astype(np.float32)
    grid = np.clip(np.round(vals / _bcast_scale(scale)), -qmax, qmax)
    if container == "int4":
        packed, _ = pack_bits(jnp.asarray(grid, jnp.int32), 4)
        return np.asarray(packed), scale
    return grid.astype(np.int8), scale


def requantize_blob(blob: PageBlob, *, steps: Optional[int] = 1,
                    floor_bits: int = 4,
                    valid_len: Optional[int] = None
                    ) -> Tuple[PageBlob, int]:
    """Repack every pool record of one page toward a narrower container.

    Each record steps down the fp -> int8 -> int4 ladder ``steps`` times
    (``None`` = all the way to its floor) with a freshly calibrated
    per-(layer-)page max-abs scale. The per-page scale machinery in
    ``paged_gather`` dequantizes the result no matter which container the
    destination pool was built for — an fp pool legally holds int8-grid
    values under a non-unit scale — so narrowed pages stay readable by the
    unmodified attention path. ``valid_len`` zeroes token slots past a
    partial page's written count before calibration, so stale garbage
    cannot inflate the scale. Returns ``(new_blob, records_narrowed)``;
    records already at their floor pass through untouched.
    """
    out: List[Dict[str, np.ndarray]] = []
    narrowed = 0
    for rec in blob.arrays:
        cur = _rec_container(rec)
        hd = _rec_head_dim(rec)
        tgt = cur
        for _ in range(64 if steps is None else steps):
            nxt = narrower_container(tgt, head_dim=hd,
                                     floor_bits=floor_bits)
            if nxt == tgt:
                break
            tgt = nxt
        if tgt == cur:
            out.append(dict(rec))
            continue
        k = _dequant_plane(rec["k"], rec["ks"], cur, hd)
        v = _dequant_plane(rec["v"], rec["vs"], cur, hd)
        if valid_len is not None and valid_len < k.shape[-3]:
            k[..., valid_len:, :, :] = 0.0
            v[..., valid_len:, :, :] = 0.0
        kq, ks = _quant_plane(k, tgt)
        vq, vs = _quant_plane(v, tgt)
        out.append({"k": kq, "v": vq, "ks": ks, "vs": vs})
        narrowed += 1
    return PageBlob(out), narrowed


def requantize_page(caches, page: int, *, steps: Optional[int] = 1,
                    floor_bits: int = 4,
                    valid_len: Optional[int] = None
                    ) -> Tuple[PageBlob, int]:
    """Extract + requantize one logical page (see :func:`requantize_blob`).

    The narrowed blob does NOT go back into its source page — the point is
    that the source pool's container is wider. Callers park it in a
    :class:`QuantTierStore` (freeing the device page before any host
    demotion) or widen + inject it into a matching pool later.
    """
    return requantize_blob(extract_page(caches, page), steps=steps,
                           floor_bits=floor_bits, valid_len=valid_len)


def widen_blob(blob: PageBlob, caches) -> PageBlob:
    """Convert a (possibly narrowed) blob into each pool's NATIVE container
    so :func:`inject_page` can write it back.

    Grid widening is exact AND recalibrates the restored page's scale to
    the target container's granularity (the live-traffic recalibration
    hook): an int4 grid widens into an int8 pool as ``grid * 16,
    scale / 16`` — bit-identical dequant (|grid| <= 7 so the widened grid
    fits int8, and a power-of-two rescale is exact in float32) while the
    page is left with an int8-granularity scale, so later page-scale CoW
    extensions quantize fresh tokens at int8 precision instead of being
    pinned to the parked int4 step. Into an fp pool the grid is stored as
    floats with its scale CARRIED rather than folded to a unit scale:
    dequant still happens in float32 at gather time, so a low-precision fp
    pool (bf16/fp16) never rounds the grid*scale product at rest. Recycled
    fp pages stay safe because the fp write path resets a page's scale on
    its first write (``paged_kv.paged_update``) and CoW copies fold scales
    before extension (``paged_kv.copy_pool_pages``). The rounding loss of
    the original narrowing step is NOT undone; that is the accuracy cost
    the adapt gate measures.
    """
    pools = list(iter_kv_pools(caches))
    if len(pools) != len(blob.arrays):
        raise ValueError("blob/pool record count mismatch")
    out: List[Dict[str, np.ndarray]] = []
    for rec, (pool, _) in zip(blob.arrays, pools):
        cur = _rec_container(rec)
        tgt = pool_container(pool)
        hd = _rec_head_dim(rec)
        if cur == tgt:
            out.append(dict(rec))
        elif tgt == "fp":
            dt = np.dtype(pool["k_pages"].dtype)
            k = rec["k"]
            v = rec["v"]
            if cur == "int4":
                k = np.asarray(unpack_bits(jnp.asarray(k), 4, hd))
                v = np.asarray(unpack_bits(jnp.asarray(v), 4, hd))
            out.append({
                "k": k.astype(dt), "v": v.astype(dt),
                "ks": np.asarray(rec["ks"], np.float32),
                "vs": np.asarray(rec["vs"], np.float32)})
        elif tgt == "int8" and cur == "int4":
            up = 1 << 4   # int8/int4 grid-step ratio (exact rescale)
            out.append({
                "k": (np.asarray(unpack_bits(jnp.asarray(rec["k"]), 4, hd))
                      .astype(np.int32) * up).astype(np.int8),
                "v": (np.asarray(unpack_bits(jnp.asarray(rec["v"]), 4, hd))
                      .astype(np.int32) * up).astype(np.int8),
                "ks": np.asarray(rec["ks"], np.float32) / up,
                "vs": np.asarray(rec["vs"], np.float32) / up})
        else:
            raise ValueError(
                f"cannot widen a {cur!r} record into a {tgt!r} pool")
    return PageBlob(out)


class QuantTierStore:
    """Bounded DEVICE-resident requantization tier (ROADMAP item 4).

    Under pool pressure the prefix cache requantizes a cold page one
    container step narrower (freshly calibrated scales) and parks the
    narrowed blob here — still on device, so the page never pays the host
    round trip — then frees the original page. A parked page re-enters the
    pool through :meth:`restore` (widen + inject into a fresh page,
    carrying the narrower grid's rounding loss), or narrows further under
    continued byte pressure (:meth:`deepen`, the fp -> int8 -> int4
    progression). Capacity is bounded in BYTES — ``pages`` fully-floored
    page equivalents — so the relief valve itself honors the paper's
    bounded-memory contract.
    """

    def __init__(self, get_caches, set_caches, *, pages: int,
                 floor_bits: int = 4,
                 metrics: Optional[MetricsRegistry] = None):
        if pages < 1:
            raise ValueError("quant tier needs >= 1 page of capacity")
        if metrics is not None:
            metrics.register_gauge("tier.bytes", lambda: self.nbytes)
            metrics.register_gauge("tier.pages", lambda: self.num_pages)
        if floor_bits not in (4, 8):
            raise ValueError("floor_bits must be 4 or 8")
        self._get = get_caches
        self._set = set_caches
        self.floor_bits = floor_bits
        # probe real geometry off the scratch page: bytes of one page
        # narrowed a single step (admission size) and all the way down
        # (the capacity unit)
        step_blob, can_narrow = requantize_page(get_caches(), 0, steps=1,
                                                floor_bits=floor_bits)
        floor_blob, _ = requantize_page(get_caches(), 0, steps=None,
                                        floor_bits=floor_bits)
        if not can_narrow:
            raise ValueError(
                "quant tier has nothing to narrow: every pool is already "
                "at its floor container")
        self.page_bytes_step = step_blob.nbytes
        self.page_bytes_floor = floor_blob.nbytes
        self.max_bytes = pages * self.page_bytes_floor
        self._recs: Dict[int, List[Dict[str, jnp.ndarray]]] = {}
        self._nb: Dict[int, int] = {}
        self._next = 0
        self.nbytes = 0
        self.puts = 0
        self.pops = 0
        self.drops = 0
        self.deepens = 0
        self.peak_pages = 0
        self.peak_bytes = 0

    @property
    def num_pages(self) -> int:
        return len(self._recs)

    def room_pages(self) -> int:
        """How many more one-step-narrowed pages fit before any deepening —
        the conservative figure admission preflight reports."""
        return max(0, (self.max_bytes - self.nbytes)
                   // max(self.page_bytes_step, 1))

    def has_room(self, blob: PageBlob) -> bool:
        return self.nbytes + blob.nbytes <= self.max_bytes

    def requantize(self, page: int,
                   valid_len: Optional[int] = None) -> Optional[PageBlob]:
        """One-step-narrower blob of device ``page`` (None: every pool is
        already at its floor — nothing to gain, let the host tier take
        it)."""
        blob, n = requantize_page(self._get(), page, steps=1,
                                  floor_bits=self.floor_bits,
                                  valid_len=valid_len)
        return blob if n else None

    def put(self, blob: PageBlob) -> int:
        if not self.has_room(blob):
            raise RuntimeError("quant tier byte budget exhausted; deepen "
                               "parked pages or demote to host instead")
        h = self._next
        self._next += 1
        # device-resident: the narrowed bytes live in accelerator memory
        self._recs[h] = [{f: jnp.asarray(rec[f]) for f in _FIELDS}
                         for rec in blob.arrays]
        self._nb[h] = blob.nbytes
        self.nbytes += blob.nbytes
        self.puts += 1
        self.peak_pages = max(self.peak_pages, self.num_pages)
        self.peak_bytes = max(self.peak_bytes, self.nbytes)
        return h

    def _host_blob(self, handle: int) -> PageBlob:
        return PageBlob([{f: np.asarray(rec[f]) for f in _FIELDS}
                         for rec in self._recs[handle]])

    def deepen(self, handle: int,
               valid_len: Optional[int] = None) -> int:
        """Narrow a parked page one more step in place; returns the bytes
        freed (0 = already at the floor)."""
        before = self._nb[handle]
        blob, n = requantize_blob(self._host_blob(handle), steps=1,
                                  floor_bits=self.floor_bits,
                                  valid_len=valid_len)
        if n == 0 or blob.nbytes >= before:
            return 0
        self._recs[handle] = [{f: jnp.asarray(rec[f]) for f in _FIELDS}
                              for rec in blob.arrays]
        self._nb[handle] = blob.nbytes
        self.nbytes -= before - blob.nbytes
        self.deepens += 1
        return before - blob.nbytes

    def restore(self, handle: int, page: int) -> None:
        """Widen the parked blob to the pools' native containers, inject it
        into ``page`` (caller allocated it), release the slot."""
        blob = widen_blob(self._host_blob(handle), self._get())
        self._set(inject_page(self._get(), blob, page))
        self._release(handle)
        self.pops += 1

    def export(self, handle: int) -> PageBlob:
        """Pool-native copy of a parked page (the snapshot path); the slot
        stays parked."""
        return widen_blob(self._host_blob(handle), self._get())

    def drop(self, handle: int) -> None:
        self._release(handle)
        self.drops += 1

    def _release(self, handle: int) -> None:
        del self._recs[handle]
        self.nbytes -= self._nb.pop(handle)

    def bytes_by_container(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for h in self._recs:
            for cont, b in self._host_blob(h).bytes_by_container().items():
                out[cont] = out.get(cont, 0) + b
        return out


# ---------------------------------------------------------------------------
# Snapshot / restore (persistent prefix pages)
# ---------------------------------------------------------------------------
SNAPSHOT_VERSION = 1


def snapshot_path(path: str) -> str:
    """The on-disk filename for ``path``: ``np.savez`` appends ``.npz`` to
    bare names, so save/load/exists checks all normalize through here."""
    return path if path.endswith(".npz") else path + ".npz"


def save_prefix_snapshot(path: str, entries, *, page_size: int,
                         geometry: str) -> int:
    """Serialize prefix-cache chains to ``path`` (one ``np.savez`` archive).

    ``entries`` is an iterable of ``(profile_key, tokens, PageBlob)`` with
    parents emitted before children (the trie's DFS order); ``tokens`` is
    the FULL token path from the root through the node's own chunk, so
    restore can rebuild the chain shape without trie internals. Returns the
    number of pages written.
    """
    chains = []
    arrays = {}
    n = 0
    for pk, tokens, blob in entries:
        chains.append({"profile": pk, "tokens": [int(t) for t in tokens],
                       "pools": len(blob.arrays)})
        for j, rec in enumerate(blob.arrays):
            for f in _FIELDS:
                arrays[f"e{n}_p{j}_{f}"] = rec[f]
        n += 1
    header = {"version": SNAPSHOT_VERSION, "page_size": int(page_size),
              "geometry": geometry, "chains": chains}
    np.savez(snapshot_path(path), __header__=np.asarray(json.dumps(header)),
             **arrays)
    return n


def load_prefix_snapshot(path: str) -> Tuple[dict, List[tuple]]:
    """Read a snapshot back: ``(meta, [(profile_key, tokens, PageBlob)])``
    in the order saved (parents before children)."""
    with np.load(snapshot_path(path), allow_pickle=False) as z:
        header = json.loads(str(z["__header__"]))
        if header.get("version") != SNAPSHOT_VERSION:
            raise ValueError(f"unsupported snapshot version "
                             f"{header.get('version')!r}")
        entries = []
        for i, ch in enumerate(header["chains"]):
            arrays = [{f: z[f"e{i}_p{j}_{f}"] for f in _FIELDS}
                      for j in range(ch["pools"])]
            entries.append((ch["profile"], list(ch["tokens"]),
                            PageBlob(arrays)))
    meta = {"version": header["version"], "page_size": header["page_size"],
            "geometry": header["geometry"]}
    return meta, entries
