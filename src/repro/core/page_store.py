"""Tiered KV page store: host-memory offload + persistent prefix pages.

The device page pool (:mod:`repro.core.paged_kv`) enforces the paper's
*bounded memory* brutally: when it fills, requests defer and LRU-evicted
cached prefixes are destroyed. This module adds the second, cheaper tier the
bound can spill into and refill from:

* :func:`extract_page` / :func:`inject_page` move ONE logical page between
  the device pools and host memory. Bytes stay in their **packed storage
  containers** (int8 grids, int4 lane-packed int32 words, fp pages) plus the
  per-page dequant scales — so offload traffic scales with the searched
  precision policy (a 4-bit layer demotes at ~1/8 the fp32 cost), which is
  the paper's per-layer payoff made operational, and a demote→promote round
  trip is **byte-identical** (the preemption-resume bitwise contract).
* :class:`HostPageStore` is the bounded host tier: a handle-keyed dict of
  :class:`PageBlob` snapshots with page/byte accounting per container.
* :class:`TieredPager` binds an allocator + host store + the server's cache
  pytree into demote/promote primitives, and registers itself as an
  allocator ``pressure`` callback consumer (the prefix cache drives it).
* :func:`save_prefix_snapshot` / :func:`load_prefix_snapshot` persist host
  pages (token chains + blobs) across server restarts. The format is
  **profile-key-namespaced like the trie**: every chain carries the KV
  quantization profile key it was written under, so an int8 snapshot can
  never back an int4 server, and a geometry signature guards against arch
  mismatches.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .paged_kv import iter_kv_pools, map_kv_pools, pool_container

__all__ = ["PageBlob", "HostPageStore", "TieredPager", "extract_page",
           "inject_page", "cache_geometry", "save_prefix_snapshot",
           "load_prefix_snapshot"]

_FIELDS = ("k", "v", "ks", "vs")


@dataclasses.dataclass
class PageBlob:
    """Host-side copy of ONE logical page across every attention pool.

    ``arrays[i]`` holds the page's k/v bytes and k/v scales for the i-th
    pool in :func:`repro.core.paged_kv.iter_kv_pools` traversal order —
    stacked pools contribute a leading layer dim, unstacked pools a single
    page. Arrays keep the pool's storage dtype (packed containers).
    """

    arrays: List[Dict[str, np.ndarray]]

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for rec in self.arrays
                   for a in rec.values())

    def bytes_by_container(self) -> Dict[str, int]:
        """Page (k+v) bytes per storage container; the per-page dequant
        scales are excluded here (they are counted in ``nbytes``)."""
        out: Dict[str, int] = {}
        for rec in self.arrays:
            dt = rec["k"].dtype
            if np.issubdtype(dt, np.floating):
                cont = "fp"
            else:
                cont = "int8" if dt == np.dtype(np.int8) else "int4"
            out[cont] = out.get(cont, 0) + int(rec["k"].nbytes
                                               + rec["v"].nbytes)
        return out


def extract_page(caches, page: int) -> PageBlob:
    """Copy logical ``page``'s stored bytes + scales out of every pool.

    Non-destructive (the device page keeps its content); the copy is forced
    to host numpy, so the blob stays valid after the page is freed and
    recycled.
    """
    arrays = []
    for pool, axis in iter_kv_pools(caches):
        idx = (slice(None), page) if axis == 1 else (page,)
        arrays.append({
            "k": np.asarray(pool["k_pages"][idx]),
            "v": np.asarray(pool["v_pages"][idx]),
            "ks": np.asarray(pool["k_scale"][idx]),
            "vs": np.asarray(pool["v_scale"][idx]),
        })
    return PageBlob(arrays)


def inject_page(caches, blob: PageBlob, page: int):
    """Write ``blob`` into logical ``page`` of every pool; returns the new
    cache structure (functional update — callers reassign their caches)."""
    it = iter(blob.arrays)

    def put(pool, axis):
        rec = next(it)
        idx = (slice(None), page) if axis == 1 else (page,)
        return {
            "k_pages": pool["k_pages"].at[idx].set(
                jnp.asarray(rec["k"], pool["k_pages"].dtype)),
            "v_pages": pool["v_pages"].at[idx].set(
                jnp.asarray(rec["v"], pool["v_pages"].dtype)),
            "k_scale": pool["k_scale"].at[idx].set(
                jnp.asarray(rec["ks"], pool["k_scale"].dtype)),
            "v_scale": pool["v_scale"].at[idx].set(
                jnp.asarray(rec["vs"], pool["v_scale"].dtype)),
        }

    new_caches = map_kv_pools(caches, put)
    try:
        next(it)
    except StopIteration:
        return new_caches
    raise ValueError("blob has more pool records than the cache structure")


def cache_geometry(caches) -> str:
    """Canonical signature of the paged-pool structure (shapes minus the
    page axis, dtypes, containers). Snapshot restore validates it so a blob
    is only ever injected into an identically shaped pool."""
    sig = []
    for pool, axis in iter_kv_pools(caches):
        shape = list(pool["k_pages"].shape)
        del shape[axis]            # page count may differ between servers
        sig.append([pool_container(pool), shape,
                    str(pool["k_pages"].dtype), int(axis)])
    return json.dumps(sig)


# ---------------------------------------------------------------------------
# Host tier
# ---------------------------------------------------------------------------
class HostPageStore:
    """Bounded host-memory (numpy) page tier.

    Pure storage + accounting: handles are opaque ints, policy (what to
    demote, what to drop when full) lives in the callers — the prefix cache
    manages its demoted nodes, the server its preempted requests. ``put``
    on a full store raises; callers check :meth:`has_room` first.
    """

    def __init__(self, max_pages: Optional[int] = None):
        if max_pages is not None and max_pages < 1:
            raise ValueError("max_pages must be >= 1 (or None = unbounded)")
        self.max_pages = max_pages
        self._blobs: Dict[int, PageBlob] = {}
        self._next = 0
        self.nbytes = 0
        # lifetime counters (benchmarks read these)
        self.puts = 0
        self.pops = 0
        self.drops = 0
        self.peak_pages = 0
        self.peak_bytes = 0

    @property
    def num_pages(self) -> int:
        return len(self._blobs)

    def has_room(self, n: int = 1) -> bool:
        return (self.max_pages is None
                or self.num_pages + n <= self.max_pages)

    def put(self, blob: PageBlob) -> int:
        if not self.has_room(1):
            raise RuntimeError(
                f"host page tier full ({self.num_pages}/{self.max_pages} "
                f"pages); raise --host-pages or drop cold prefixes first")
        h = self._next
        self._next += 1
        self._blobs[h] = blob
        self.nbytes += blob.nbytes
        self.puts += 1
        self.peak_pages = max(self.peak_pages, self.num_pages)
        self.peak_bytes = max(self.peak_bytes, self.nbytes)
        return h

    def get(self, handle: int) -> PageBlob:
        return self._blobs[handle]

    def pop(self, handle: int) -> PageBlob:
        blob = self._blobs.pop(handle)
        self.nbytes -= blob.nbytes
        self.pops += 1
        return blob

    def drop(self, handle: int) -> None:
        blob = self._blobs.pop(handle)
        self.nbytes -= blob.nbytes
        self.drops += 1

    def bytes_by_container(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for blob in self._blobs.values():
            for cont, b in blob.bytes_by_container().items():
                out[cont] = out.get(cont, 0) + b
        return out


# ---------------------------------------------------------------------------
# Pager: moves pages between the tiers
# ---------------------------------------------------------------------------
class TieredPager:
    """Demote/promote primitives over (allocator, host store, cache pytree).

    The cache pytree is owned by the server and rebuilt functionally on
    every write, so the pager holds ``get_caches``/``set_caches`` closures
    instead of a reference. ``promote`` may recursively trigger allocator
    pressure (reclaim -> prefix-cache demotion), which is safe: eviction
    never touches pinned or non-resident nodes.
    """

    def __init__(self, allocator, host: HostPageStore, get_caches,
                 set_caches):
        self.allocator = allocator
        self.host = host
        self._get = get_caches
        self._set = set_caches
        self.demotions = 0
        self.promotions = 0

    def host_room(self) -> float:
        """Host pages still available (inf when unbounded)."""
        if self.host.max_pages is None:
            return float("inf")
        return max(0, self.host.max_pages - self.host.num_pages)

    def extract(self, page: int) -> PageBlob:
        return extract_page(self._get(), page)

    def demote(self, page: int) -> int:
        """Copy ``page`` to the host tier, release the caller's device
        reference, return the host handle. The caller must hold the ONLY
        reference (refcount 1) or the page content could keep changing
        under other owners after the snapshot."""
        blob = extract_page(self._get(), page)
        h = self.host.put(blob)
        self.allocator.free([page])
        self.demotions += 1
        return h

    def promote(self, handle: int) -> int:
        """Allocate a device page (may trigger reclaim pressure), inject the
        host blob into it, release the host copy; returns the page id (at
        refcount 1, owned by the caller)."""
        page = self.allocator.alloc()
        blob = self.host.pop(handle)
        self._set(inject_page(self._get(), blob, page))
        self.promotions += 1
        return page


# ---------------------------------------------------------------------------
# Snapshot / restore (persistent prefix pages)
# ---------------------------------------------------------------------------
SNAPSHOT_VERSION = 1


def snapshot_path(path: str) -> str:
    """The on-disk filename for ``path``: ``np.savez`` appends ``.npz`` to
    bare names, so save/load/exists checks all normalize through here."""
    return path if path.endswith(".npz") else path + ".npz"


def save_prefix_snapshot(path: str, entries, *, page_size: int,
                         geometry: str) -> int:
    """Serialize prefix-cache chains to ``path`` (one ``np.savez`` archive).

    ``entries`` is an iterable of ``(profile_key, tokens, PageBlob)`` with
    parents emitted before children (the trie's DFS order); ``tokens`` is
    the FULL token path from the root through the node's own chunk, so
    restore can rebuild the chain shape without trie internals. Returns the
    number of pages written.
    """
    chains = []
    arrays = {}
    n = 0
    for pk, tokens, blob in entries:
        chains.append({"profile": pk, "tokens": [int(t) for t in tokens],
                       "pools": len(blob.arrays)})
        for j, rec in enumerate(blob.arrays):
            for f in _FIELDS:
                arrays[f"e{n}_p{j}_{f}"] = rec[f]
        n += 1
    header = {"version": SNAPSHOT_VERSION, "page_size": int(page_size),
              "geometry": geometry, "chains": chains}
    np.savez(snapshot_path(path), __header__=np.asarray(json.dumps(header)),
             **arrays)
    return n


def load_prefix_snapshot(path: str) -> Tuple[dict, List[tuple]]:
    """Read a snapshot back: ``(meta, [(profile_key, tokens, PageBlob)])``
    in the order saved (parents before children)."""
    with np.load(snapshot_path(path), allow_pickle=False) as z:
        header = json.loads(str(z["__header__"]))
        if header.get("version") != SNAPSHOT_VERSION:
            raise ValueError(f"unsupported snapshot version "
                             f"{header.get('version')!r}")
        entries = []
        for i, ch in enumerate(header["chains"]):
            arrays = [{f: z[f"e{i}_p{j}_{f}"] for f in _FIELDS}
                      for j in range(ch["pools"])]
            entries.append((ch["profile"], list(ch["tokens"]),
                            PageBlob(arrays)))
    meta = {"version": header["version"], "page_size": header["page_size"],
            "geometry": header["geometry"]}
    return meta, entries
