"""Range calibration: pick integer bits from observed dynamic range.

The paper finds the needed integer bits empirically via accuracy sweeps
(Fig. 2b / 3 middle column). Calibration gives the same answer cheaply: run a
few batches, record per-layer max|x| (or a high percentile for outlier
robustness), and set I = required_int_bits(range). The search in
``core.search`` then only has to descend, never grow, formats.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .fixedpoint import FixedPointFormat, required_int_bits
from .policy import LayerPolicy, PrecisionPolicy


@dataclasses.dataclass
class RangeStats:
    """Streaming per-layer absolute-range statistics."""

    max_abs: Dict[str, float] = dataclasses.field(default_factory=dict)
    pctl_abs: Dict[str, float] = dataclasses.field(default_factory=dict)

    def update(self, name: str, values: jnp.ndarray, pctl: float = 99.9):
        v = np.abs(np.asarray(jax.device_get(values), np.float32)).reshape(-1)
        if v.size == 0:
            return
        m = float(v.max())
        p = float(np.percentile(v, pctl))
        self.max_abs[name] = max(self.max_abs.get(name, 0.0), m)
        self.pctl_abs[name] = max(self.pctl_abs.get(name, 0.0), p)


def int_bits_for(stats: RangeStats, name: str, *, use_percentile: bool = False,
                 margin_bits: int = 0) -> int:
    src = stats.pctl_abs if use_percentile else stats.max_abs
    r = src.get(name, 1.0)
    return int(required_int_bits(r)) + margin_bits


def calibrated_policy(names: Sequence[str],
                      weight_ranges: Dict[str, float],
                      data_ranges: Dict[str, float],
                      *, frac_bits_weight: int = 10,
                      frac_bits_data: int = 2,
                      weightless: Sequence[str] = ()) -> PrecisionPolicy:
    """Initial policy: calibrated I, generous F (paper's <0.1%-error start)."""
    layers = []
    for n in names:
        if n in weightless or n not in weight_ranges:
            w = None
        else:
            wi = int(required_int_bits(weight_ranges[n]))
            w = FixedPointFormat(wi, frac_bits_weight)
        di = int(required_int_bits(data_ranges.get(n, 1.0)))
        d = FixedPointFormat(di, frac_bits_data)
        layers.append(LayerPolicy(w, d))
    return PrecisionPolicy(tuple(names), tuple(layers))
